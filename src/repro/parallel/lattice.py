"""Dimension-ordered collectives — the Swallow lattice lesson (§V-A)
applied to multi-pod all-reduce.

Swallow's 2.5-D lattice routes one dimension per layer, crossing layers
at most twice.  The pod-scale translation: decompose big collectives one
mesh axis at a time, cheapest dimension last, so the slow (DCN) axis
carries only 1/N_fast of the bytes:

  lattice_all_reduce(x, ("data", "pod")):
      reduce-scatter over "data"   (fast ICI, full bytes)
      all-reduce over "pod"        (slow DCN, bytes / n_data)
      all-gather over "data"

vs a flat all-reduce over ("data","pod") which drags full gradients
across the pod boundary.  ``dcn_bytes_saved`` quantifies the win; the
equivalence tests prove numerical identity with psum.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import current_env

from repro.parallel.sharding import compat_shard_map as _shard_map


def _lattice_ar_local(x, fast_axes: Tuple[str, ...], slow_axis: str):
    """Per-shard body: RS over fast axes, AR over slow, AG back."""
    # flatten to 1-D so scatter dims always divide
    shape = x.shape
    flat = x.reshape(-1)
    for ax in fast_axes:
        flat = jax.lax.psum_scatter(flat, ax, scatter_dimension=0,
                                    tiled=True)
    if slow_axis is not None:
        flat = jax.lax.psum(flat, slow_axis)
    for ax in reversed(fast_axes):
        flat = jax.lax.all_gather(flat, ax, axis=0, tiled=True)
    return flat.reshape(shape)


def lattice_all_reduce(x, fast_axes: Sequence[str] = ("data",),
                       slow_axis: str = "pod"):
    """Dimension-ordered all-reduce of a replicated array.

    Numerically identical to psum over (fast + slow) axes; wire bytes on
    the slow axis shrink by prod(fast sizes).
    """
    env = current_env()
    if env is None:
        return x
    fast = tuple(a for a in fast_axes if a in env.mesh.axis_names
                 and env.mesh.shape[a] > 1)
    slow = slow_axis if (slow_axis in env.mesh.axis_names
                         and env.mesh.shape[slow_axis] > 1) else None
    if not fast and slow is None:
        return x
    n = 1
    for a in fast:
        n *= env.mesh.shape[a]
    pad = (-x.size) % n
    body = partial(_lattice_ar_local, fast_axes=fast, slow_axis=slow)
    if pad:
        orig = x.shape
        xp = jnp.pad(x.reshape(-1), (0, pad))
        out = _shard_map(body, mesh=env.mesh, in_specs=(P(),),
                         out_specs=P(), check_vma=False)(xp)
        return out[:x.size].reshape(orig)
    return _shard_map(body, mesh=env.mesh, in_specs=(P(),), out_specs=P(),
                      check_vma=False)(x)


def dcn_bytes_saved(nbytes: float, n_fast: int, n_pods: int) -> dict:
    """Wire bytes over the pod (DCN) boundary: flat vs dimension-ordered."""
    flat = 2.0 * nbytes * (n_pods - 1) / n_pods          # full AR over DCN
    lattice = 2.0 * (nbytes / n_fast) * (n_pods - 1) / n_pods
    return {"flat_dcn_bytes": flat, "lattice_dcn_bytes": lattice,
            "saving_factor": flat / max(lattice, 1e-12)}
