"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family; hf-verified].

Dense decoder: 28L, d_model=2048, 16 Q heads / 8 KV heads, d_ff=6144,
vocab=151936, qk-norm, SwiGLU, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=True,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_block_q=16, attn_block_kv=32)
