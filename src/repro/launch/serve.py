"""CLI batched-serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch tiny-100m \
      --batch 4 --prompt-len 64 --gen 32

Implements a simple continuous-batch scheduler: a request queue feeds
fixed-size decode batches; finished sequences are replaced by prefilling
waiting requests (the farmer-worker paradigm, C3: the coordinator hands
work to a fixed pool of compute slots).  ``--layout auto`` asks the cost
engine for the fastest (data, model) mesh for the decode shape and
reports predicted vs measured per-token time.
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--layout", default="manual", choices=["manual", "auto"],
                    help="auto: let the cost engine pick (data, model)")
    ap.add_argument("--link-mode", default="circuit",
                    choices=["circuit", "packet"])
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_tiny_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_test_mesh
    from repro.models import lm
    from repro import steps as steps_mod
    from repro.parallel.sharding import (autotune_layout, make_layout_mesh,
                                         use_sharding)

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    predicted = None
    if args.layout == "auto":
        decode_shape = ShapeConfig("serve", args.prompt_len + args.gen,
                                   args.batch, "decode")
        best, ranked = autotune_layout(cfg, decode_shape,
                                       mode=args.link_mode)
        predicted = best
        print(f"[cost-engine] {len(ranked)} candidate layouts for "
              f"{best.layout.n_chips} chips ({args.link_mode} mode):")
        for est in ranked:
            tag = " <= chosen" if est is ranked[0] else ""
            print(f"[cost-engine]   {est.describe()}{tag}")
        print(f"[cost-engine] predicted decode step "
              f"{best.step_time_s * 1e3:.3f} ms "
              f"({best.tokens_per_s:.0f} tok/s)")
        mesh = make_layout_mesh(best.layout)
    else:
        mesh = make_test_mesh(args.data, args.model) \
            if args.data * args.model > 1 else None

    max_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)

    with use_sharding(mesh):
        params = lm.init_params(key, cfg)
        prefill = jax.jit(steps_mod.make_prefill_step(cfg, max_len=max_len))
        serve = jax.jit(steps_mod.make_serve_step(cfg), donate_argnums=(2,))

        # request queue (farmer side)
        pending = [jax.random.randint(jax.random.PRNGKey(i),
                                      (args.prompt_len,), 2, cfg.vocab_size)
                   for i in range(args.requests)]
        done = 0
        t0 = time.time()
        tokens_out = 0
        while pending:
            batch_prompts = [pending.pop(0) for _ in
                             range(min(args.batch, len(pending) + 0))]
            while len(batch_prompts) < args.batch:   # pad the worker pool
                batch_prompts.append(batch_prompts[-1])
            prompts = jnp.stack(batch_prompts)
            logits, caches = prefill(params, prompts)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs = [tok]
            for i in range(args.gen - 1):
                pos = args.prompt_len + i
                tok, logits, caches = serve(params, tok, caches,
                                            jnp.int32(pos))
                outs.append(tok)
            done += len(batch_prompts)
            tokens_out += args.gen * args.batch
        dt = time.time() - t0
        print(f"served {done} requests, {tokens_out} tokens "
              f"in {dt:.2f}s ({tokens_out / dt:.1f} tok/s)")
        if predicted is not None and tokens_out:
            measured = dt / tokens_out * args.batch   # s per decode step
            print(f"[cost-engine] predicted {predicted.step_time_s * 1e3:.3f}"
                  f" ms vs measured {measured * 1e3:.3f} ms per decode step "
                  f"(ratio {measured / predicted.step_time_s:.2f}x; the "
                  f"engine models v5e-class chips, not this host)")


if __name__ == "__main__":
    main()
