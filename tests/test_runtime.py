"""Runtime fault-tolerance: checkpoint/restart, health, elastic logic."""
import os
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_tiny_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.optim import adam as adam_lib
from repro.runtime import checkpoint as ckpt, elastic, health, train_loop


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_tiny_config("qwen3-14b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam_lib.init(params, adam_lib.AdamConfig())
    state = {"params": params, "opt": opt}
    ckpt.save(str(tmp_path), 7, state)
    tpl = jax.eval_shape(lambda: state)
    step, restored = ckpt.restore(str(tmp_path), tpl)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert jnp.array_equal(a, b)


def test_checkpoint_int8_state_roundtrip(tmp_path):
    cfg = get_tiny_config("deepseek-v3-671b").replace(opt_state_dtype="int8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam_lib.init(params, adam_lib.AdamConfig(state_dtype="int8"))
    ckpt.save(str(tmp_path), 3, {"params": params, "opt": opt})
    tpl = jax.eval_shape(lambda: {"params": params, "opt": opt})
    step, restored = ckpt.restore(str(tmp_path), tpl)
    assert step == 3


def test_async_checkpointer_and_gc(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=2)
    state = {"x": jnp.arange(10.0)}
    for s in (1, 2, 3):
        c.save(s, state)
    c.wait()
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000002", "step_00000003"]
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_restart_continues_training(tmp_path):
    """Crash mid-run, restart, and verify the loop resumes at the right
    step with identical data (deterministic pipeline)."""
    cfg = get_tiny_config("qwen3-14b")
    shape = ShapeConfig("t", 32, 2, "train")
    job = train_loop.TrainJobConfig(steps=10, ckpt_every=5, log_every=5,
                                    ckpt_dir=str(tmp_path))

    class Crash(Exception):
        pass

    def bomb(step):
        if step == 7:
            raise Crash()

    with pytest.raises(Crash):
        train_loop.run(cfg, shape, job=job, failure_hook=bomb)
    assert ckpt.latest_step(str(tmp_path)) == 5
    out = train_loop.run(cfg, shape, job=job)   # restart from step 5
    assert out["final_metrics"]["step"] >= 9
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_heartbeat_monitor():
    hb = health.HeartbeatMonitor(["a", "b", "c"], timeout_s=10.0)
    t0 = time.time()
    hb.beat("a", t0)
    hb.beat("b", t0)
    hb.beat("c", t0 - 100)
    failed = hb.check(t0 + 1)
    assert failed == {"c"}
    assert hb.healthy() == ["a", "b"]
    hb.beat("c", t0 + 2)     # node returns
    assert hb.check(t0 + 3) == set()
    assert hb.healthy() == ["a", "b", "c"]


def test_straggler_detector():
    sd = health.StragglerDetector(["a", "b", "c", "d"], ratio=1.5,
                                  patience=3)
    for i in range(2):
        assert sd.observe({"a": 1.0, "b": 1.0, "c": 1.0, "d": 2.0}) == set()
    assert sd.observe({"a": 1.0, "b": 1.0, "c": 1.0, "d": 2.0}) == {"d"}
    # recovery resets strikes
    sd.observe({"a": 1.0, "b": 1.0, "c": 1.0, "d": 1.0})
    assert sd.observe({"a": 1.0, "b": 1.0, "c": 1.0, "d": 2.0}) == set()


def test_straggler_detector_needs_a_cohort():
    """Degenerate observations (all nodes failed or held out) evict
    nobody — there is no fleet median to straggle against — and a
    single-node cohort can never exceed its own median."""
    sd = health.StragglerDetector(["a", "b"], ratio=1.5, patience=1)
    assert sd.observe({}) == set()
    assert sd.observe({"a": 99.0}) == set()      # median == own duration
    assert sd.observe({}) == set()               # still safe mid-history
    assert sd.summary() == {"a": 99.0}


def test_heartbeat_rejoin_races_timeout():
    """A beat that lands exactly as the timeout would fire wins: beating
    discards the node from the failed set (elastic re-join) and resets
    its clock, so the next check reports nothing."""
    hb = health.HeartbeatMonitor(["a", "b"], timeout_s=2.0)
    t0 = time.time()
    hb.beat("a", t0)
    hb.beat("b", t0)
    assert hb.check(t0 + 3) == {"a", "b"}        # both dark
    hb.beat("a", t0 + 3)                         # a returns at the verdict
    assert hb.failed == {"b"}
    assert hb.check(t0 + 4) == set()             # no re-report of b
    assert hb.healthy() == ["a"]
    hb.beat("b", t0 + 5)
    assert hb.failed == set()


def test_heartbeat_all_nodes_failed():
    hb = health.HeartbeatMonitor(["a", "b", "c"], timeout_s=1.0)
    t0 = time.time()
    for n in ("a", "b", "c"):
        hb.beat(n, t0)
    assert hb.check(t0 + 5) == {"a", "b", "c"}
    assert hb.healthy() == []
    # the watchdog's straggler pass sees an empty cohort: no eviction
    sd = health.StragglerDetector(["a", "b", "c"], patience=1)
    assert sd.observe({}) == set()


def test_recovery_policy():
    rp = health.RecoveryPolicy(data_axis=16, model_axis=16, spares=2)
    assert rp.plan(0)["action"] == "none"
    assert rp.plan(2)["action"] == "replace"
    plan = rp.plan(20)
    assert plan["action"] == "shrink"
    assert plan["new_data_axis"] == 14


def test_rebatch_invariant():
    per, accum = elastic.rebatch(256, old_data=16, new_data=12, accum=1)
    assert per % 12 == 0
    assert abs(per * accum - 256) / 256 < 0.1


def test_elastic_restore_reshards(tmp_path):
    """Save on no mesh, restore 'onto a new mesh' (single device here —
    the multi-device path is exercised in test_multidevice.py)."""
    cfg = get_tiny_config("qwen3-14b")
    adam_cfg = adam_lib.AdamConfig()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam_lib.init(params, adam_cfg)
    ckpt.save(str(tmp_path), 11, {"params": params, "opt": opt})
    step, p2, o2 = elastic.restore_elastic(str(tmp_path), cfg, adam_cfg,
                                           new_mesh=None)
    assert step == 11
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert jnp.array_equal(a, b)
