"""CLI trainer.

  PYTHONPATH=src python -m repro.launch.train --arch tiny-100m \
      --seq 512 --batch 8 --steps 200 --ckpt-dir /tmp/ck

Use --tiny to run the reduced smoke config of any assigned arch, and
--devices N (with --data D --model M) to train on N fake CPU devices.
With ``--layout auto`` the cost engine enumerates every (data, model)
factorization of the device count, prints the fastest, and the run
reports predicted vs measured step time.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced smoke config of --arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--impl", default=None)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake CPU device count (0 = real devices)")
    ap.add_argument("--layout", default="manual", choices=["manual", "auto"],
                    help="auto: let the cost engine pick (data, model)")
    ap.add_argument("--link-mode", default="circuit",
                    choices=["circuit", "packet"],
                    help="interconnect model used by --layout auto")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    from repro.configs import get_config, get_tiny_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import autotune_layout, make_layout_mesh
    from repro.runtime import train_loop

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    predicted = None
    if args.layout == "auto":
        best, ranked = autotune_layout(cfg, shape, mode=args.link_mode)
        predicted = best
        print(f"[cost-engine] {len(ranked)} candidate layouts for "
              f"{best.layout.n_chips} chips ({args.link_mode} mode):")
        for est in ranked:
            tag = " <= chosen" if est is ranked[0] else ""
            print(f"[cost-engine]   {est.describe()}{tag}")
        print(f"[cost-engine] predicted step time "
              f"{best.step_time_s * 1e3:.3f} ms "
              f"({best.tokens_per_s:.0f} tok/s)")
        mesh = make_layout_mesh(best.layout)
    else:
        mesh = None
        if args.data * args.model > 1:
            mesh = make_test_mesh(args.data, args.model)

    job = train_loop.TrainJobConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, peak_lr=args.lr,
        metrics_path=args.metrics)
    out = train_loop.run(cfg, shape, mesh=mesh, job=job, impl=args.impl)
    print("final:", {k: v for k, v in out["final_metrics"].items()})
    if predicted is not None:
        measured = out["final_metrics"].get("sec_per_step")
        if measured:
            print(f"[cost-engine] predicted {predicted.step_time_s:.4f}s "
                  f"vs measured {measured:.4f}s per step "
                  f"(ratio {measured / predicted.step_time_s:.2f}x; the "
                  f"engine models v5e-class chips, not this host)")


if __name__ == "__main__":
    main()
