"""Unified interconnect-aware cost engine (Swallow §II-B + §V + §VI, composed).

The paper's thesis is that scalability comes from pricing communication
honestly: the §V link model (circuit vs packet), the §II-B e/c-E/C
ratio methodology, and the §VI energy accounting only matter when they
*drive placement decisions*.  This module composes the three existing
analytic models into one API:

    estimate(config, layout, mode) -> CostEstimate

  * compute + HBM side  — ``analysis/flops.step_costs`` (HLO-equivalent
    FLOPs, per-chip HBM traffic, GSPMD padding waste at the layout's TP
    degree);
  * interconnect side   — ``core/network.ring_collective_time`` prices
    every collective the layout implies, under the paper's circuit
    (persistent, compiler-scheduled) or packet (per-step setup) model;
  * energy side         — ``core/energy.step_energy`` converts the
    resulting counters into the Fig. 8 three-way split.

Consumers:
  * ``parallel/sharding.autotune_layout`` — enumerates candidate
    (data, model) factorizations and picks the fastest (the §II-B
    "choose the balanced design point" loop, automated);
  * ``core/nos.NOS`` — prices candidate placements at admission and
    accounts per-job energy (§VIII nOS energy optimisation);
  * ``launch/train.py`` / ``launch/serve.py`` ``--layout auto`` and
    ``benchmarks/cost_sweep.py`` (Fig. 8/9-style tables).

Everything here is pure host-side arithmetic — no devices touched — so
the scheduler and the autotuner stay unit-testable on a laptop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.flops import CellCost, param_bytes, step_costs
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.core.energy import StepEnergy, step_energy
from repro.core.network import LinkSpec, ring_collective_time
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

ACT_BYTES = 2.0  # bf16 activations on the wire


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Layout:
    """A (data x model) mesh factorization — the unit the engine prices.

    ``data`` is the batch/FSDP axis (paper: farmer-worker rows), ``model``
    the tensor-parallel axis (paper: the high-bandwidth dimension that
    nOS never splits between tenants).
    """
    data: int = 1
    model: int = 1
    pod: int = 1

    @property
    def n_chips(self) -> int:
        return self.pod * self.data * self.model

    def __str__(self) -> str:
        if self.pod > 1:
            return f"{self.pod}x{self.data}x{self.model} (pod x data x model)"
        return f"{self.data}x{self.model} (data x model)"


def candidate_layouts(n_chips: int, max_model: Optional[int] = None
                      ) -> List[Layout]:
    """All (data, model) factorizations of ``n_chips``, smallest TP first."""
    out = []
    for m in range(1, n_chips + 1):
        if n_chips % m:
            continue
        if max_model is not None and m > max_model:
            continue
        out.append(Layout(data=n_chips // m, model=m))
    return out


# ---------------------------------------------------------------------------
# Collective traffic implied by a layout
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CommEvent:
    """One collective in the per-step schedule."""
    name: str
    kind: str                 # all_gather | reduce_scatter | all_reduce | all_to_all
    group: int                # participating devices (ring size)
    bytes_per_device: float   # input bytes each device contributes
    count: int = 1            # occurrences per step

    def wire_bytes_per_device(self) -> float:
        """Bytes each device actually pushes onto its links (ring model)."""
        if self.group <= 1:
            return 0.0
        factor = 2.0 if self.kind == "all_reduce" else 1.0
        return self.count * factor * self.bytes_per_device \
            * (self.group - 1) / self.group


def comm_events(cfg: ModelConfig, shape: ShapeConfig,
                layout: Layout) -> List[CommEvent]:
    """The collective schedule one step executes under ``layout``.

    Megatron-style accounting: every mixer and FFN sublayer ends in one
    all-reduce over the model axis; training re-runs the forward
    collectives in the backward pass (and once more under remat).  MoE
    layers add dispatch/combine all-to-alls.  Training adds a ZeRO-1
    gradient reduce-scatter + parameter all-gather over the data axis,
    on each device's TP shard of the parameters.
    """
    D = layout.data * layout.pod
    M = layout.model
    mode = shape.kind
    B, S = shape.global_batch, shape.seq_len
    tokens = float(B) * (1 if mode == "decode" else S)
    t_local = tokens / D
    passes = (3 if cfg.remat else 2) if mode == "train" else 1

    events: List[CommEvent] = []
    if M > 1:
        per = t_local * cfg.d_model * ACT_BYTES
        events.append(CommEvent("tp_sublayer_allreduce", "all_reduce", M,
                                per, count=2 * cfg.n_layers * passes))
        if cfg.moe is not None:
            n_moe = cfg.n_layers - cfg.first_k_dense
            slots = t_local * cfg.moe.top_k * cfg.moe.capacity_factor
            events.append(CommEvent(
                "moe_dispatch_combine", "all_to_all", M,
                slots * cfg.d_model * ACT_BYTES,
                count=2 * n_moe * passes))
    if mode == "train" and D > 1:
        shard = param_bytes(cfg) / M
        events.append(CommEvent("grad_reduce_scatter", "reduce_scatter",
                                D, shard))
        events.append(CommEvent("param_all_gather", "all_gather", D, shard))
    return events


def serving_comm_events(cfg: ModelConfig, layout: Layout, *,
                        n_tokens: int, n_merges: int = 1
                        ) -> List[CommEvent]:
    """The extra collectives paged-KV serving adds on top of
    :func:`comm_events` when the page pools are striped over the model
    axis (paper §V applied to §III-A's ``address % n`` store).

    * ``kv_stripe_write`` — every decoded/prefilled token appends one KV
      entry to the page owning its slot; under uniform page placement
      ``(M-1)/M`` of those writes leave the producing node, exactly the
      paper's remote-fraction model.  Modelled as an all-to-all of the
      per-token KV bytes (``2 * n_kv_heads * head_dim * n_layers`` bf16
      words for K and V) so ``wire_bytes_per_device`` carries the
      (M-1)/M factor.
    * ``decode_stats_merge`` — the sharded paged-attention kernel merges
      per-stripe online-softmax partials ``(m, l, acc)`` with an
      all-reduce over the model axis, once per decode dispatch
      (``n_merges``) per layer.
    """
    M = layout.model
    if M <= 1:
        return []
    kv_bytes_per_token = 2.0 * cfg.kv_dim * cfg.n_layers * ACT_BYTES
    stats_bytes = (float(n_tokens) * cfg.n_kv_heads
                   * (cfg.n_heads // cfg.n_kv_heads)
                   * (cfg.head_dim + 2) * 4.0)  # f32 acc + m + l
    return [
        CommEvent("kv_stripe_write", "all_to_all", M,
                  float(n_tokens) * kv_bytes_per_token),
        CommEvent("decode_stats_merge", "all_reduce", M, stats_bytes,
                  count=n_merges * cfg.n_layers),
    ]


def serving_comm_cost(cfg: ModelConfig, layout: Layout,
                      mode: str = "circuit", *, n_tokens: int,
                      n_merges: int = 1, link: LinkSpec = LinkSpec()
                      ) -> Tuple[float, float]:
    """(seconds, wire bytes per device) the serving collectives add under
    ``layout`` — the §V link model priced on the stripe traffic."""
    secs = 0.0
    wire = 0.0
    for ev in serving_comm_events(cfg, layout, n_tokens=n_tokens,
                                  n_merges=n_merges):
        secs += ev.count * ring_collective_time(
            ev.bytes_per_device, ev.group, kind=ev.kind, link=link,
            mode=mode)
        wire += ev.wire_bytes_per_device()
    return secs, wire


# ---------------------------------------------------------------------------
# The estimate
# ---------------------------------------------------------------------------
@dataclass
class CostEstimate:
    """What one step costs under a layout — time, traffic and energy."""
    layout: Layout
    shape: ShapeConfig
    mode: str                       # circuit | packet
    step_time_s: float
    compute_s: float
    hbm_s: float
    ici_s: float
    flops_per_chip: float
    hbm_bytes_per_chip: float
    ici_bytes_per_chip: float
    energy: StepEnergy
    cell: CellCost
    events: Tuple[CommEvent, ...] = ()
    breakdown: Dict[str, float] = field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        t = self.shape.global_batch * (
            1 if self.shape.kind == "decode" else self.shape.seq_len)
        return t / max(self.step_time_s, 1e-12)

    def edp(self) -> float:
        """Energy-delay product of one step across the whole slice —
        the §VIII nOS objective (fast AND proportional)."""
        return self.step_time_s * self.energy.total_j * self.layout.n_chips

    def describe(self) -> str:
        e = self.energy
        return (f"layout {self.layout}: step {self.step_time_s * 1e3:.3f} ms "
                f"(compute {self.compute_s * 1e3:.3f}, hbm "
                f"{self.hbm_s * 1e3:.3f}, ici {self.ici_s * 1e3:.3f}) "
                f"{e.w_per_chip:.0f} W/chip")


def estimate(config: ModelConfig, layout: Layout, mode: str = "circuit",
             shape: Optional[ShapeConfig] = None,
             link: LinkSpec = LinkSpec()) -> CostEstimate:
    """Price one step of ``config`` at ``shape`` under ``layout``.

    ``mode`` selects the §V link model: "circuit" (persistent ring
    collectives, zero per-step setup) or "packet" (per-step schedule
    setup + per-hop routing overhead).
    """
    if mode not in ("circuit", "packet"):
        raise ValueError(f"mode must be circuit|packet, got {mode!r}")
    shape = shape or SHAPES["train_4k"]
    n = layout.n_chips
    cell = step_costs(config, shape, n, tp=layout.model)
    compute_s = cell.flops_total / (n * PEAK_FLOPS_BF16)
    hbm_s = cell.hbm_bytes_per_chip / HBM_BW

    events = comm_events(config, shape, layout)
    ici_s = 0.0
    ici_bytes = 0.0
    for ev in events:
        ici_s += ev.count * ring_collective_time(
            ev.bytes_per_device, ev.group, kind=ev.kind, link=link, mode=mode)
        ici_bytes += ev.wire_bytes_per_device()

    # compute and HBM streams overlap (roofline max); collectives are
    # exposed — the pessimistic end of what GSPMD achieves, and exactly
    # the quantity the circuit/packet gap acts on.
    step = max(compute_s, hbm_s) + ici_s
    energy = step_energy(
        flops_per_chip=cell.flops_total / n,
        hbm_bytes_per_chip=cell.hbm_bytes_per_chip,
        ici_bytes_per_chip=ici_bytes,
        step_seconds=step)
    return CostEstimate(
        layout=layout, shape=shape, mode=mode, step_time_s=step,
        compute_s=compute_s, hbm_s=hbm_s, ici_s=ici_s,
        flops_per_chip=cell.flops_total / n,
        hbm_bytes_per_chip=cell.hbm_bytes_per_chip,
        ici_bytes_per_chip=ici_bytes, energy=energy, cell=cell,
        events=tuple(events),
        breakdown={"compute_s": compute_s, "hbm_s": hbm_s, "ici_s": ici_s})


def rank_layouts(config: ModelConfig, shape: Optional[ShapeConfig] = None,
                 n_chips: int = 1, mode: str = "circuit",
                 link: LinkSpec = LinkSpec(),
                 max_model: Optional[int] = None) -> List[CostEstimate]:
    """Estimates for every feasible factorization of ``n_chips``, fastest
    first.  Layouts whose data degree does not divide the global batch
    are excluded (the batch is sharded over that axis), unless no
    candidate survives the filter."""
    lays = candidate_layouts(n_chips, max_model)
    if shape is not None:
        B = shape.global_batch
        feasible = [l for l in lays if B % (l.data * l.pod) == 0]
        lays = feasible or lays
    ests = [estimate(config, lay, mode, shape, link) for lay in lays]
    ests.sort(key=lambda e: e.step_time_s)
    return ests


def rank_serving_layouts(config: ModelConfig,
                         shape: Optional[ShapeConfig] = None,
                         n_chips: int = 1, mode: str = "circuit",
                         link: LinkSpec = LinkSpec(),
                         max_model: Optional[int] = None
                         ) -> List[CostEstimate]:
    """:func:`rank_layouts` with the paged-serving stripe traffic priced
    in (``serving_comm_events``): each estimate's ``step_time_s`` and
    ``ici_s`` gain the per-decode-step KV stripe write + partials merge,
    recorded under ``breakdown["serving_comm_s"]``, then the candidates
    are re-sorted.  ``--layout auto`` on the paged engine ranks with
    this so the §V link model arbitrates serving placement too."""
    ests = rank_layouts(config, shape, n_chips, mode, link, max_model)
    for est in ests:
        n_tokens = est.shape.global_batch  # one token/sequence/decode step
        secs, wire = serving_comm_cost(
            config, est.layout, mode, n_tokens=n_tokens, n_merges=1,
            link=link)
        est.step_time_s += secs
        est.ici_s += secs
        est.ici_bytes_per_chip += wire
        est.breakdown["serving_comm_s"] = secs
        est.events = est.events + tuple(serving_comm_events(
            config, est.layout, n_tokens=n_tokens, n_merges=1))
    ests.sort(key=lambda e: e.step_time_s)
    return ests
