"""Quickstart: init a tiny LM, train a few steps, generate greedily.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_tiny_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.runtime import train_loop


def main():
    cfg = get_tiny_config("qwen3-14b")
    print(f"config: {cfg.name} (reduced) — {cfg.n_params()/1e6:.2f}M params")

    shape = ShapeConfig("quickstart", seq_len=64, global_batch=4,
                        kind="train")
    job = train_loop.TrainJobConfig(steps=30, log_every=10, peak_lr=2e-3,
                                    warmup=5)
    out = train_loop.run(cfg, shape, job=job)
    print(f"trained 30 steps in {out['wall_s']:.1f}s; "
          f"loss {out['history'][0]['loss']:.3f} -> "
          f"{out['final_metrics']['loss']:.3f}")

    params = out["params"]
    prompt = jnp.array([[5, 17, 42, 100, 7, 23, 88, 3]], jnp.int32)
    logits, caches = lm.prefill(params, cfg, prompt, max_len=24)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    gen = [int(tok[0, 0])]
    for i in range(8):
        logits, caches = lm.decode_step(params, cfg, tok, caches,
                                        prompt.shape[1] + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen.append(int(tok[0, 0]))
    print("greedy continuation token ids:", gen)


if __name__ == "__main__":
    main()
