"""Pallas TPU flash attention (forward), H-space layout.

Tiling: grid (B, H, nq, nkv), kv innermost ("arbitrary" = sequential) so
the online-softmax state (m, l, acc) lives in VMEM scratch across the kv
sweep.  Block shapes are (block_q, head_dim) / (block_kv, head_dim) —
head_dim is 64..256 for the assigned archs, so a (512, 128) q tile +
(1024, 128) kv tile + fp32 acc uses well under 1 MB of VMEM, and the MXU
contraction dims are multiples of 128 (hardware aligned).

Causal masking is block-exact: fully-masked kv blocks are skipped with
``pl.when`` (no FLOPs on the lower-triangle complement — unlike the
blocked-jnp fallback, which computes the full S^2; the cost model
accounts for both).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -2.0 ** 30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, causal, window, softcap, block_q, block_kv, nkv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # skip kv blocks fully outside the causal / sliding-window band
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, q_start - (k_start + block_kv - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        iq = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_kv), 0)
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_kv), 1)
        ok = jnp.ones((block_q, block_kv), bool)
        if causal:
            ok &= jk <= iq
        if window is not None:
            ok &= (iq - jk) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nkv - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...], 1e-37)[:, None]
                            ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None, block_q=512, block_kv=1024,
                    interpret=True):
    """q,k,v (B,S,H,hd), k/v pre-expanded to H heads. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    bq = min(block_q, S)
    while S % bq:
        bq -= 1
    bkv = min(block_kv, S)
    while S % bkv:
        bkv -= 1
    nq, nkv = S // bq, S // bkv

    qt = jnp.moveaxis(q, 2, 1)   # (B,H,S,hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_kv=bkv, nkv=nkv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
