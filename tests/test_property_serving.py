"""Property-based allocator/scheduler tests (hypothesis-driven).

Randomized op sequences — alloc / share / release / grow / reserve /
truncate_to / free — run against :class:`PageAllocator`, asserting after
every op the invariants the serving stack leans on:

* refcount conservation — every non-null page is on exactly one side
  (free list at refcount 0, or allocated at refcount >= 1), and the
  free list holds no duplicates (``check_conservation``);
* no double free — releasing an unallocated page always raises;
* null-page invariance — page 0 is never allocated, held, shared or
  refcounted, no matter the op sequence;
* fault-plane extension — op sequences that interleave ``fail_node`` /
  ``restore_node`` keep the three-way conservation partition (free /
  allocated / quarantined-parked), never re-allocate or share a
  quarantined page while its node is down, and drain back to a whole
  pool once every node restores.

Plus scheduler conservation under randomized arrival traces (both the
monolithic FIFO machine and the chunked EDF machine with chunk-step
transitions: request conservation, strict chunk progress per round — the
no-starvation property — and page-aligned chunk boundaries), a
chunked-vs-monolithic engine bit-identity property over drawn
(chunk_tokens, prompt_len) pairs including non-page-aligned tails, and
algebraic properties of the n-gram proposer/acceptance rule.

Runs under the optional-hypothesis shim (tests/hypothesis_compat.py):
with hypothesis absent (the base image) every ``@given`` test reports
SKIPPED; the CI ``tests-hypothesis`` job installs hypothesis and runs
them for real.  See docs/TESTING.md.
"""
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.serving import (ContinuousBatchScheduler, NULL_PAGE,
                           PageAllocator, Request, propose_ngram)
from repro.serving.spec_decode import NGramSpec

# an op is (opcode, rid index, size): the interpreter maps out-of-domain
# ops to no-ops so every generated sequence is valid
OPS = st.lists(st.tuples(st.integers(0, 6), st.integers(0, 3),
                         st.integers(0, 9)), max_size=60)


def _check_invariants(a: PageAllocator):
    assert a.check_conservation()
    assert NULL_PAGE not in a.refcount
    for pages in a.held.values():
        assert NULL_PAGE not in pages
    assert a.free_pages + a.pages_in_use == a.n_pages - 1


def _apply(a: PageAllocator, shared_refs, op):
    """One interpreter step; ``shared_refs`` tracks extra references we
    took (a stand-in for prefix-cache nodes / second tenants) so the
    drain phase can balance them."""
    code, r, n = op
    rid = f"r{r}"
    held = a.held.get(rid)
    if code == 0 and held is None:
        a.alloc(rid, n % 5 + 1)
    elif code == 1 and held is not None:
        a.grow(rid, n % 3 + 1)
    elif code == 2 and held is not None:
        a.free(rid)
    elif code == 3 and held:
        page = held[n % len(held)]
        a.share(page)
        shared_refs.append(page)
    elif code == 4 and shared_refs:
        a.release_page(shared_refs.pop(n % len(shared_refs)))
    elif code == 5 and held is not None:
        a.reserve(rid, n * a.page_size // 2)
    elif code == 6 and held is not None:
        a.truncate_to(rid, n * a.page_size // 2)


@settings(max_examples=60, deadline=None)
@given(OPS)
def test_allocator_random_ops_conserve_pages(ops):
    a = PageAllocator(n_pages=17, page_size=4, n_nodes=3)
    shared_refs = []
    for op in ops:
        _apply(a, shared_refs, op)
        _check_invariants(a)
    # drain: balance every reference; the pool must come back whole
    for page in shared_refs:
        a.release_page(page)
    for rid in list(a.held):
        a.free(rid)
    _check_invariants(a)
    assert a.pages_in_use == 0 and a.free_pages == a.n_pages - 1


@settings(max_examples=60, deadline=None)
@given(OPS)
def test_allocator_random_ops_never_double_free(ops):
    """After any op sequence, releasing a page that is on the free list
    raises instead of corrupting the free list."""
    a = PageAllocator(n_pages=9, page_size=4, n_nodes=2)
    shared_refs = []
    for op in ops:
        _apply(a, shared_refs, op)
    free = [p for f in a._free_by_node for p in f]
    for page in free[:3]:
        with pytest.raises(ValueError):
            a.release_page(page)
    with pytest.raises(ValueError):
        a.share(NULL_PAGE)
    _check_invariants(a)


# the fault-aware op space adds fail_node (7) and restore_node (8)
FAULT_OPS = st.lists(st.tuples(st.integers(0, 8), st.integers(0, 3),
                               st.integers(0, 9)), max_size=60)


def _check_fault_invariants(a: PageAllocator):
    """The quarantine-extended partition: free + allocated +
    quarantined-parked == n_pages - 1, with no page on two sides."""
    assert a.check_conservation()
    assert NULL_PAGE not in a.refcount
    assert NULL_PAGE not in a.quarantined
    free = [p for f in a._free_by_node for p in f]
    assert not (set(free) & a.quarantined)
    for node in a.failed_nodes:
        assert not a._free_by_node[node], \
            "a failed node's free list must be empty"
    parked = len(a.quarantined - set(a.refcount))
    assert a.free_pages + a.pages_in_use + parked == a.n_pages - 1


def _apply_faulty(a: PageAllocator, shared_refs, op):
    """The fault-aware interpreter: base ops plus node fail/restore.
    ``share`` only targets non-quarantined pages (sharing a quarantined
    page is *asserted* to raise separately)."""
    code, r, n = op
    if code == 7:
        a.fail_node(n % a.n_nodes)
    elif code == 8:
        a.restore_node(n % a.n_nodes)
    elif code == 3:
        held = a.held.get(f"r{r}")
        if held:
            page = held[n % len(held)]
            if page not in a.quarantined:
                a.share(page)
                shared_refs.append(page)
    else:
        _apply(a, shared_refs, op)


@settings(max_examples=60, deadline=None)
@given(FAULT_OPS)
def test_allocator_fault_ops_conserve_and_quarantine(ops):
    """Random interleavings of alloc/share/release/grow/truncate with
    node failures and re-joins: the extended conservation partition
    holds after EVERY op, a quarantined page is never re-allocated or
    shared while its node is down, and once every node restores and
    every reference drains the pool comes back whole."""
    a = PageAllocator(n_pages=17, page_size=4, n_nodes=3)
    shared_refs = []
    for op in ops:
        _apply_faulty(a, shared_refs, op)
        _check_fault_invariants(a)
        if a.quarantined:
            # never re-served: a quarantined page cannot gain readers
            with pytest.raises(ValueError):
                a.share(next(iter(a.quarantined)))
        # and never re-allocated: a fresh allocation only sees healthy
        # stripes
        probe = a.alloc("probe", 2)
        if probe is not None:
            assert not (set(probe) & a.quarantined)
            a.free("probe")
        _check_fault_invariants(a)
    # drain: restore every node, release every reference — the pool
    # must come back whole (no page leaked into quarantine limbo)
    for node in range(a.n_nodes):
        a.restore_node(node)
    assert not a.quarantined and not a.failed_nodes
    for page in shared_refs:
        a.release_page(page)
    for rid in list(a.held):
        a.free(rid)
    _check_invariants(a)
    assert a.pages_in_use == 0 and a.free_pages == a.n_pages - 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 8), st.integers(1, 6)),
                min_size=1, max_size=10),
       st.integers(8, 20), st.integers(1, 4))
def test_scheduler_random_traces_conserve_requests(reqs, n_pages,
                                                   max_batch):
    """Any admissible random trace drains with every request finished
    exactly once, every token accounted for, and every page returned —
    preemption and page pressure included."""
    a = PageAllocator(n_pages=n_pages, page_size=4, n_nodes=2)
    s = ContinuousBatchScheduler(a, max_batch=max_batch)
    submitted = 0
    for i, (plen, gen) in enumerate(reqs):
        if a.pages_for(plen + gen) > n_pages - 1:
            continue               # larger-than-pool requests are rejected
        s.submit(Request(rid=f"q{i}", prompt_len=plen, gen=gen))
        submitted += 1
    steps = 0
    while (s.waiting or s.running) and steps < 2000:
        plan = s.plan_step()
        for req in plan.admitted:
            s.note_first_token(req, token=1)
        s.complete_step({slot: 1 for slot in list(s.running)})
        steps += 1
    assert steps < 2000, "scheduler wedged"
    assert s.conserved(submitted)
    assert len(s.finished) == submitted
    for r in s.finished:
        assert len(r.tokens) == r.gen
    assert a.pages_in_use == 0
    _check_invariants(a)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 12), st.integers(1, 6),
                          st.integers(0, 2)),
                min_size=1, max_size=10),
       st.integers(10, 24), st.integers(1, 4), st.integers(2, 9))
def test_chunked_scheduler_random_traces_conserve_and_progress(
        reqs, n_pages, max_batch, chunk_tokens):
    """The chunked op-machine: any admissible random trace — drawn
    prompt/gen lengths, drawn SLO classes, drawn (possibly misaligned)
    chunk size, page pressure and preemption included — drains through
    chunk-step transitions with

    * request conservation (every request finished exactly once, every
      token accounted for, every page returned);
    * STRICT chunk progress: each ``plan_chunks`` round advances every
      prefilling request by >= 1 chunk (the no-starvation guarantee);
    * non-final chunk boundaries page-aligned whenever the chunk can
      reach a boundary (small chunks stay inside the start's page);
    * allocator refcount conservation after every round."""
    slo_names = ("interactive", "standard", "batch")
    a = PageAllocator(n_pages=n_pages, page_size=4, n_nodes=2)
    s = ContinuousBatchScheduler(a, max_batch=max_batch, chunked=True,
                                 chunk_tokens=chunk_tokens,
                                 prefill_cost_s=lambda n: float(n),
                                 decode_cost_s=1.0)
    submitted = 0
    for i, (plen, gen, slo_i) in enumerate(reqs):
        if a.pages_for(plen + gen) > n_pages - 1:
            continue               # larger-than-pool requests are rejected
        s.submit(Request(rid=f"q{i}", prompt_len=plen, gen=gen,
                         slo=slo_names[slo_i]))
        submitted += 1
    steps = 0
    while (s.waiting or s.prefilling or s.running) and steps < 2000:
        s.plan_step()
        before = {r.rid: r.prefilled for r in s.prefilling.values()}
        tasks = s.plan_chunks(window=2)
        advanced = set()
        for req, start, n in tasks:
            assert n >= 1 and start + n <= req.prompt_len
            end = start + n
            if end < req.prompt_len:
                # non-final chunks land on a page boundary unless the
                # chunk is too small to reach one from its start (then
                # it stays inside the start's page and realigns later)
                assert end % a.page_size == 0 \
                    or end // a.page_size == start // a.page_size
            advanced.add(req.rid)
        # strict progress: every request that was prefilling when the
        # round was planned got at least one chunk
        assert advanced == set(before), "a prefilling request starved"
        for req in list(s.prefilling.values()):
            assert req.prefilled >= before[req.rid] + 1
            if req.prefilled == req.prompt_len:
                s.finish_prefill(req, token=1)
        s.complete_step({slot: 1 for slot in list(s.running)})
        assert a.check_conservation()
        assert NULL_PAGE not in a.refcount
        steps += 1
    assert steps < 2000, "chunked scheduler wedged"
    assert s.conserved(submitted)
    assert len(s.finished) == submitted
    for r in s.finished:
        assert len(r.tokens) == r.gen
    assert a.pages_in_use == 0
    _check_invariants(a)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 12), st.lists(st.integers(5, 18), min_size=1,
                                    max_size=3),
       st.integers(2, 5))
def test_chunked_engine_bit_identical_to_monolithic(chunk_tokens, plens,
                                                    gen):
    """Chunked prefill is bit-identical to the monolithic engine for ANY
    drawn (chunk_tokens, prompt_len) pair — page-aligned or not, final
    chunks partial or not.  Few examples (the engine compiles per pow2
    prefill bucket), but each drives the full dispatch path."""
    import sys
    sys.path.insert(0, "tests")
    import numpy as np
    from conftest import get_tiny_model, make_engine, seeded_prompts

    cfg, params = get_tiny_model()
    max_len = max(plens) + gen
    prompts = [seeded_prompts(cfg, 1, plen, seed=60 + i)[0]
               for i, plen in enumerate(plens)]

    def run(chunked):
        eng = make_engine(cfg, params, max_batch=2, page_size=4,
                          n_pages=48, max_len=max_len, fused=True,
                          max_window=4, chunked_prefill=chunked,
                          chunk_tokens=chunk_tokens)
        for i, (p, g) in enumerate(zip(prompts, [gen] * len(prompts))):
            eng.submit(np.asarray(p), g, rid=f"r{i}", slo="interactive")
        toks = {r.rid: list(r.tokens) for r in eng.run()}
        assert eng.alloc.pages_in_use == 0
        assert eng.alloc.check_conservation()
        return toks

    assert run(True) == run(False), (chunk_tokens, plens, gen)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=0, max_size=40),
       st.integers(1, 8))
def test_propose_ngram_drafts_are_history_slices(history, k):
    """A non-empty draft is always a verbatim slice of the history that
    follows an occurrence of the history's own tail n-gram."""
    d = propose_ngram(history, k, max_n=3)
    assert len(d) <= k
    if not d:
        return
    found = False
    for n in range(1, 4):
        if n >= len(history):
            break
        tail = list(history[-n:])
        for i in range(len(history) - n):
            if list(history[i:i + n]) == tail \
                    and list(history[i + n:i + n + k]) == d:
                found = True
    assert found


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=0, max_size=28),
       st.integers(0, 9), st.integers(1, 3),
       st.lists(st.integers(0, 6), min_size=0, max_size=4),
       st.data())
def test_device_propose_matches_host_proposer(history, k, max_n, junk,
                                              data):
    """The differential proposer oracle (docs/TESTING.md rung): the
    jitted :func:`device_propose` suffix match over a fixed-width,
    junk-padded device buffer is token-identical to the host reference
    :func:`propose_ngram` over the exact history — same
    longest-n-first, earliest-occurrence, end-of-history-clipped
    drafts, for looping, aperiodic, shorter-than-n and padding-adjacent
    histories alike.  The padding bytes beyond ``hist_len`` are drawn
    adversarially (including copies of the history's own tail, the case
    a missing validity mask would false-match)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.serving.spec_decode import device_propose

    H, k_max = 32, 9
    min_n = data.draw(st.integers(1, max_n))
    buf = np.zeros((H,), np.int32)
    buf[:len(history)] = history
    # adversarial tail padding right past hist_len: junk, then repeat
    # the history's own tail so clipped indices look like matches
    pad = junk + list(history[-3:])
    buf[len(history):len(history) + len(pad)] = pad[:H - len(history)]
    fn = jax.jit(device_propose, static_argnames=("k_max", "max_n",
                                                  "min_n"))
    draft, m = fn(jnp.asarray(buf), jnp.int32(len(history)),
                  jnp.int32(k), k_max=k_max, max_n=max_n, min_n=min_n)
    draft, m = np.asarray(draft), int(m)
    ref = propose_ngram(history, min(k, k_max), max_n=max_n, min_n=min_n)
    assert list(draft[:m]) == ref
    assert all(int(t) == 0 for t in draft[m:])   # zero-masked past m


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=8),
       st.lists(st.integers(0, 3), min_size=2, max_size=9))
def test_accept_rule_emits_exactly_the_greedy_tokens(draft, greedy):
    """accept() output == the greedy sequence up to and including the
    first divergence — never more, never different (this is the whole
    exactness argument for speculative decoding)."""
    if len(greedy) < len(draft) + 1:
        draft = draft[:len(greedy) - 1]
    spec = NGramSpec(k=8)
    out = spec.accept(draft, greedy)
    assert 1 <= len(out) <= len(draft) + 1
    assert out == [int(t) for t in greedy[:len(out)]]
    a = len(out) - 1
    assert draft[:a] == greedy[:a]
    if a < len(draft):
        assert draft[a] != greedy[a]


def test_hypothesis_shim_reports_presence():
    """Documentation breadcrumb: tier-1 runs these as SKIPPED without
    hypothesis; the tests-hypothesis CI job runs them for real."""
    assert HAVE_HYPOTHESIS in (True, False)
