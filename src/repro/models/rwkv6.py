"""RWKV-6 "Finch" layer: time-mix with data-dependent decay + channel-mix.

Time-mix recurrence per head (K = V = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent per-channel decay w_t = exp(-exp(dd_t)) and token-shift
low-rank interpolation for the five projections (w,k,v,r,g).

Implementations: ref = lax.scan over time; blocked = chunked algorithm with
exact log-space intra-chunk decays (scan over chunks of length L, inside
each chunk an (L,L,K) masked-decay product — bounded memory, no 1/A
overflow); pallas = same chunk math as a TPU kernel.

The layer bundles its own channel-mix (squared-ReLU with token shift), so
blocks.py treats kind=="rwkv6" as a complete layer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.parallel.sharding import logical_constraint

LORA_MIX = 32
LORA_DECAY = 64
CHUNK = 32


class RWKVCache(NamedTuple):
    state: jnp.ndarray    # (B, H, K, V) fp32 time-mix state
    x_tm: jnp.ndarray     # (B, D) previous token (time-mix shift)
    x_cm: jnp.ndarray     # (B, D) previous token (channel-mix shift)


def init(key, cfg, dtype):
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 14)
    scale_o = 1.0 / max(1, cfg.n_layers) ** 0.5
    p = {
        "rwkv_mix_x": jnp.zeros((d,), jnp.float32),
        "rwkv_mix_base": jnp.zeros((5, d), jnp.float32),
        "rwkv_mix_lora_a": (jax.random.normal(ks[0], (d, 5, LORA_MIX),
                                              jnp.float32) * d ** -0.5
                            ).astype(dtype),
        "rwkv_mix_lora_b": (jax.random.normal(ks[1], (5, LORA_MIX, d),
                                              jnp.float32) * LORA_MIX ** -0.5
                            ).astype(dtype),
        "rwkv_decay_base": jnp.full((d,), -1.0, jnp.float32),
        "rwkv_decay_lora_a": (jax.random.normal(ks[2], (d, LORA_DECAY),
                                                jnp.float32) * d ** -0.5
                              ).astype(dtype),
        "rwkv_decay_lora_b": (jax.random.normal(ks[3], (LORA_DECAY, d),
                                                jnp.float32)
                              * LORA_DECAY ** -0.5).astype(dtype),
        "rwkv_u": (jax.random.normal(ks[4], (H, hd), jnp.float32) * 0.1),
        "rwkv_wr": nn.dense_init(ks[5], d, d, dtype),
        "rwkv_wk": nn.dense_init(ks[6], d, d, dtype),
        "rwkv_wv": nn.dense_init(ks[7], d, d, dtype),
        "rwkv_wg": nn.dense_init(ks[8], d, d, dtype),
        "rwkv_wo": nn.dense_init(ks[9], d, d, dtype, scale=scale_o),
        "rwkv_ln_scale": jnp.ones((d,), jnp.float32),
        "rwkv_ln_bias": jnp.zeros((d,), jnp.float32),
        # channel-mix
        "rwkv_cm_mix_k": jnp.full((d,), 0.5, jnp.float32),
        "rwkv_cm_mix_r": jnp.full((d,), 0.5, jnp.float32),
        "rwkv_cm_wk": nn.dense_init(ks[10], d, cfg.d_ff, dtype),
        "rwkv_cm_wv": nn.dense_init(ks[11], cfg.d_ff, d, dtype, scale=scale_o),
        "rwkv_cm_wr": nn.dense_init(ks[12], d, d, dtype),
    }
    return p


def _shift(x, x_prev):
    """x (B,S,D); x_prev (B,D) -> previous-token tensor (B,S,D)."""
    return jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], 1)


def _time_mix_inputs(p, x, x_prev):
    """Token-shift interpolation -> (xw, xk, xv, xr, xg), each (B,S,D)."""
    sx = _shift(x, x_prev) - x
    xxx = x + sx * p["rwkv_mix_x"].astype(x.dtype)
    h = jnp.tanh(jnp.einsum("bsd,dfk->bsfk", xxx, p["rwkv_mix_lora_a"],
                            preferred_element_type=jnp.float32))
    deltas = jnp.einsum("bsfk,fkd->bsfd", h.astype(x.dtype),
                        p["rwkv_mix_lora_b"],
                        preferred_element_type=jnp.float32)
    mix = p["rwkv_mix_base"][None, None].astype(jnp.float32) + deltas
    outs = x.astype(jnp.float32)[:, :, None] \
        + sx.astype(jnp.float32)[:, :, None] * mix
    outs = outs.astype(x.dtype)
    return tuple(outs[:, :, i] for i in range(5))


def _decay(p, xw):
    """Per-channel log-decay lw = -exp(dd) (B,S,D) fp32; w = exp(lw)."""
    dd = p["rwkv_decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsd,dk,ke->bse", xw, p["rwkv_decay_lora_a"], p["rwkv_decay_lora_b"],
        preferred_element_type=jnp.float32).astype(jnp.float32)
    return -jnp.exp(dd)


def _group_norm(p, o, eps=64e-5):
    """Per-head layernorm on (B,S,H,hd), then (D,) scale/bias."""
    B, S, H, hd = o.shape
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    y = (o - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(B, S, H * hd)
    return y * p["rwkv_ln_scale"] + p["rwkv_ln_bias"]


# ---------------------------------------------------------------------------
# wkv recurrence
# ---------------------------------------------------------------------------
def _wkv_ref(r, k, v, lw, u, S0):
    """lax.scan oracle. r,k,v (B,S,H,K); lw (B,S,H,K) log decay; u (H,K);
    S0 (B,H,K,V). Returns (o (B,S,H,V), S_T)."""
    def step(S, inp):
        rt, kt, vt, lwt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt,
                        preferred_element_type=jnp.float32)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv,
                       preferred_element_type=jnp.float32)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, o
    seq = (jnp.moveaxis(r, 1, 0).astype(jnp.float32),
           jnp.moveaxis(k, 1, 0).astype(jnp.float32),
           jnp.moveaxis(v, 1, 0).astype(jnp.float32),
           jnp.moveaxis(lw, 1, 0))
    S_T, os = jax.lax.scan(step, S0, seq)
    return jnp.moveaxis(os, 0, 1), S_T


def _wkv_chunked(r, k, v, lw, u, S0, chunk=CHUNK):
    """Chunked algorithm, exact in fp32 log space.

    Within a chunk of length L (la = inclusive cumsum of lw):
      inter:  o_t += (r_t * exp(la_{t-1})) @ S0
      intra:  o_t += sum_{s<t} (sum_K r k exp(la_{t-1}-la_s)) v_s
      diag:   o_t += (r_t * u * k_t) @ v_t
      state:  S' = diag(exp(la_L)) S0 + sum_s (k_s exp(la_L - la_s))^T v_s
    All exponent differences are <= 0, so nothing overflows.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    while S % L:
        L -= 1
    n = S // L
    rf = r.astype(jnp.float32).reshape(B, n, L, H, K)
    kf = k.astype(jnp.float32).reshape(B, n, L, H, K)
    vf = v.astype(jnp.float32).reshape(B, n, L, H, V)
    lwf = lw.reshape(B, n, L, H, K)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)       # s < t

    def chunk_step(S0, inp):
        rc, kc, vc, lwc = inp                            # (B,L,H,*)
        la = jnp.cumsum(lwc, axis=1)                     # inclusive
        la_prev = la - lwc                               # la_{t-1}
        q_int = rc * jnp.exp(la_prev)                    # (B,L,H,K)
        o = jnp.einsum("blhk,bhkv->blhv", q_int, S0)
        # intra-chunk: exponent la_prev[t] - la[s], masked s<t.  The mask
        # must be applied to the EXPONENT (not the exp output): for s > t
        # the difference is positive and exp overflows, and inf * 0 in the
        # VJP of where() would poison the gradients with NaNs.
        diff = la_prev[:, :, None] - la[:, None]         # (B,L,L,H,K) t,s
        diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
        p = jnp.exp(diff)
        A = jnp.einsum("blhk,bmhk,blmhk->blmh", rc, kc, p)
        o = o + jnp.einsum("blmh,bmhv->blhv", A, vc)
        # current-token bonus
        du = jnp.einsum("blhk,blhk->blh", rc, u[None, None] * kc)
        o = o + du[..., None] * vc
        # state update
        la_L = la[:, -1]                                 # (B,H,K)
        k_dec = kc * jnp.exp(la_L[:, None] - la)
        S1 = jnp.exp(la_L)[..., None] * S0 + jnp.einsum(
            "blhk,blhv->bhkv", k_dec, vc)
        return S1, o

    seq = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
           jnp.moveaxis(vf, 1, 0), jnp.moveaxis(lwf, 1, 0))
    S_T, os = jax.lax.scan(chunk_step, S0, seq)          # os (n,B,L,H,V)
    o = jnp.moveaxis(os, 0, 1).reshape(B, S, H, V)
    return o, S_T


# ---------------------------------------------------------------------------
# layer entry points
# ---------------------------------------------------------------------------
def time_mix(p, cfg, x, cache: RWKVCache, *, impl=None):
    impl = impl or cfg.impl
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xw, xk, xv, xr, xg = _time_mix_inputs(p, x, cache.x_tm)
    r = nn.matmul(xr, p["rwkv_wr"]).reshape(B, S, H, hd)
    k = nn.matmul(xk, p["rwkv_wk"]).reshape(B, S, H, hd)
    v = nn.matmul(xv, p["rwkv_wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(nn.matmul(xg, p["rwkv_wg"]))
    lw = _decay(p, xw).reshape(B, S, H, hd)
    r = logical_constraint(r, "batch", None, "heads", None)
    k = logical_constraint(k, "batch", None, "heads", None)
    v = logical_constraint(v, "batch", None, "heads", None)
    u = p["rwkv_u"].astype(jnp.float32)
    if impl == "ref":
        o, S_T = _wkv_ref(r, k, v, lw, u, cache.state)
    elif impl == "blocked":
        o, S_T = _wkv_chunked(r, k, v, lw, u, cache.state)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        o, S_T = kops.rwkv6_scan(r, k, v, lw, u, cache.state)
    else:
        raise ValueError(impl)
    o = _group_norm(p, o.astype(jnp.float32)).astype(x.dtype)
    from repro.parallel.collectives import row_parallel
    out = row_parallel(o * g, p["rwkv_wo"])
    return out, RWKVCache(state=S_T, x_tm=x[:, -1], x_cm=cache.x_cm)


def channel_mix(p, cfg, x, cache: RWKVCache):
    sx = _shift(x, cache.x_cm) - x
    xk = x + sx * p["rwkv_cm_mix_k"].astype(x.dtype)
    xr = x + sx * p["rwkv_cm_mix_r"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(nn.matmul(xk, p["rwkv_cm_wk"])))
    h = logical_constraint(h, "batch", None, "ffn")
    from repro.parallel.collectives import row_parallel
    out = jax.nn.sigmoid(nn.matmul(xr, p["rwkv_cm_wr"])) \
        * row_parallel(h, p["rwkv_cm_wv"])
    return out, RWKVCache(state=cache.state, x_tm=cache.x_tm, x_cm=x[:, -1])


def cache_init(cfg, batch: int, dtype):
    return RWKVCache(
        state=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                        jnp.float32),
        x_tm=jnp.zeros((batch, cfg.d_model), dtype),
        x_cm=jnp.zeros((batch, cfg.d_model), dtype))
