"""Pallas kernel allclose sweeps vs ref.py oracles (deliverable c).

Every kernel is swept over shapes and dtypes in interpret mode (the
kernel body executes with jnp semantics on CPU; on TPU the same tiling
lowers natively)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(11)


def _rand(shape, dtype, k):
    x = jax.random.normal(k, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 2, 64), (2, 256, 4, 64),
                                   (1, 192, 3, 128)])
@pytest.mark.parametrize("mode", ["causal", "window", "bidir", "softcap"])
def test_flash_attention_sweep(shape, dtype, mode):
    B, S, H, hd = shape
    ks = jax.random.split(KEY, 3)
    q = _rand(shape, dtype, ks[0])
    k = _rand(shape, dtype, ks[1])
    v = _rand(shape, dtype, ks[2])
    kw = dict(causal=mode != "bidir",
              window=64 if mode == "window" else None,
              softcap=30.0 if mode == "softcap" else None)
    o_ref = ref.flash_attention(q, k, v, **kw)
    o = ops.flash_attention(q, k, v, block_q=64, block_kv=64, **kw)
    err = jnp.abs(o.astype(jnp.float32) - o_ref.astype(jnp.float32)).max()
    assert err < _tol(dtype), (shape, dtype, mode, float(err))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,Kv,G,pos", [(128, 2, 4, 17), (256, 1, 8, 255),
                                        (192, 4, 1, 100)])
def test_decode_attention_sweep(T, Kv, G, pos, dtype):
    B, hd = 2, 64
    H = Kv * G
    ks = jax.random.split(KEY, 3)
    q = _rand((B, H, hd), dtype, ks[0])
    k = _rand((B, T, Kv, hd), dtype, ks[1])
    v = _rand((B, T, Kv, hd), dtype, ks[2])
    o_ref = ref.decode_attention(q, k, v, pos)
    o = ops.decode_attention(q, k, v, jnp.int32(pos), block_t=64)
    err = jnp.abs(o.astype(jnp.float32) - o_ref.astype(jnp.float32)).max()
    assert err < _tol(dtype), float(err)


@pytest.mark.parametrize("S,W", [(64, 128), (256, 256), (128, 512)])
def test_rglru_scan_sweep(S, W):
    B = 2
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    hs_r, hT_r = ref.rglru_scan(a, b, h0)
    hs, hT = ops.rglru_scan(a, b, h0)
    assert jnp.abs(hs - hs_r).max() < 1e-5
    assert jnp.abs(hT - hT_r).max() < 1e-5


@pytest.mark.parametrize("S,H,K,chunk", [(64, 2, 32, 16), (128, 1, 64, 32),
                                         (96, 3, 16, 32)])
def test_rwkv6_scan_sweep(S, H, K, chunk):
    B = 2
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 1.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    S0 = jax.random.normal(ks[5], (B, H, K, K)).astype(jnp.float32)
    o_r, s_r = ref.rwkv6_scan(r, k, v, lw, u, S0)
    o, s = ops.rwkv6_scan(r, k, v, lw, u, S0, chunk=chunk)
    assert jnp.abs(o - o_r).max() < 2e-3, float(jnp.abs(o - o_r).max())
    assert jnp.abs(s - s_r).max() < 2e-3


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(2, 64, 128, 256), (4, 32, 256, 128)])
def test_moe_gemm_sweep(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 2)
    x = _rand((E, C, D), dtype, ks[0])
    w = _rand((E, D, F), dtype, ks[1])
    o_ref = ref.moe_gemm(x, w)
    o = ops.moe_gemm(x, w, block_c=32, block_f=128, block_d=64)
    rel = (jnp.abs(o.astype(jnp.float32) - o_ref.astype(jnp.float32)).max()
           / jnp.abs(o_ref.astype(jnp.float32)).max())
    assert rel < (3e-2 if dtype == jnp.bfloat16 else 1e-5), float(rel)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,D", [(64, 128), (96, 256)])
def test_rmsnorm_sweep(N, D, dtype):
    ks = jax.random.split(KEY, 2)
    x = _rand((N, D), dtype, ks[0])
    s = jax.random.normal(ks[1], (D,))
    o_ref = ref.rmsnorm(x, s)
    o = ops.rmsnorm(x, s, block_rows=32)
    err = jnp.abs(o.astype(jnp.float32) - o_ref.astype(jnp.float32)).max()
    assert err < _tol(dtype)


def test_model_pallas_impl_matches_blocked():
    """The full model gives the same loss under impl=pallas vs blocked."""
    from conftest import make_batch
    from repro.configs import get_tiny_config
    from repro.models import lm
    for arch in ("qwen3-14b", "recurrentgemma-2b", "rwkv6-1.6b"):
        cfg = get_tiny_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        l_b, _ = lm.loss_fn(params, cfg.replace(impl="blocked"), batch)
        l_p, _ = lm.loss_fn(params, cfg.replace(impl="pallas"), batch)
        assert abs(float(l_b) - float(l_p)) < 5e-3, (arch, l_b, l_p)
