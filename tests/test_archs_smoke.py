"""Per-arch smoke tests (deliverable f): reduced same-family configs run
one forward/train step on CPU; output shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ASSIGNED_ARCHS, get_config, get_tiny_config
from repro.models import lm


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), arch
    assert loss.shape == ()
    grads = jax.jit(jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0]))(params)
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes(arch):
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    B, S = batch["labels"].shape
    h, caches, aux = lm.forward(params, cfg, batch["tokens"], mode="train",
                                positions=batch.get("positions"))
    assert h.shape == (B, S, cfg.d_model)
    assert caches is None
    logits = lm.head_logits(params, cfg, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).supports_decode])
def test_prefill_decode_smoke(arch):
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B=2, S=32)
    logits, caches = jax.jit(
        lambda p, t: lm.prefill(p, cfg, t, max_len=40))(
        params, batch["tokens"])
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    if cfg.embed_inputs:
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    else:
        nxt = jnp.zeros((2, 1, cfg.d_model), jnp.float32)
    logits2, caches2 = jax.jit(
        lambda p, t, c: lm.decode_step(p, cfg, t, c, 32))(params, nxt, caches)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits2).all()


def test_assigned_arch_configs_exact():
    """The full configs must match the assignment card exactly."""
    expect = {
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }
    for arch, (L, d, H, kv, ff, V) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V), arch


def test_moe_configs():
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.n_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.n_shared == 1 and ds.mla is not None
    assert ds.mtp_depth == 1 and ds.first_k_dense == 3
    gk = get_config("grok-1-314b")
    assert gk.moe.n_experts == 8 and gk.moe.top_k == 2


def test_param_counts_sane():
    # within 6% of the nominal sizes
    approx = {"qwen3-14b": 14.8e9, "minitron-8b": 8e9, "qwen3-1.7b": 1.7e9,
              "gemma2-27b": 27.2e9, "qwen2-vl-7b": 7.6e9,
              "recurrentgemma-2b": 2.7e9, "grok-1-314b": 314e9,
              "rwkv6-1.6b": 1.6e9, "hubert-xlarge": 1e9}
    for arch, n in approx.items():
        got = get_config(arch).n_params()
        assert abs(got - n) / n < 0.12, (arch, got, n)
    ds = get_config("deepseek-v3-671b")
    assert abs(ds.n_params() - 682e9) / 682e9 < 0.05
    assert ds.n_active_params() < 60e9  # sparse activation
