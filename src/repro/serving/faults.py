"""Swallow §VIII's operating condition made testable: a deterministic
fault plane for the paged serving engine.

At 480 cores, node and link failure is routine, not exceptional — nOS
already models it for training placement (``core/nos.py::fail_rows``)
and the runtime ships pure-state-machine detectors
(:mod:`repro.runtime.health`).  This module gives the *serving* stack
the same story, deterministically: a :class:`FaultPlan` is a seeded,
replayable schedule of fault events on the scheduler's step clock —
node failures (a stripe of the §X-B DSM goes dark), transient dispatch
errors (an admission bounces and retries under capped exponential
backoff), and straggler slowdowns (a node's step durations inflate
until the detector evicts it) — and a :class:`FaultPlane` is the
watchdog that wires the plan through ``HeartbeatMonitor`` and
``StragglerDetector`` into :meth:`repro.serving.engine.PagedEngine
.fail_node` / ``join_node``.

Everything runs on the deterministic step clock (the detectors take
explicit ``now`` timestamps), so a chaos run is exactly reproducible:
same seed, same fault schedule, same detection steps, same recoveries —
which is what lets the chaos harness pin surviving requests
bit-identical to a fault-free run (greedy recompute is exact).

Pure host-side logic: no jax imports.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Set

import numpy as np

from repro.runtime.health import HeartbeatMonitor, StragglerDetector
from repro.serving.telemetry import MetricsRegistry, counter_attr

KINDS = ("fail", "join", "slow", "transient")


@dataclass(frozen=True)
class FaultEvent:
    """One planned fault on the scheduler step clock.

    ``fail``/``join`` toggle a node's liveness (it stops/resumes
    heartbeating); ``slow`` inflates the node's observed step durations
    by ``factor`` for ``duration`` steps; ``transient`` makes ``count``
    admission dispatches bounce from ``step`` onward."""
    step: int
    kind: str
    node: int = -1
    count: int = 1          # transient: rejection tokens made available
    duration: int = 0       # slow: steps the slowdown lasts
    factor: float = 3.0     # slow: per-step duration multiplier

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.step < 0:
            raise ValueError("fault steps are >= 0 (relative to arming)")


@dataclass
class FaultPlan:
    """A replayable fault schedule.  Steps are relative to the plane's
    arming point (the engine installs the plan *after* warmup, so warmup
    steps never consume events)."""
    events: List[FaultEvent] = field(default_factory=list)

    def __post_init__(self):
        self.events = sorted(self.events,
                             key=lambda e: (e.step, KINDS.index(e.kind),
                                            e.node))

    # -- queries (all pure; the watchdog polls them per step) --------------
    def alive(self, node: int, step: int) -> bool:
        """Liveness under the fail/join toggles through ``step``."""
        state = True
        for ev in self.events:
            if ev.step > step:
                break
            if ev.node != node:
                continue
            if ev.kind == "fail":
                state = False
            elif ev.kind == "join":
                state = True
        return state

    def slow_factor(self, node: int, step: int) -> float:
        """Duration multiplier for the node at ``step`` (1.0 = nominal)."""
        f = 1.0
        for ev in self.events:
            if ev.step > step:
                break
            if ev.kind == "slow" and ev.node == node \
                    and step < ev.step + ev.duration:
                f = max(f, ev.factor)
        return f

    def transients_through(self, step: int) -> int:
        """Total transient-rejection tokens made available by ``step``."""
        return sum(ev.count for ev in self.events
                   if ev.kind == "transient" and ev.step <= step)

    @property
    def n_node_failures(self) -> int:
        return sum(1 for ev in self.events if ev.kind == "fail")

    @property
    def horizon(self) -> int:
        """Last step any event (or slow window) is active."""
        h = 0
        for ev in self.events:
            h = max(h, ev.step + (ev.duration if ev.kind == "slow" else 0))
        return h

    @classmethod
    def seeded(cls, seed: int, *, n_nodes: int, horizon: int,
               n_fails: int = 2, n_transients: int = 2,
               n_slow: int = 1, slow_factor: float = 4.0) -> "FaultPlan":
        """Draw a deterministic chaos schedule.  Node 0 never fails —
        the pool always keeps at least one healthy stripe, so the run
        degrades instead of dying — and each failed node re-joins before
        the horizon so elastic re-join is exercised too.  Fail windows
        land on distinct nodes round-robin (a node is never double-failed
        while already down)."""
        if n_nodes < 2 and (n_fails or n_slow):
            raise ValueError("need n_nodes >= 2 to fail or slow a node "
                             "while keeping node 0 healthy")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        span = max(horizon, 8 * (n_fails + 1))
        for i in range(n_fails):
            node = 1 + i % (n_nodes - 1)
            at = span * (i + 1) // (n_fails + 2) \
                + int(rng.integers(0, max(span // 8, 1)))
            down = 3 + int(rng.integers(0, max(span // 6, 1)))
            events.append(FaultEvent(at, "fail", node))
            events.append(FaultEvent(at + down, "join", node))
        for _ in range(n_transients):
            at = int(rng.integers(1, max(span // 2, 2)))
            events.append(FaultEvent(at, "transient",
                                     count=1 + int(rng.integers(0, 2))))
        for i in range(n_slow):
            node = 1 + int(rng.integers(0, n_nodes - 1))
            at = int(rng.integers(1, max(span // 2, 2)))
            dur = 6 + int(rng.integers(0, max(span // 6, 1)))
            events.append(FaultEvent(at, "slow", node, duration=dur,
                                     factor=slow_factor))
        return cls(events)


class FaultPlane:
    """The watchdog: polls the plan each engine step, feeds the health
    detectors on the deterministic step clock, and drives
    ``engine.fail_node`` / ``engine.join_node``.

    Detection is honest, not oracular: a killed node is failed only
    after ``heartbeat_steps`` of missed beats, and a straggler only
    after ``straggler_patience`` consecutive over-ratio observations —
    the same state machines a wall-clock deployment would run, just fed
    synthetic observations derived from the plan."""

    # registry-backed (the engine's registry when installed through
    # PagedEngine.install_faults, so a warmup reset covers it)
    _transients_used = counter_attr("fault_transients_used")

    def __init__(self, plan: FaultPlan, n_nodes: int, *,
                 epoch: int = 0, heartbeat_steps: float = 2.0,
                 straggler_ratio: float = 1.5, straggler_patience: int = 2,
                 base_step_s: float = 1.0,
                 registry: MetricsRegistry = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.plan = plan
        self.n_nodes = n_nodes
        self.epoch = epoch            # plan step 0 == scheduler step epoch
        self.base_step_s = base_step_s
        names = [str(i) for i in range(n_nodes)]
        self.hb = HeartbeatMonitor(names, timeout_s=float(heartbeat_steps))
        self.sd = StragglerDetector(names, ratio=straggler_ratio,
                                    patience=straggler_patience)
        self.down: Set[int] = set()   # nodes the engine currently holds out
        self._transients_used = 0
        for n in names:
            self.hb.beat(n, 0.0)      # rebase heartbeats onto the step clock

    # the scheduler calls this per admission attempt (Request, step_idx)
    def transient_gate(self, req, step: int) -> bool:
        avail = self.plan.transients_through(step - self.epoch)
        if self._transients_used < avail:
            self._transients_used += 1
            return True
        return False

    def on_step(self, eng) -> None:
        """One watchdog tick: beats for alive nodes, heartbeat timeout
        check, straggler observation over the healthy cohort, then
        fail/join transitions on the engine."""
        rel = eng.sched.step_idx - self.epoch
        now = float(rel)
        for i in range(self.n_nodes):
            if self.plan.alive(i, rel):
                self.hb.beat(str(i), now)
        newly = {int(n) for n in self.hb.check(now)}
        durations = {str(i): self.base_step_s * self.plan.slow_factor(i, rel)
                     for i in range(self.n_nodes)
                     if i not in self.down and str(i) not in self.hb.failed}
        evicted: Set[int] = set()
        if len(durations) >= 2:
            evicted = {int(n) for n in self.sd.observe(durations)}
        for node in sorted(newly | evicted):
            if node not in self.down:
                self.down.add(node)
                eng.fail_node(node)
        for node in sorted(self.down - newly - evicted):
            if str(node) in self.hb.failed:
                continue              # still missing heartbeats
            if self.plan.alive(node, rel) \
                    and self.plan.slow_factor(node, rel) <= 1.0:
                self.down.discard(node)
                eng.join_node(node)

    def summary(self) -> dict:
        return {
            "events": len(self.plan.events),
            "planned_failures": self.plan.n_node_failures,
            "transients_used": self._transients_used,
            "nodes_down": sorted(self.down),
        }
