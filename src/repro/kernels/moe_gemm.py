"""Pallas TPU grouped GEMM for MoE expert FFNs.

x (E, C, D) @ w (E, D, F) -> (E, C, F): grid (E, nC, nF, nD) with the
contraction dim innermost and an fp32 accumulator tile in VMEM.  Tiles
default to (128, 512, 512) — MXU-aligned, ~1.3 MB working set.  The
expert dim rides the grid so no capacity-sized HBM copies are made
per expert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _fin():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def moe_gemm(x, w, *, block_c=128, block_f=512, block_d=512,
             interpret=True):
    """x (E,C,D) @ w (E,D,F) -> (E,C,F) with fp32 accumulation."""
    E, C, D = x.shape
    F = w.shape[-1]
    bc = min(block_c, C)
    while C % bc:
        bc -= 1
    bf = min(block_f, F)
    while F % bf:
        bf -= 1
    bd = min(block_d, D)
    while D % bd:
        bd -= 1
    nc, nf, nd = C // bc, F // bf, D // bd

    kernel = functools.partial(_mm_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
