"""Swallow §III-A + §X-B: the KV cache as a striped distributed store.

What is reproduced: the paper's "more elegant strategy" — an address
space striped ``address % n`` over per-node controllers — applied to KV
pages.  Physical page ``p`` is owned by node ``striped_owner(p, n)``
(:mod:`repro.core.memory_server` is the single source of truth for the
mapping), and the allocator hands a request's *logical* page ``j`` a
physical page on node ``j % n`` whenever one is free, so a sequence's
cache reads fan out over the mesh exactly like the paper's memory-server
traffic instead of hammering one contention point.

What is extrapolated: Swallow stores 32-bit words; here a "word" is a
(page_size, Kv*hd) KV page and the striping axis is the mesh "model"
dimension the pools are sharded over.  Page 0 is reserved as the null
page — padded block-table slots point at it so the paged attention
kernel always DMAs a real page and masks its contribution to exactly 0.

Sharing (§X-B's shared-memory overlay made real): every allocated page
carries a refcount.  A freshly allocated page has refcount 1 (its
owner's reference); :meth:`PageAllocator.share` adds a reference (a
prefix-cache node, or a second request reusing a cached prefix) and
:meth:`PageAllocator.release_page` drops one — the page returns to the
free list only at refcount 0, so shared pages survive their original
owner's completion or preemption.  The null page is never shared and
never refcounted.  ``reclaim`` is an optional callback (wired to
:meth:`repro.serving.prefix_cache.PrefixCache.evict`) invoked when the
free list runs short, so cold cache pages are evicted before any tenant
is preempted.

Pure host-side logic: no jax imports, unit-testable anywhere.  The
device-side half (pools + block tables) lives in
:mod:`repro.serving.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.memory_server import striped_owner

NULL_PAGE = 0


@dataclass
class PageAllocator:
    """Fixed-size-page allocator over a striped pool.

    ``n_pages`` counts physical pages including the reserved null page;
    ``n_nodes`` is the striping width (mesh "model" extent).
    """
    n_pages: int
    page_size: int
    n_nodes: int = 1
    held: Dict[str, List[int]] = field(default_factory=dict)
    refcount: Dict[int, int] = field(default_factory=dict)
    reclaim: Optional[Callable[[int], int]] = None
    _free_by_node: List[List[int]] = field(default_factory=list)

    def __post_init__(self):
        assert self.n_pages > 1, "need at least one page beyond the null page"
        self._free_by_node = [[] for _ in range(self.n_nodes)]
        # LIFO free lists per owner node; page 0 is never handed out
        for p in range(self.n_pages - 1, NULL_PAGE, -1):
            self._free_by_node[self.owner(p)].append(p)

    # -- the striping rule (one source of truth) ---------------------------
    def owner(self, page: int) -> int:
        """Node owning physical ``page`` — delegates to the paper's
        address%n rule in core/memory_server."""
        return striped_owner(page, self.n_nodes)

    # -- accounting --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free_by_node)

    @property
    def pages_in_use(self) -> int:
        """Distinct allocated pages — a page shared by N requests and the
        prefix cache counts once (refcount, not held-list, is truth)."""
        return len(self.refcount)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def refcount_of(self, page: int) -> int:
        return self.refcount.get(page, 0)

    def occupancy_by_node(self) -> List[int]:
        """Allocated pages per owner node (load-balance observable).
        Shared pages count once — this is physical occupancy."""
        counts = [0] * self.n_nodes
        for p in self.refcount:
            counts[self.owner(p)] += 1
        return counts

    def check_conservation(self) -> bool:
        """Every non-null page is on exactly one side: free list (refcount
        0) or allocated (refcount >= 1)."""
        free = [p for f in self._free_by_node for p in f]
        if len(free) != len(set(free)):
            return False
        if set(free) & set(self.refcount):
            return False
        if NULL_PAGE in self.refcount or NULL_PAGE in free:
            return False
        if any(c < 1 for c in self.refcount.values()):
            return False
        return len(free) + len(self.refcount) == self.n_pages - 1

    # -- sharing (refcounts) ----------------------------------------------
    def share(self, page: int) -> None:
        """Add a reference to an allocated page (prefix-cache node or a
        second request reusing it).  The null page is never shared."""
        if page == NULL_PAGE:
            raise ValueError("the null page cannot be shared")
        if self.refcount.get(page, 0) < 1:
            raise ValueError(f"page {page} is not allocated; cannot share")
        self.refcount[page] += 1

    def release_page(self, page: int) -> bool:
        """Drop one reference; free the page at refcount 0.  Returns True
        when the page actually returned to the free list.  Releasing an
        unallocated page is a double free and raises."""
        c = self.refcount.get(page, 0)
        if c < 1:
            raise ValueError(f"double free of page {page}")
        if c == 1:
            del self.refcount[page]
            self._free_by_node[self.owner(page)].append(page)
            return True
        self.refcount[page] = c - 1
        return False

    # -- alloc / grow / free ----------------------------------------------
    def _take(self, want_node: int) -> Optional[int]:
        """Pop a free page on ``want_node``, falling back to the richest
        node (work-conserving when the stripe is fragmented)."""
        if self._free_by_node[want_node]:
            return self._free_by_node[want_node].pop()
        best = max(range(self.n_nodes),
                   key=lambda n: len(self._free_by_node[n]))
        if self._free_by_node[best]:
            return self._free_by_node[best].pop()
        return None

    def _ensure(self, n: int) -> None:
        """Ask the reclaimer (prefix-cache LRU eviction) for pages when
        the free list cannot cover ``n`` — cold cache pages go before any
        tenant is preempted."""
        if n > self.free_pages and self.reclaim is not None:
            self.reclaim(n - self.free_pages)

    def alloc(self, rid: str, n: int,
              prefix: Optional[Sequence[int]] = None) -> Optional[List[int]]:
        """All-or-nothing: ``n`` *fresh* pages for ``rid``.  ``prefix``
        is an already-shared page run (refcounts bumped by the caller via
        the prefix cache) that fills logical pages 0..len(prefix)-1, so
        fresh logical page j lands on node (len(prefix)+j) % n_nodes.
        Returns the full page list (prefix + fresh) or None."""
        if rid in self.held:
            return None
        self._ensure(n)
        if n > self.free_pages:
            return None
        off = len(prefix) if prefix else 0
        pages = list(prefix) if prefix else []
        for j in range(n):
            p = self._take(striped_owner(off + j, self.n_nodes))
            assert p is not None
            self.refcount[p] = 1
            pages.append(p)
        self.held[rid] = pages
        return pages

    def grow(self, rid: str, n: int = 1) -> bool:
        """Append ``n`` pages to an existing allocation (decode crossing
        a page boundary)."""
        self._ensure(n)
        if n > self.free_pages:
            return False
        pages = self.held[rid]
        for _ in range(n):
            p = self._take(striped_owner(len(pages), self.n_nodes))
            assert p is not None
            self.refcount[p] = 1
            pages.append(p)
        return True

    def reserve(self, rid: str, n_tokens: int) -> int:
        """Horizon pre-reservation: grow ``rid`` (best-effort under page
        pressure) until its pages cover every write position below
        ``n_tokens``, so the block-table row is fixed for a whole fused
        decode window.  Returns the token capacity actually reserved —
        the caller shrinks the window to ``capacity - pos`` when the
        pool runs dry instead of preempting mid-window."""
        need = self.pages_for(n_tokens)
        while len(self.held[rid]) < need and self.grow(rid):
            pass
        return len(self.held[rid]) * self.page_size

    def truncate_to(self, rid: str, n_tokens: int) -> int:
        """Speculative rollback: shrink ``rid``'s allocation to exactly
        the pages covering token positions below ``n_tokens`` (whole
        rejected/over-reserved tail pages are released).  Only this
        request's references are dropped — a tail page another holder
        shares survives via its refcount (``release_page`` semantics),
        and the null page is never involved because it is never held.
        KV slots past ``n_tokens`` inside the *kept* tail page are not
        wiped: they are masked by position and overwritten before the
        sequence's write position ever reaches them (the same argument
        as COW page copies).  Returns how many pages actually returned
        to the free list."""
        pages = self.held[rid]
        keep = -(-max(n_tokens, 0) // self.page_size)
        freed = 0
        while len(pages) > keep:
            if self.release_page(pages.pop()):
                freed += 1
        return freed

    def free(self, rid: str) -> int:
        """Release every reference ``rid`` holds; returns how many pages
        actually returned to the free list (shared pages survive until
        their last reference — the prefix cache's or another request's —
        is dropped)."""
        pages = self.held.pop(rid, [])
        freed = 0
        for p in pages:
            if self.release_page(p):
                freed += 1
        return freed
