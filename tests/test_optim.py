"""Optimizer + quantization + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data import pipeline as data_lib
from repro.optim import adam as adam_lib, quant


def test_adam_converges_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = adam_lib.AdamConfig(weight_decay=0.0)
    state = adam_lib.init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adam_lib.update(g, state, params, lr=0.05,
                                           cfg=cfg)
    assert loss(params) < 1e-3


@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
def test_adam_low_precision_states_still_converge(dtype):
    target = jnp.linspace(-1, 1, 64)
    params = {"w": jnp.zeros(64)}
    cfg = adam_lib.AdamConfig(weight_decay=0.0, state_dtype=dtype)
    state = adam_lib.init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(400):
        g = jax.grad(loss)(params)
        params, state, _ = adam_lib.update(g, state, params, lr=0.05,
                                           cfg=cfg)
    assert loss(params) < 5e-2, float(loss(params))


def test_clip_norm():
    params = {"w": jnp.zeros(4)}
    cfg = adam_lib.AdamConfig(clip_norm=1.0, weight_decay=0.0)
    state = adam_lib.init(params, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adam_lib.update(g, state, params, lr=0.1, cfg=cfg)
    assert m["grad_norm"] > 100  # reported pre-clip


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(1, 5000),
       scale=st.floats(1e-4, 1e4))
def test_quant_roundtrip_bounded(seed, n, scale):
    x = np.random.default_rng(seed).normal(size=n).astype(np.float32) * scale
    qt = quant.quantize(jnp.asarray(x))
    back = np.asarray(quant.dequantize(qt))
    # blockwise absmax int8: error < absmax/127 per block
    xb = np.pad(x, (0, (-n) % quant.BLOCK)).reshape(-1, quant.BLOCK)
    bound = np.abs(xb).max(1, keepdims=True) / 127.0 * 0.5001 + 1e-9
    err = np.abs(np.pad(back - x, (0, (-n) % quant.BLOCK)).reshape(
        -1, quant.BLOCK))
    assert (err <= bound + 1e-6).all()


def test_quant_sqrt_encoding_nonneg():
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (1000,))) ** 2
    qt = quant.quantize(v, sqrt_encode=True)
    back = quant.dequantize(qt)
    assert (back >= 0).all()
    assert jnp.abs(back - v).max() / v.max() < 0.05


def test_flat_blocks_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 7, 11))
    xb = quant.flatten_blocks(x)
    assert xb.shape[0] % quant.MAX_SHARDS == 0
    back = quant.unflatten_blocks(xb, x.shape)
    assert jnp.array_equal(back, x)


def test_warmup_cosine():
    lrs = [float(adam_lib.warmup_cosine(jnp.asarray(s), peak_lr=1.0,
                                        warmup=10, total=100))
           for s in range(0, 100, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) <= 1.0
    assert lrs[-1] < 0.2


# --- data pipeline ------------------------------------------------------------
def test_data_deterministic_and_restartable():
    cfg = data_lib.DataConfig(vocab_size=1000, seq_len=32, global_batch=4,
                              seed=3)
    src = data_lib.make_source(cfg)
    b1 = src.batch(17)
    b2 = src.batch(17)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # pure in (seed, step)
    b3 = src.batch(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_prefetcher_orders_batches():
    cfg = data_lib.DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    src = data_lib.make_source(cfg)
    pf = data_lib.Prefetcher(src, start_step=5)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_gradient_compression_wire_model():
    from repro.optim import compress
    full = compress.wire_bytes(10 ** 6, 16, "fp32")
    c8 = compress.wire_bytes(10 ** 6, 16, "int8_ef")
    assert full / c8 > 3.5  # ~4x reduction
