"""Pallas TPU RWKV-6 wkv kernel — chunked linear attention with
data-dependent per-channel decay.

Grid (B, H, n_chunks), chunks sequential with the (K, V) state in VMEM
scratch.  Per chunk (length L): cumulative log-decays, the inter-chunk
term q~ @ S, an (L, L) masked intra-chunk product (exact log-space — all
exponent differences <= 0), the current-token bonus, and the state
update.  L = 32/64 keeps every tile square-MXU friendly and the whole
working set (~6 (L,K) tiles + (K,V) state) far under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 o_ref, sT_ref, s_ref, *, L, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)     # (L, K)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (K,)

    la = jnp.cumsum(lw, axis=0)                # (L, K) inclusive
    la_prev = la - lw
    S0 = s_ref[...]                            # (K, V)

    q_int = r * jnp.exp(la_prev)
    o = jax.lax.dot_general(q_int, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, V)

    # intra-chunk: A[t,s] = sum_K r_t k_s exp(la_prev_t - la_s), s < t
    diff = la_prev[:, None, :] - la[None, :, :]          # (L, L, K)
    mask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # mask the exponent (exp overflows for s > t; inf*0 => NaN in VJPs)
    p = jnp.exp(jnp.where(mask[..., None], diff, -jnp.inf))
    A = jnp.sum(r[:, None, :] * k[None, :, :] * p, axis=-1)  # (L, L)
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # current-token bonus
    du = jnp.sum(r * (u[None, :] * k), axis=-1)          # (L,)
    o = o + du[:, None] * v
    o_ref[0, :, 0] = o.astype(o_ref.dtype)

    # state update
    la_L = la[-1]                                        # (K,)
    k_dec = k * jnp.exp(la_L[None, :] - la)
    s_ref[...] = jnp.exp(la_L)[:, None] * S0 + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _fin():
        sT_ref[0, 0] = s_ref[...]


def rwkv6_scan(r, k, v, lw, u, S0, *, chunk=32, interpret=True):
    """r,k,v,lw (B,S,H,K); u (H,K); S0 (B,H,K,V) fp32.

    Returns (o (B,S,H,V) fp32, S_T (B,H,K,V) fp32).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L

    kernel = functools.partial(_rwkv_kernel, L=L, nc=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, L, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1, V), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, V), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, V), jnp.float32),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u, S0)
    return o, sT
