"""Swallow §V-B/C: link model — packet vs circuit switching, aggregation.

Paper ground truth:
  token = 8 bits as 2-bit symbols; transmit time 3*Ts + Tt switch cycles;
  fastest (Ts=2, Tt=1) -> 500 Mbit/s per internal link @500 MHz, external
  links 4x slower (125 Mbit/s).  Packetized transfer adds a 3-byte route
  header + control token -> ~435 Mbit/s effective; circuit switching holds
  links open and reaches the full 500 Mbit/s.
  Latencies: core-local 50 ns (~6 instr), intra-package 32-bit word =
  40 instr, package-to-package 360 ns (45 instr).

TPU adaptation: "packet" = on-demand GSPMD resharding (header/setup ==
fresh collective schedule + latency-bound small transfers); "circuit" =
persistent compiler-scheduled ring collectives (links held by the
program; zero per-step setup).  ``CollectiveCost`` prices a collective on
either model so benchmarks can show the circuit/packet gap the paper
measures (500 vs 435 Mbit/s -> here: bandwidth-bound vs latency-bound).
"""
from __future__ import annotations

from dataclasses import dataclass

# --- paper link model --------------------------------------------------------
SWITCH_HZ = 500e6


def token_time_s(ts: int = 2, tt: int = 1, hz: float = SWITCH_HZ) -> float:
    """8-bit token transmit time = (3*Ts + Tt) + 1 switch cycles.

    The +1 sync cycle reconciles the formula with the paper's quoted
    500 Mbit/s at (Ts=2, Tt=1, 500 MHz): 8 cycles per 8-bit token.
    """
    return (3 * ts + tt + 1) / hz


def link_rate_bps(ts: int = 2, tt: int = 1, hz: float = SWITCH_HZ) -> float:
    return 8.0 / token_time_s(ts, tt, hz)


def packet_rate_bps(payload_bytes: int, ts: int = 2, tt: int = 1,
                    hz: float = SWITCH_HZ) -> float:
    """Effective rate with 3-byte header + 1 control token per packet."""
    raw = link_rate_bps(ts, tt, hz)
    overhead = 4.0  # bytes
    return raw * payload_bytes / (payload_bytes + overhead)


SWALLOW_LATENCY = {
    "core_local_s": 50e-9,
    "intra_package_word_s": 360e-9 * 40 / 45,   # 40 instr @ 125 MIPS
    "package_to_package_word_s": 360e-9,
}


# --- TPU collective cost model ------------------------------------------------
@dataclass(frozen=True)
class LinkSpec:
    bandwidth: float = 50e9      # bytes/s per ICI link
    latency: float = 1e-6        # per hop
    setup: float = 5e-6          # "packet" mode: schedule/route setup


def ring_collective_time(bytes_per_device: float, group: int,
                         kind: str = "all_gather",
                         link: LinkSpec = LinkSpec(),
                         mode: str = "circuit") -> float:
    """Ring AG/RS/AR time under the circuit (persistent) or packet
    (per-step setup) model."""
    if group <= 1:
        return 0.0
    steps = group - 1
    factor = {"all_gather": 1.0, "reduce_scatter": 1.0, "all_reduce": 2.0,
              "all_to_all": 1.0}[kind]
    wire = factor * bytes_per_device * (group - 1) / group
    t = wire / link.bandwidth + steps * link.latency * factor
    if mode == "packet":
        t += link.setup + steps * link.latency  # route setup per step
    return t


def crossover_bytes(group: int, link: LinkSpec = LinkSpec()) -> float:
    """Message size above which circuit vs packet mode stops mattering
    (<5% difference) — the TPU version of the paper's 435/500 analysis."""
    steps = group - 1
    extra = link.setup + steps * link.latency
    # want extra <= 0.05 * wire/bw  ->  wire >= 20 * extra * bw
    return 20.0 * extra * link.bandwidth * group / max(group - 1, 1)
