"""Unit tests for the deterministic fault plane (PR 8).

Bottom-up over the recovery stack: the allocator's quarantine
lifecycle (``fail_node``/``restore_node`` against the three-way
conservation invariant), the prefix cache's tree-wide
``invalidate_pages``, the scheduler's transient-rejection backoff /
degraded victim rule / graceful-degradation shedding, the
:class:`~repro.serving.faults.FaultPlan` schedule semantics, the
:class:`~repro.serving.faults.FaultPlane` watchdog against a fake
engine (detection is honest — missed heartbeats and straggler
patience, not oracular), and finally a real :class:`PagedEngine` run
with a manual mid-stream fail/join whose tokens must stay
bit-identical to the dense oracle.
"""
import numpy as np
import pytest

from repro.serving.faults import FaultEvent, FaultPlan, FaultPlane
from repro.serving.paged_kv import NULL_PAGE, PageAllocator
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ContinuousBatchScheduler, Request


def _stripe(a: PageAllocator, node: int) -> set:
    return {p for p in range(1, a.n_pages) if a.owner(p) == node}


# -- allocator: quarantine lifecycle ------------------------------------


def test_fail_node_quarantines_stripe_and_empties_free_list():
    a = PageAllocator(n_pages=13, page_size=4, n_nodes=3)
    newly = a.fail_node(1)
    assert newly == _stripe(a, 1) == a.quarantined
    assert NULL_PAGE not in newly
    assert not a._free_by_node[1]
    assert a.failed_nodes == {1}
    assert a.allocatable_pages == (a.n_pages - 1) - len(newly)
    assert a.check_conservation()
    # idempotent per node; out-of-range is a caller bug
    assert a.fail_node(1) == set()
    with pytest.raises(ValueError):
        a.fail_node(3)
    with pytest.raises(ValueError):
        a.fail_node(-1)


def test_release_parks_quarantined_pages_until_restore():
    a = PageAllocator(n_pages=13, page_size=4, n_nodes=3)
    pages = a.alloc("r0", 6)
    a.fail_node(1)
    held_dead = [p for p in pages if a.owner(p) == 1]
    assert held_dead, "stripe width 3 over 6 logical pages must hit node 1"
    # a referenced quarantined page stays in refcount until its holder
    # releases it; the release parks it instead of recirculating
    for p in held_dead:
        assert p in a.refcount
    freed = a.free("r0")
    assert freed == 6 - len(held_dead)
    for p in held_dead:
        assert p in a.quarantined and p not in a.refcount
        assert p not in a._free_by_node[1]
    assert a.check_conservation()
    # restore returns exactly the refcount-0 stripe to the node's list
    restored = a.restore_node(1)
    assert restored == len(_stripe(a, 1))
    assert not a.quarantined and not a.failed_nodes
    assert a.free_pages == a.n_pages - 1
    assert a.check_conservation()
    # restoring a healthy node is a no-op
    assert a.restore_node(1) == 0


def test_quarantined_pages_never_reenter_circulation():
    a = PageAllocator(n_pages=13, page_size=4, n_nodes=3)
    pages = a.alloc("r0", 3)
    dead_page = next(p for p in pages if a.owner(p) == 1)
    a.fail_node(1)
    # no new readers on a dead stripe
    with pytest.raises(ValueError):
        a.share(dead_page)
    # fresh allocations route around the quarantine entirely
    probe = a.alloc("probe", a.free_pages)
    assert probe is not None
    assert not (set(probe) & a.quarantined)
    # the pool is now empty: alloc/grow fail soft, never raise
    assert a.alloc("more", 1) is None
    assert a.grow("probe") is False
    assert a.check_conservation()


def test_restore_with_live_reference_resumes_refcount_life():
    a = PageAllocator(n_pages=13, page_size=4, n_nodes=3)
    pages = a.alloc("r0", 3)
    dead_page = next(p for p in pages if a.owner(p) == 1)
    a.fail_node(1)
    restored = a.restore_node(1)
    # the held page was not restored (still referenced) ...
    assert restored == len(_stripe(a, 1)) - 1
    assert dead_page in a.refcount and dead_page not in a.quarantined
    # ... and frees normally wherever its last release lands
    assert a.release_page(dead_page) is True
    a.held["r0"].remove(dead_page)
    assert dead_page in a._free_by_node[1]
    assert a.check_conservation()


# -- prefix cache: tree-wide invalidation -------------------------------


def test_invalidate_pages_drops_whole_subtree():
    a = PageAllocator(n_pages=13, page_size=2, n_nodes=3)
    cache = PrefixCache(a)
    tokens = [5, 6, 7, 8, 9, 10]
    pages = a.alloc("seed", 3)          # logical j -> node j%3
    cache.insert(tokens, pages, len(tokens))
    a.free("seed")                      # tree refs keep all three alive
    assert cache.n_nodes == 3 and a.pages_in_use == 3
    # kill the middle page's node: the node AND its descendant go — the
    # descendant is only reachable through the lost ancestor
    quar = a.fail_node(a.owner(pages[1]))
    dropped = cache.invalidate_pages(quar)
    assert dropped == 2
    assert cache.n_nodes == 1
    assert cache.peek(tokens) == 2      # only the surviving root chunk
    # the dead page parked in quarantine; the healthy descendant freed
    assert pages[1] in a.quarantined and pages[1] not in a.refcount
    assert pages[2] in a._free_by_node[a.owner(pages[2])]
    assert cache.metrics()["prefix_invalidations"] == 2
    assert a.check_conservation()
    # pages not in the tree are ignored
    assert cache.invalidate_pages({99, 100}) == 0


# -- scheduler: backoff, shedding, degraded victims ---------------------


def _sched(n_pages=13, n_nodes=1, max_batch=2, **kw):
    a = PageAllocator(n_pages=n_pages, page_size=4, n_nodes=n_nodes)
    return a, ContinuousBatchScheduler(a, max_batch=max_batch, **kw)


def test_transient_backoff_grows_exponentially_and_caps():
    a, s = _sched()
    s.transient_gate = lambda req, step: req.transient_rejections < 5
    q = Request("q0", prompt_len=4, gen=2)
    s.submit(q)
    backoffs = []
    while q.state == "waiting" and len(backoffs) < 8:
        s.step_idx = max(s.step_idx, q.backoff_until)
        plan = s.plan_step()
        if not plan.admitted:
            backoffs.append(q.backoff_until - s.step_idx)
    assert backoffs == [1, 2, 4, 8, 8]      # capped exponential
    assert q.state == "running"             # sixth attempt admits
    assert q.transient_rejections == 5
    assert s.transient_rejections == 5


def test_backing_off_request_never_blocks_the_queue():
    a, s = _sched()
    s.transient_gate = lambda req, step: req.rid == "q0" \
        and req.transient_rejections < 2
    q0 = Request("q0", prompt_len=4, gen=2)
    q1 = Request("q1", prompt_len=4, gen=2)
    s.submit(q0)
    s.submit(q1)
    plan = s.plan_step()
    # the FIFO head bounced; the request behind it admits the same step
    assert [r.rid for r in plan.admitted] == ["q1"]
    assert q0.state == "waiting" and q0.backoff_until == s.step_idx + 1
    assert s.conserved(2)


def test_shed_infeasible_is_terminal_and_batch_first():
    a, s = _sched(n_nodes=3, max_batch=3)
    big_int = Request("int", prompt_len=28, gen=8, slo="interactive")
    big_bat = Request("bat", prompt_len=28, gen=8, slo="batch")
    small = Request("ok", prompt_len=4, gen=4, slo="interactive")
    for r in (big_int, big_bat, small):
        s.submit(r)                     # 9, 9, 2 pages at peak; pool = 12
    a.fail_node(1)                      # capacity 12 -> 8: the 9s can
    plan = s.plan_step()                # never be admitted again
    assert [r.rid for r in s.shed] == ["bat", "int"]   # batch absorbs first
    assert all(r.state == "shed" for r in s.shed)
    assert small.state == "running" and small in plan.admitted
    assert s.conserved(3)
    # shedding stamps finished_step so goodput accounting stays total
    assert all(r.finished_step == s.step_idx for r in s.shed)


def test_degraded_victim_rule_sheds_batch_before_interactive():
    a, s = _sched(n_nodes=3)
    early_bat = Request("bat", prompt_len=4, gen=4, slo="batch",
                        arrived_step=0, seq=0, state="running", slot=0)
    late_int = Request("int", prompt_len=4, gen=4, slo="interactive",
                       arrived_step=1, seq=1, state="running", slot=1)
    s.running = {0: early_bat, 1: late_int}
    # healthy rule: latest arrival, SLO-blind
    assert s._victim(early_bat) is late_int
    # degraded rule: batch tenants absorb the shrunken pool's pressure
    # first, even when they arrived earlier
    a.fail_node(1)
    assert s._victim(early_bat) is early_bat


def test_fault_reset_rides_preemption_and_stamps_recovery():
    a, s = _sched()
    q = Request("q0", prompt_len=4, gen=4)
    s.submit(q)
    s.plan_step()
    assert q.state == "running"
    q.tokens = [1, 2]
    s.step_idx = 7
    s.fault_reset(q)
    assert q.state == "waiting" and q.tokens == []
    assert q.recoveries == 1 and q.preemptions == 1
    assert q.recovered_step == 7
    assert not a.held.get("q0")
    # the first re-landed token reports the reset -> first-token latency
    s.step_idx = 12
    s.note_first_token(q, 0)
    assert s.recovery_steps == [5]
    assert q.recovered_step is None     # cleared: one latency per reset


# -- FaultPlan: schedule semantics --------------------------------------


def test_fault_plan_queries():
    plan = FaultPlan([
        FaultEvent(2, "fail", 1),
        FaultEvent(6, "join", 1),
        FaultEvent(3, "slow", 2, duration=4, factor=3.0),
        FaultEvent(1, "transient", count=2),
        FaultEvent(5, "transient", count=1),
    ])
    assert [plan.alive(1, s) for s in (0, 2, 5, 6)] == \
        [True, False, False, True]
    assert plan.slow_factor(2, 2) == 1.0
    assert plan.slow_factor(2, 3) == 3.0
    assert plan.slow_factor(2, 6) == 3.0    # last slow step: 3 + 4 - 1
    assert plan.slow_factor(2, 7) == 1.0
    assert [plan.transients_through(s) for s in (0, 1, 5)] == [0, 2, 3]
    assert plan.n_node_failures == 1
    assert plan.horizon == 7                # slow window outlives the join


def test_fault_plan_rejects_bad_events():
    with pytest.raises(ValueError):
        FaultEvent(0, "explode")
    with pytest.raises(ValueError):
        FaultEvent(-1, "fail", 0)


def test_seeded_plan_is_deterministic_and_spares_node_zero():
    p1 = FaultPlan.seeded(7, n_nodes=4, horizon=40)
    p2 = FaultPlan.seeded(7, n_nodes=4, horizon=40)
    assert p1.events == p2.events
    assert p1.events != FaultPlan.seeded(8, n_nodes=4, horizon=40).events
    assert p1.n_node_failures == 2
    fails = [e for e in p1.events if e.kind == "fail"]
    slows = [e for e in p1.events if e.kind == "slow"]
    assert all(e.node >= 1 for e in fails + slows), "node 0 never fails"
    for f in fails:                     # every failure re-joins later
        assert any(j.kind == "join" and j.node == f.node and j.step > f.step
                   for j in p1.events)
    with pytest.raises(ValueError):
        FaultPlan.seeded(0, n_nodes=1, horizon=40)


# -- FaultPlane: the watchdog against a fake engine ---------------------


class _FakeEngine:
    """Just enough engine for the watchdog: a step clock and recorded
    fail/join transitions."""

    def __init__(self):
        class _S:
            step_idx = 0
        self.sched = _S()
        self.failed = []
        self.joined = []

    def fail_node(self, node):
        self.failed.append((self.sched.step_idx, node))

    def join_node(self, node):
        self.joined.append((self.sched.step_idx, node))


def _drive(plane, eng, steps):
    for s in range(steps):
        eng.sched.step_idx = s
        plane.on_step(eng)


def test_watchdog_detects_failure_after_missed_heartbeats():
    plan = FaultPlan([FaultEvent(2, "fail", 1), FaultEvent(8, "join", 1)])
    eng = _FakeEngine()
    _drive(FaultPlane(plan, n_nodes=3), eng, 16)
    assert [n for _, n in eng.failed] == [1]
    assert [n for _, n in eng.joined] == [1]
    det_step = eng.failed[0][0]
    # honest detection: the kill lands at step 2 but the monitor needs
    # heartbeat_steps (2.0) of silence past the last beat at step 1, so
    # the earliest possible verdict is step 4 — never the kill step
    assert det_step >= 2 + 2
    assert eng.joined[0][0] >= 8


def test_watchdog_evicts_straggler_then_rejoins():
    plan = FaultPlan([FaultEvent(1, "slow", 2, duration=6, factor=4.0)])
    eng = _FakeEngine()
    plane = FaultPlane(plan, n_nodes=3)
    _drive(plane, eng, 14)
    assert [n for _, n in eng.failed] == [2]
    det_step = eng.failed[0][0]
    assert det_step >= 2, "patience 2 needs two over-ratio observations"
    # once the slow window ends the (still-heartbeating) node re-joins
    assert [n for _, n in eng.joined] == [2]
    assert eng.joined[0][0] >= 1 + 6
    assert not plane.down
    assert plane.summary()["planned_failures"] == 0


def test_watchdog_transient_gate_honours_budget_and_epoch():
    plan = FaultPlan([FaultEvent(3, "transient", count=2)])
    plane = FaultPlane(plan, n_nodes=2, epoch=100)
    req = Request("q0", prompt_len=4, gen=2)
    assert not plane.transient_gate(req, 102)   # before the event
    assert plane.transient_gate(req, 103)       # epoch-relative step 3
    assert plane.transient_gate(req, 103)
    assert not plane.transient_gate(req, 120)   # budget exhausted
    assert plane.summary()["transients_used"] == 2


# -- engine: manual mid-stream fail/join stays bit-exact ----------------


def test_engine_manual_fail_join_matches_dense_oracle():
    from conftest import dense_oracle, get_tiny_model, make_engine, \
        seeded_prompts
    cfg, params = get_tiny_model()
    prompts = seeded_prompts(cfg, 4, 12, seed=11)
    gens = [8, 6, 7, 5]
    max_len = max(p.shape[0] + g for p, g in zip(prompts, gens))
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng = make_engine(cfg, params, max_batch=4, page_size=4, n_pages=31,
                      max_len=max_len, n_nodes=3)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(np.asarray(p), g, rid=f"r{i}")
    for _ in range(4):
        eng.step()
    quar = eng.fail_node(1)
    assert quar and eng.alloc.check_conservation()
    assert eng.metrics()["requests_recovered"] >= 1
    eng.step()                          # degraded step: conservation holds
    assert eng.alloc.check_conservation()
    rejoined = eng.join_node(1)
    assert rejoined > 0
    eng.run()
    toks = {r.rid: list(r.tokens) for r in eng.sched.finished}
    assert toks == dense                # recovery is exact greedy recompute
    m = eng.metrics()
    assert m["node_failures"] == 1 and m["node_joins"] == 1
    assert m["pages_quarantined"] == len(quar)
    assert m["pages_quarantined_now"] == 0
    assert m["tokens_recomputed"] >= 1
    assert m["quarantined_served"] == 0
    assert m["recovery_steps_p99"] >= 0.0
    assert eng.sched.conserved(eng._n_submitted)
    assert eng.alloc.pages_in_use == 0
