"""Multi-head Latent Attention (DeepSeek-V2/V3) with weight-absorbed decode.

Prefill/train: latents are up-projected to per-head k/v and attention runs
as usual (blocked-flash).  Decode: the cache stores only the compressed
latent c_kv (kv_lora) + the shared rope key (qk_rope_head_dim); queries are
absorbed through kv_b so scores/outputs are computed directly in latent
space — cache is O(kv_lora + rope) per token instead of O(H * head_dim).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import modules as nn
from repro.parallel.sharding import logical_constraint

NEG_INF = attn.NEG_INF


class MLACache(NamedTuple):
    ckv: jnp.ndarray     # (B, T, kv_lora)
    k_rope: jnp.ndarray  # (B, T, rope_dim)


def init(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "q_a": nn.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_a_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "q_b": nn.dense_init(ks[1], m.q_lora_rank, H * qd, dtype),
        "kv_a": nn.dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                              dtype),
        "kv_a_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "kv_b": nn.dense_init(ks[3], m.kv_lora_rank,
                              H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": nn.dense_init(ks[4], H * m.v_head_dim, d, dtype,
                            scale=1.0 / max(1, cfg.n_layers) ** 0.5),
    }
    return p


def _project_q(p, cfg, x, angles):
    m = cfg.mla
    H = cfg.n_heads
    cq = nn.rmsnorm(nn.matmul(x, p["q_a"]), p["q_a_norm"], cfg.norm_eps)
    q = nn.matmul(cq, p["q_b"]).reshape(
        *x.shape[:-1], H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    if angles is not None:
        q_rope = nn.apply_rope(q_rope, angles)
    return q_nope, q_rope


def _project_kv_latent(p, cfg, x, angles):
    m = cfg.mla
    lat = nn.matmul(x, p["kv_a"])
    ckv, k_rope = lat[..., :m.kv_lora_rank], lat[..., m.kv_lora_rank:]
    ckv = nn.rmsnorm(ckv, p["kv_a_norm"], cfg.norm_eps)
    if angles is not None:
        k_rope = nn.apply_rope(k_rope[..., None, :], angles)[..., 0, :]
    return ckv, k_rope


def _scale(cfg) -> float:
    m = cfg.mla
    return (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5


def apply(p, cfg, x, *, angles, impl=None):
    """Train/prefill. Returns (out, (ckv, k_rope)) for cache building."""
    m = cfg.mla
    H = cfg.n_heads
    B, S, _ = x.shape
    impl = impl or cfg.impl

    q_nope, q_rope = _project_q(p, cfg, x, angles)
    ckv, k_rope = _project_kv_latent(p, cfg, x, angles)
    kv = nn.matmul(ckv, p["kv_b"]).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    # H-space core: all operands sharded on heads (H=128 divides TP)
    q = logical_constraint(q, "batch", None, "heads", None)
    k = logical_constraint(k, "batch", None, "heads", None)
    v = logical_constraint(v, "batch", None, "heads", None)

    kw = dict(causal=cfg.causal, window=None, scale=_scale(cfg),
              softcap=cfg.attn_softcap)
    if impl == "ref":
        o = attn.attend_ref(q, k, v_pad(v, q.shape[-1]), **kw)
    elif impl in ("blocked", "pallas"):
        o = attn.attend_blocked(q, k, v_pad(v, q.shape[-1]),
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv, **kw)
    else:
        raise ValueError(impl)
    o = o[..., :m.v_head_dim]  # un-pad v
    from repro.parallel.collectives import row_parallel
    out = row_parallel(o.reshape(B, S, H * m.v_head_dim), p["wo"])
    return out, (ckv, k_rope)


def v_pad(v, d):
    """Pad v head-dim so the generic attention helpers can be reused."""
    if v.shape[-1] == d:
        return v
    return jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, d - v.shape[-1]),))


# ---------------------------------------------------------------------------
# decode with absorbed weights + latent cache
# ---------------------------------------------------------------------------
def _kv_b_split(p, cfg):
    m = cfg.mla
    H = cfg.n_heads
    kv_b = p["kv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = kv_b[..., :m.qk_nope_head_dim]   # (lora, H, nope)
    w_uv = kv_b[..., m.qk_nope_head_dim:]   # (lora, H, v)
    return w_uk, w_uv


def cache_init(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return MLACache(
        ckv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype))


def cache_from_prefill(ckv, k_rope, max_len):
    B, S = ckv.shape[:2]
    pad = max_len - S
    if pad > 0:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return MLACache(ckv, k_rope)


def _decode_scores_local(q_lat, q_rope, ckv, k_rope, valid, cfg):
    """Partial absorbed-attention over a latent-cache slice.
    Returns (m, l, acc_lat (B,H,lora))."""
    s = jnp.einsum("bhl,btl->bht", q_lat.astype(ckv.dtype), ckv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhd,btd->bht", q_rope, k_rope,
                       preferred_element_type=jnp.float32)
    s = s * _scale(cfg)
    s = nn.softcap(s, cfg.attn_softcap)
    s = jnp.where(valid[None, None], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bht,btl->bhl", p.astype(ckv.dtype), ckv,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def apply_decode(p, cfg, x, cache: MLACache, pos, *, angles):
    """x (B,1,D). Absorbed-weight decode in latent space.

    With a mesh, the latent cache is time-sharded over "model" (split-T)
    and the partial softmax stats merge with (B,H)-sized psums.
    """
    m = cfg.mla
    H = cfg.n_heads
    B = x.shape[0]

    q_nope, q_rope = _project_q(p, cfg, x, angles)       # (B,1,H,nope/rope)
    ckv_new, k_rope_new = _project_kv_latent(p, cfg, x, angles)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope_new, pos, axis=1)

    w_uk, w_uv = _kv_b_split(p, cfg)
    # absorb: q_lat[h] = q_nope[h] @ w_uk[:,h,:]^T  -> latent-space query
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32)  # (B,H,lora)

    from repro.models.attention import _split_t_applicable
    from repro.parallel.sharding import current_env
    env = current_env()
    T = ckv.shape[1]
    if _split_t_applicable(env, T):
        from repro.models.moe import _shard_map
        axes = env.resolve("seq_sp")
        axes = (axes,) if isinstance(axes, str) else tuple(axes)

        def body(q_lat_l, q_rope_l, ckv_l, kr_l):
            idx = jax.lax.axis_index(axes[0])
            Tl = ckv_l.shape[1]
            valid = idx * Tl + jnp.arange(Tl) <= pos
            mm, ll, acc = _decode_scores_local(q_lat_l, q_rope_l[:, 0],
                                               ckv_l, kr_l, valid, cfg)
            m_g = jax.lax.pmax(mm, axes)
            corr = jnp.exp(mm - m_g)
            l_g = jax.lax.psum(ll * corr, axes)
            acc_g = jax.lax.psum(acc * corr[..., None], axes)
            return acc_g / jnp.maximum(l_g[..., None], 1e-37)

        o_lat = _shard_map(
            body, mesh=env.mesh,
            in_specs=(env.spec("batch", None, None),
                      env.spec("batch", None, None, None),
                      env.spec("batch", "seq_sp", None),
                      env.spec("batch", "seq_sp", None)),
            out_specs=env.spec("batch", None, None),
            check_vma=False)(q_lat, q_rope, ckv, k_rope)
    else:
        valid = jnp.arange(T) <= pos
        mm, ll, acc = _decode_scores_local(q_lat, q_rope[:, 0], ckv,
                                           k_rope, valid, cfg)
        o_lat = acc / jnp.maximum(ll[..., None], 1e-37)

    o = jnp.einsum("bhl,lhv->bhv", o_lat.astype(x.dtype), w_uv,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = nn.matmul(o.reshape(B, 1, H * m.v_head_dim), p["wo"])
    return out, MLACache(ckv, k_rope)
