"""Analytic per-step cost model: HLO-equivalent FLOPs and HBM bytes.

``compiled.cost_analysis()`` counts while/scan bodies once, so for a
scanned-layer model it undercounts by ~n_layers.  This module computes the
*HLO-equivalent* global FLOPs (what the device actually executes,
including blocked-attention full-S^2 compute, MoE capacity padding,
GSPMD head-padding waste, remat recompute and the backward pass) plus a
per-chip HBM-traffic model.  Validated against cost_analysis() on small
*unrolled* configs in tests/test_cost_model.py.

MODEL_FLOPS (the "useful" count) = 6*N_active*tokens for training,
2*N_active*tokens for inference — the MaxText/PaLM convention.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import (ATTN, LOCAL, MLA, RGLRU, RWKV6, ModelConfig,
                                ShapeConfig)
from repro.models.rwkv6 import CHUNK as RWKV_CHUNK


@dataclass
class CellCost:
    flops_fwd: float = 0.0          # global forward FLOPs (one step)
    flops_total: float = 0.0        # incl. backward + remat (train)
    hbm_bytes_per_chip: float = 0.0
    model_flops: float = 0.0        # 6*N_active*D (train) / 2*N*D (infer)
    breakdown: Dict[str, float] = field(default_factory=dict)

    def add(self, name: str, flops: float):
        self.flops_fwd += flops
        self.breakdown[name] = self.breakdown.get(name, 0.0) + flops


def _pad_factor(n: int, shards: int) -> float:
    """GSPMD padding waste when n is sharded over `shards`."""
    if shards <= 1:
        return 1.0
    return math.ceil(n / shards) * shards / n


def _blocked(block: int, s: int) -> int:
    b = min(block, s)
    while s % b:
        b -= 1
    return b


def attention_core_flops(cfg: ModelConfig, kind: str, S: int, B: int,
                         mode: str, tp: int, cache_len: int = 0) -> float:
    """Score + AV einsum FLOPs (global), incl. sharding-padding waste."""
    H, hd = cfg.n_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        v_dim = qk_dim  # v is padded to qk_dim in the blocked path
    else:
        qk_dim = v_dim = hd
    pad = _pad_factor(cfg.n_kv_heads if cfg.mla is None else H, tp)
    if mode == "decode":
        T = cache_len
        if kind == LOCAL:
            T = min(cfg.sliding_window, T)
        if cfg.mla is not None:
            m = cfg.mla
            lat = m.kv_lora_rank + m.qk_rope_head_dim
            # absorbed decode: scores vs latent + output in latent space
            core = 2.0 * B * H * T * lat + 2.0 * B * H * T * m.kv_lora_rank
            absorb = 2.0 * B * H * m.qk_nope_head_dim * m.kv_lora_rank \
                + 2.0 * B * H * m.kv_lora_rank * m.v_head_dim
            return (core + absorb) * _pad_factor(H, tp)
        return (2.0 * B * H * T * qk_dim + 2.0 * B * H * T * v_dim) * pad
    # train / prefill — blocked flash computes the full S^2 (masked), except
    # the sliding-window fast path which only touches the window span
    if kind == LOCAL and cfg.causal:
        bq = _blocked(cfg.attn_block_q, S)
        span = cfg.sliding_window + bq
        if span < S:
            kv_span = span
        else:
            kv_span = S
    else:
        kv_span = S
    return (2.0 * B * H * S * kv_span * qk_dim
            + 2.0 * B * H * S * kv_span * v_dim) * pad


def layer_flops(cfg: ModelConfig, kind: str, is_moe: bool, t: float,
                S: int, B: int, mode: str, tp: int,
                cache_len: int = 0) -> Dict[str, float]:
    """Global forward FLOPs for one layer. t = tokens processed."""
    d = cfg.d_model
    out: Dict[str, float] = {}
    mm = lambda m, k, n: 2.0 * m * k * n

    if kind in (ATTN, LOCAL):
        out["attn_proj"] = (mm(t, d, cfg.q_dim) + 2 * mm(t, d, cfg.kv_dim)
                            + mm(t, cfg.q_dim, d))
        out["attn_core"] = attention_core_flops(cfg, kind, S, B, mode, tp,
                                                cache_len)
    elif kind == MLA:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        out["attn_proj"] = (
            mm(t, d, m.q_lora_rank) + mm(t, m.q_lora_rank, cfg.n_heads * qk)
            + mm(t, d, m.kv_lora_rank + m.qk_rope_head_dim)
            + mm(t, cfg.n_heads * m.v_head_dim, d))
        if mode != "decode":   # decode absorbs kv_b (counted in core)
            out["attn_proj"] += mm(t, m.kv_lora_rank,
                                   cfg.n_heads * (m.qk_nope_head_dim
                                                  + m.v_head_dim))
        out["attn_core"] = attention_core_flops(cfg, kind, S, B, mode, tp,
                                                cache_len)
    elif kind == RGLRU:
        w = cfg.lru_width or d
        hd = w // cfg.n_heads
        out["rglru_proj"] = 3 * mm(t, d, w)
        out["rglru_gates"] = 2 * mm(t * cfg.n_heads, hd, hd)
        out["rglru_scan"] = 12.0 * t * w  # conv + gating + assoc-scan
    elif kind == RWKV6:
        out["rwkv_proj"] = 5 * mm(t, d, d)
        out["rwkv_lora"] = (mm(t, d, 5 * 32) + 5 * mm(t, 32, d)
                            + mm(t, d, 64) + mm(t, 64, d))
        H, hd = cfg.n_heads, cfg.head_dim
        L = min(RWKV_CHUNK, S if mode != "decode" else 1)
        nc = max(1, (S if mode != "decode" else 1) // L)
        per_chunk = (2.0 * B * H * L * hd * hd      # inter (o += q @ S0)
                     + 3.0 * B * H * L * L * hd     # intra decay product
                     + 2.0 * B * H * L * L * hd     # intra o
                     + 2.0 * B * H * L * hd * hd)   # state update
        out["rwkv_core"] = per_chunk * nc * _pad_factor(H, tp)
        out["rwkv_cm"] = mm(t, d, cfg.d_ff) + mm(t, cfg.d_ff, d) + mm(t, d, d)
        return out
    else:
        raise ValueError(kind)

    if is_moe:
        m = cfg.moe
        from repro.models.moe import capacity
        # dispatch capacity is computed per data-shard token count; the
        # padded slot count is what the grouped GEMM actually computes
        slots = t * m.top_k * m.capacity_factor
        n_mats = 3 if cfg.gated_ffn else 2
        out["moe_router"] = mm(t, d, m.n_experts)
        out["moe_experts"] = n_mats * mm(slots, d, m.d_ff_expert)
        if m.n_shared:
            out["moe_shared"] = n_mats * mm(t, d, m.d_ff_expert * m.n_shared)
    else:
        n_mats = 3 if cfg.gated_ffn else 2
        out["ffn"] = n_mats * mm(t, d, cfg.d_ff)
    return out


def step_costs(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
               tp: int = 16) -> CellCost:
    """Full-step analytic cost for one (arch x shape) cell."""
    mode = shape.kind
    B = shape.global_batch
    S = shape.seq_len
    d = cfg.d_model
    cost = CellCost()
    mm = lambda m, k, n: 2.0 * m * k * n

    if mode == "decode":
        t = float(B)          # one token per sequence
        S_eff = 1
        cache_len = S
    else:
        t = float(B) * S
        S_eff = S
        cache_len = 0

    for i, kind in enumerate(cfg.layer_kinds):
        is_moe = cfg.moe is not None and i >= cfg.first_k_dense
        for name, f in layer_flops(cfg, kind, is_moe, t, S_eff, B, mode, tp,
                                   cache_len).items():
            cost.add(name, f)

    # head / loss
    V = cfg.vocab_size
    if mode == "train":
        cost.add("head", mm(t, d, V) + 6.0 * t * V)   # logits + CE softmax
        if cfg.mtp_depth:
            seg_kind = cfg.layer_kinds[-1]
            is_moe = cfg.moe is not None
            cost.add("mtp_proj", mm(t, 2 * d, d))
            for name, f in layer_flops(cfg, seg_kind, is_moe, t, S_eff, B,
                                       mode, tp).items():
                cost.add("mtp_" + name, f)
            cost.add("mtp_head", mm(t, d, V) + 6.0 * t * V)
    else:
        t_head = float(B)     # prefill/decode: only last-position logits
        cost.add("head", mm(t_head, d, V))

    # --- totals -------------------------------------------------------------
    if mode == "train":
        # backward = 2x fwd matmuls; remat recomputes the scanned fwd once
        fwd = cost.flops_fwd
        remat = fwd if cfg.remat else 0.0
        cost.flops_total = fwd * 3.0 + remat
        tokens_for_model = t
        cost.model_flops = 6.0 * cfg.n_active_params() * tokens_for_model
    else:
        cost.flops_total = cost.flops_fwd
        cost.model_flops = 2.0 * _n_active_no_mtp(cfg) * t

    cost.hbm_bytes_per_chip = hbm_bytes_per_chip(cfg, shape, n_chips, tp)
    return cost


def _n_active_no_mtp(cfg: ModelConfig) -> float:
    """Active params for inference MODEL_FLOPS: excludes MTP modules and
    the vocab matrices (embedding lookup is a gather; the unembed runs
    only on the last position for prefill/decode)."""
    n = cfg.n_active_params()
    n -= cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.mtp_depth:
        # MTP modules are train-only
        mtp = cfg._mixer_params(cfg.layer_kinds[-1]) + 3 * cfg.d_model \
            + cfg.d_model * 2 * cfg.d_model
        if cfg.moe is not None:
            m = cfg.moe
            per = (3 if cfg.gated_ffn else 2) * cfg.d_model * m.d_ff_expert
            mtp += (m.top_k + m.n_shared) * per
        n -= cfg.mtp_depth * mtp
    return float(n)


def param_bytes(cfg: ModelConfig) -> float:
    bpp = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    return float(cfg.n_params()) * bpp


def opt_state_bytes(cfg: ModelConfig) -> float:
    per = {"float32": 8.0, "bfloat16": 4.0, "int8": 2.02}[cfg.opt_state_dtype]
    return float(cfg.n_params()) * per


def kv_cache_bytes(cfg: ModelConfig, B: int, T: int) -> float:
    act = 2  # bf16
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind == ATTN:
            total += 2.0 * B * T * cfg.kv_dim * act
        elif kind == LOCAL:
            total += 2.0 * B * min(T, cfg.sliding_window) * cfg.kv_dim * act
        elif kind == MLA:
            m = cfg.mla
            total += B * T * (m.kv_lora_rank + m.qk_rope_head_dim) * act
        elif kind == RGLRU:
            w = cfg.lru_width or cfg.d_model
            total += B * w * 4.0 + B * (cfg.conv1d_width - 1) * w * act
        elif kind == RWKV6:
            total += B * cfg.n_heads * cfg.head_dim ** 2 * 4.0 \
                + 2.0 * B * cfg.d_model * act
    return total


def activation_stream_bytes(cfg: ModelConfig, t: float) -> float:
    """Approximate global activation HBM traffic of one forward pass:
    input+output of every major matmul at bf16."""
    act = 2.0
    d = cfg.d_model
    total = 0.0
    for i, kind in enumerate(cfg.layer_kinds):
        is_moe = cfg.moe is not None and i >= cfg.first_k_dense
        if kind in (ATTN, LOCAL):
            widths = [cfg.q_dim, 2 * cfg.kv_dim, cfg.q_dim, d]
        elif kind == MLA:
            m = cfg.mla
            widths = [m.q_lora_rank,
                      cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                      m.kv_lora_rank + m.qk_rope_head_dim,
                      cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim), d]
        elif kind == RGLRU:
            w = cfg.lru_width or d
            widths = [w, w, w, d]
        else:  # rwkv6
            widths = [d] * 6 + [cfg.d_ff]
        for wdt in widths:
            total += t * (d + wdt) * act
        if is_moe:
            m = cfg.moe
            slots = t * m.top_k * m.capacity_factor
            n_mats = 3 if cfg.gated_ffn else 2
            total += n_mats * slots * (d + m.d_ff_expert) * act
        elif kind != RWKV6:
            n_mats = 3 if cfg.gated_ffn else 2
            total += n_mats * t * (d + cfg.d_ff) * act
    return total


def hbm_bytes_per_chip(cfg: ModelConfig, shape: ShapeConfig,
                       n_chips: int, tp: int) -> float:
    """Per-chip HBM traffic for one step (documented approximation)."""
    mode = shape.kind
    B, S = shape.global_batch, shape.seq_len
    pb = param_bytes(cfg)
    if mode == "train":
        t = float(B) * S
        # own shard r/w for optimizer + grads; gathered copies (sharded only
        # over tp) read for fwd, bwd and remat
        weights = pb / n_chips * 3.0 + 3.0 * pb / tp
        opt = opt_state_bytes(cfg) / n_chips * 2.0
        acts = activation_stream_bytes(cfg, t) / n_chips * 3.0
        return weights + opt + acts
    if mode == "prefill":
        t = float(B) * S
        weights = pb / tp
        acts = activation_stream_bytes(cfg, t) / n_chips
        cache = kv_cache_bytes(cfg, B, S) / n_chips
        return weights + acts + cache
    # decode: weights + full cache read per token
    weights = pb / tp
    cache = kv_cache_bytes(cfg, B, S) / n_chips
    acts = activation_stream_bytes(cfg, float(B)) / n_chips
    return weights + cache + acts
