"""~100M-parameter dense LM used by the end-to-end example driver.

Not an assigned architecture — it is the Swallow-style "motivating
application": small enough to train a few hundred steps on CPU, structured
exactly like the big dense configs (GQA + SwiGLU + qk_norm).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tiny-100m",
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    qk_norm=True,
    act="silu",
    gated_ffn=True,
    tie_embeddings=True,
    attn_block_q=128,
    attn_block_kv=256,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256,
                          attn_block_q=16, attn_block_kv=32)
