"""Pallas TPU fused RMSNorm: one HBM round-trip for norm + scale.

Grid (nRows,): a (block_rows, D) tile is read once; the mean-square
reduction, rsqrt and scale all happen in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _rms_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=True):
    """x (..., D); scale (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    bn = min(block_rows, N)
    while N % bn:
        bn -= 1
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xf, scale)
    return out.reshape(orig_shape)
