#!/usr/bin/env python
"""Offline trace analysis: summarize a Chrome trace-event JSON file
exported by ``repro.launch.serve --engine paged --trace-out`` (or a
flight-recorder dump's re-export).

Prints the per-phase predicted-vs-measured model-error table, the
request-lifecycle state census (how many spans each state contributed,
per tenant), and the dispatch-span totals; validates the document
against the trace-event schema first and exits non-zero if it would
not load in Perfetto.

    PYTHONPATH=src python -m repro.launch.serve --tiny --engine paged \
        --requests 4 --gen 8 --trace-out /tmp/trace.json \
        --metrics-out /tmp/metrics.json
    python scripts/report_trace.py /tmp/trace.json \
        --metrics /tmp/metrics.json

Pure host-side: imports only repro.serving.telemetry (numpy + stdlib),
so it runs without jax installed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import Counter

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.serving.telemetry import (format_model_error,  # noqa: E402
                                     rollup_dispatch_events,
                                     validate_chrome_trace)


def lifecycle_census(events) -> dict:
    """Per-tenant state counts over the request-lifecycle spans
    (cat "request" = dwell states, cat "marker" = terminal events).
    The tenant is the span's process lane — recovered from the
    ``process_name`` metadata events."""
    groups = {ev["pid"]: ev["args"]["name"] for ev in events
              if ev.get("ph") == "M" and ev.get("name") == "process_name"}
    census: dict = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") not in ("request",
                                                        "marker"):
            continue
        group = groups.get(ev.get("pid"), "?")
        tenant = group.split(":", 1)[1] if group.startswith("tenant:") \
            else group
        census.setdefault(tenant, Counter())[ev["name"]] += 1
    return census


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON "
                                  "(--trace-out output)")
    ap.add_argument("--metrics", default=None,
                    help="optional metrics registry snapshot "
                         "(--metrics-out output) to summarize alongside")
    args = ap.parse_args()

    with open(args.trace) as f:
        doc = json.load(f)
    errs = validate_chrome_trace(doc)
    if errs:
        print(f"{args.trace}: INVALID trace-event JSON "
              f"({len(errs)} error(s)):", file=sys.stderr)
        for e in errs:
            print(" -", e, file=sys.stderr)
        sys.exit(1)
    events = doc["traceEvents"]
    n_x = sum(1 for e in events if e.get("ph") == "X")
    n_c = sum(1 for e in events if e.get("ph") == "C")
    print(f"{args.trace}: valid ({len(events)} events: {n_x} spans, "
          f"{n_c} counter samples)")

    report = rollup_dispatch_events(events)
    if report:
        total_pred = sum(r["predicted_s"] for r in report.values())
        total_meas = sum(r["measured_s"] for r in report.values())
        print("\nper-phase model error (cost-engine predicted vs "
              "measured wall):")
        print(format_model_error(report))
        print(f"total: predicted {total_pred:.6f}s, measured "
              f"{total_meas:.6f}s")
    else:
        print("\nno dispatch spans in the ring (decode-only trace or "
              "all evicted)")

    census = lifecycle_census(events)
    if census:
        print("\nrequest lifecycle (spans per state, per tenant):")
        for tenant in sorted(census):
            states = ", ".join(f"{k}={v}" for k, v
                               in sorted(census[tenant].items()))
            print(f"  {tenant}: {states}")

    if args.metrics:
        with open(args.metrics) as f:
            snap = json.load(f)
        counters = snap.get("counters", {})
        hists = snap.get("histograms", {})
        nonzero = {k: v for k, v in sorted(counters.items()) if v}
        print(f"\nmetrics snapshot ({args.metrics}): "
              f"{len(counters)} counters ({len(nonzero)} nonzero), "
              f"{len(snap.get('gauges', {}))} gauges, "
              f"{len(hists)} histograms")
        for k, v in nonzero.items():
            print(f"  {k} = {v}")
        for name, h in sorted(hists.items()):
            print(f"  {name}: n={h['count']} p50={h['p50']:.3g} "
                  f"p95={h['p95']:.3g} p99={h['p99']:.3g}")


if __name__ == "__main__":
    main()
