"""Blockwise int8 quantization for optimizer state and gradient compression.

Swallow's 64 kB-per-core memory pressure reappears at pod scale as
HBM-per-chip pressure: deepseek-v3 (671B params) only fits a 256-chip pod
with bf16 params + int8 Adam moments.  Blocks of 256 along the trailing
dim share one fp32 absmax scale; second moments are stored as sqrt to
tame their dynamic range.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256
# block count padded to a multiple of this so the flat (n_blocks, BLOCK)
# layout can be sharded over every mesh axis (512-chip multi-pod mesh)
MAX_SHARDS = 512


def _pad_blocks(n_blocks: int) -> int:
    return -(-n_blocks // MAX_SHARDS) * MAX_SHARDS


BLOCK_ALIGNED = 128   # last-dim block for the param-shaped layout


class QTensor(NamedTuple):
    # mode "flat": q (n_blocks, BLOCK) int8, scale (n_blocks,)
    # mode "aligned": q = param-shaped int8, scale (..., last/BLOCK_ALIGNED)
    #   — keeps the moment sharding identical to the parameter sharding so
    #   the optimizer update is comms-free (see EXPERIMENTS.md §Perf it. 6)
    q: jnp.ndarray
    scale: jnp.ndarray
    shape: tuple          # original shape (static)
    sqrt_encoded: bool
    mode: str = "flat"


def flatten_blocks(x) -> jnp.ndarray:
    """(any shape) -> fp32 (n_blocks_padded, BLOCK) fully-shardable layout."""
    xf = x.astype(jnp.float32).reshape(-1)
    n_blocks = _pad_blocks(-(-xf.size // BLOCK))
    pad = n_blocks * BLOCK - xf.size
    if pad:
        xf = jnp.pad(xf, (0, pad))
    return xf.reshape(n_blocks, BLOCK)


def unflatten_blocks(xb, shape) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    return xb.astype(jnp.float32).reshape(-1)[:n].reshape(shape)


def quantize(x, *, sqrt_encode: bool = False) -> QTensor:
    shape = x.shape
    xf = x.astype(jnp.float32)
    if sqrt_encode:
        xf = jnp.sqrt(jnp.maximum(xf, 0.0))
    xb = flatten_blocks(xf)
    scale = jnp.max(jnp.abs(xb), axis=1)
    q = jnp.round(xb / jnp.maximum(scale[:, None], 1e-12) * 127.0)
    return QTensor(q.astype(jnp.int8), scale, shape, sqrt_encode, "flat")


def aligned_ok(shape) -> bool:
    return len(shape) >= 2 and shape[-1] % BLOCK_ALIGNED == 0


def quantize_aligned(x, *, sqrt_encode: bool = False) -> QTensor:
    """Param-shaped int8 with per-(last-dim-block) scales — the moment
    tensor shards exactly like the parameter."""
    shape = x.shape
    xf = x.astype(jnp.float32)
    if sqrt_encode:
        xf = jnp.sqrt(jnp.maximum(xf, 0.0))
    nb = shape[-1] // BLOCK_ALIGNED
    xb = xf.reshape(*shape[:-1], nb, BLOCK_ALIGNED)
    scale = jnp.max(jnp.abs(xb), axis=-1)                   # (..., nb)
    q = jnp.round(xb / jnp.maximum(scale[..., None], 1e-12) * 127.0)
    return QTensor(q.reshape(shape).astype(jnp.int8), scale, shape,
                   sqrt_encode, "aligned")


def dequantize(qt: QTensor) -> jnp.ndarray:
    if qt.mode == "aligned":
        nb = qt.shape[-1] // BLOCK_ALIGNED
        xb = qt.q.reshape(*qt.shape[:-1], nb, BLOCK_ALIGNED).astype(
            jnp.float32) * (qt.scale[..., None] / 127.0)
        xf = xb.reshape(qt.shape)
    else:
        xb = qt.q.astype(jnp.float32) * (qt.scale[:, None] / 127.0)
        xf = unflatten_blocks(xb, qt.shape)
    if qt.sqrt_encoded:
        xf = jnp.square(xf)
    return xf


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale), (t.shape, t.sqrt_encoded, t.mode)),
    lambda aux, ch: QTensor(ch[0], ch[1], aux[0], aux[1], aux[2]))
