"""Swallow §V applied to the model-dispatch "interconnect": weightless
n-gram speculative decoding for the paged serving engine.

The paper's throughput argument is about the communication-to-computation
ratio: a fixed per-message overhead is amortized by making every message
carry more useful payload.  PR 3 applied that to host<->device syncs
(O(1)/window); this module applies it to *model dispatches per emitted
token* — the remaining per-token fixed cost.  A decode step is one model
pass for one token; speculative decoding turns it into one model pass
for up to K+1 tokens: a draft of K tokens is *proposed for free* (no
model, no weights — pure string matching over the sequence's own
history) and *verified in one batched dispatch*
(:func:`repro.models.lm.verify_window_paged`, the same
``apply_prefill_paged`` arithmetic as the prefix-cache suffix path), so
the accepted prefix plus the verifier's own bonus/correction token all
land from a single pass.

Drafting is prompt-lookup (n-gram) speculation: match the last ``n``
tokens of the sequence's own prompt+output history against an earlier
occurrence in that same history, and propose the tokens that followed
it.  Repetitive text — templated output, code, retrieval-heavy prompts,
or the fixed-point loops greedy decode falls into — drafts almost
perfectly; adversarial text drafts nothing and the engine degrades to
the plain fused-window path.  Either way the *emitted* tokens are
bit-identical to non-speculative greedy decode, because acceptance only
keeps drafts that equal the verifier's greedy argmax and the first
mismatch is replaced by that argmax (pinned by
tests/test_spec_decode.py across prefix-cache hits, preemption and
fused windows).

Two proposers, one semantics:

* :func:`propose_ngram` — the host reference implementation (pure
  Python, no jax).  It is the oracle rung of the exactness ladder
  (docs/TESTING.md) and stays the drafting path for
  ``spec_proposer="host"`` engines.
* :func:`device_propose` — the same suffix match vectorized in jnp over
  a device-resident history buffer, so drafting composes into the
  engine's fused draft+verify dispatch with no host materialization of
  candidate drafts.  Pinned token-identical to the host proposer by a
  differential hypothesis property (tests/test_property_serving.py).

:class:`AdaptiveK` closes the loop: a per-request EWMA of observed
acceptance picks the draft depth K (clamped to the scheduler's safe
horizon and snapped to the pow2 verify buckets), collapsing to K=0 —
speculation off, with a periodic 1-token probe — under sustained
rejection instead of thrashing rollbacks.

The verify dispatch and the page rollback
(:meth:`repro.serving.paged_kv.PageAllocator.truncate_to`) live in
:mod:`repro.serving.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence


def _pow2_floor(k: int) -> int:
    return 1 << (max(k, 1).bit_length() - 1)


def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def propose_ngram(history: Sequence[int], k: int, *, max_n: int = 3,
                  min_n: int = 1) -> List[int]:
    """Prompt-lookup drafting: find the *earliest* earlier occurrence of
    the history's last ``n`` tokens (longest ``n`` first, ``max_n`` down
    to ``min_n``) and propose up to ``k`` tokens that followed it.
    Earliest — not most recent — because the match nearest the end has
    the least history left after it: on a looping sequence the latest
    occurrence only ever yields a 1-token draft, while the earliest
    yields the whole period.

    Returns [] when nothing matches — the caller falls back to plain
    decode.  O(n * len(history)) per candidate ``n``; histories are
    bounded by the engine's ``max_len``, so this stays microseconds-cheap
    next to a model dispatch.
    """
    L = len(history)
    if k < 1 or L < min_n + 1:
        return []
    hist = [int(t) for t in history]
    for n in range(min(max_n, L - 1), min_n - 1, -1):
        pattern = hist[L - n:]
        for i in range(L - n):
            if hist[i:i + n] == pattern:
                return hist[i + n:i + n + k]
    return []


def device_propose(history, hist_len, k, *, k_max: int, max_n: int = 3,
                   min_n: int = 1):
    """:func:`propose_ngram` as a jittable jnp suffix match over a
    device-resident history row.

    ``history`` is a fixed-width ``(H,)`` int32 buffer whose first
    ``hist_len`` entries are the sequence's prompt+output history
    (entries past ``hist_len`` are arbitrary — padding or stale tokens
    from a rolled-back draft; the validity mask below keeps them out of
    every match).  ``hist_len`` and ``k`` are traced scalars, so one
    compilation serves every history length and draft depth;
    ``k_max``/``max_n``/``min_n`` are static.

    Returns ``(draft, m)``: a ``(k_max,)`` int32 buffer whose first
    ``m`` entries are the draft (zero-masked past ``m``), with ``m = 0``
    when nothing matches — exactly the cases where the host proposer
    returns ``[]``.  Token-identical to ``propose_ngram(history[:L], k)``
    for every ``min(k, k_max)`` (the differential oracle property,
    tests/test_property_serving.py): same longest-``n``-first,
    earliest-occurrence match, same clip of the draft at the history
    end.
    """
    import jax.numpy as jnp

    H = history.shape[-1]
    idx = jnp.arange(H, dtype=jnp.int32)
    L = jnp.asarray(hist_len, jnp.int32)
    kq = jnp.minimum(jnp.asarray(k, jnp.int32), jnp.int32(k_max))
    found = jnp.bool_(False)
    start = jnp.int32(0)
    for n in range(max_n, min_n - 1, -1):
        # the history's tail n-gram (indices clipped; masked below when
        # L < n so a clipped pattern can never produce a false match)
        pat = history[jnp.clip(L - n + jnp.arange(n), 0, H - 1)]
        eq = jnp.ones((H,), bool)
        for j in range(n):
            eq = eq & (history[jnp.clip(idx + j, 0, H - 1)] == pat[j])
        # a match at i is valid only if the whole n-gram AND at least
        # one continuation token lie strictly inside the history — this
        # also excludes every clipped index above from participating
        valid = eq & (idx + n < L)
        has = jnp.any(valid)
        first = jnp.argmax(valid).astype(jnp.int32)   # earliest match
        take = has & ~found                           # longest n wins
        start = jnp.where(take, first + jnp.int32(n), start)
        found = found | has
    ok = found & (kq >= 1) & (L >= min_n + 1)
    m = jnp.where(ok, jnp.minimum(kq, L - start), 0).astype(jnp.int32)
    offs = jnp.arange(k_max, dtype=jnp.int32)
    draft = history[jnp.clip(start + offs, 0, H - 1)]
    draft = jnp.where(offs < m, draft, 0).astype(jnp.int32)
    return draft, m


@dataclass
class SpecStats:
    """Acceptance accounting for the engine's ``accept_rate`` /
    ``dispatches_per_token`` observables."""
    drafted: int = 0       # draft tokens proposed to the verifier
    accepted: int = 0      # draft tokens the verifier kept
    verifies: int = 0      # verification dispatches run
    rollbacks: int = 0     # verifies that released rejected pages
    k_requested: int = 0   # summed draft depth K over verifies

    @property
    def accept_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def k_mean(self) -> float:
        return self.k_requested / max(self.verifies, 1)


@dataclass
class AdaptiveK:
    """Per-request draft-depth controller: an EWMA ``rate`` of the
    observed accepted/requested ratio, mapped to the draft depth that
    ratio earns.

    For geometric acceptance at per-token rate r the expected accepted
    prefix of an infinite draft is r/(1-r), so that is the target depth:
    r=0.75 -> 3, r=0.9 -> 9, r -> 1 saturates at the engine's ``k_max``.
    Below r=0.5 the target is 0 — drafting is priced off entirely
    (collapse instead of rollback thrash) — and every ``probe_every``
    disabled windows a single 1-token probe runs so a sequence that
    *becomes* repetitive can re-enable itself (one accepted probe lifts
    the EWMA back over the threshold).
    """
    alpha: float = 0.3     # EWMA gain per observed verify
    rate: float = 0.75     # optimistic prior: try drafting, learn fast
    probe_every: int = 8   # disabled windows between 1-token probes
    idle: int = 0          # disabled windows since the last probe

    def observe(self, requested: int, accepted: int):
        """Fold one verify's outcome (K requested, a accepted) into the
        EWMA.  A no-draft verify (requested=0) teaches nothing."""
        if requested < 1:
            return
        self.rate += self.alpha * (accepted / requested - self.rate)
        self.idle = 0

    def target(self, k_max: int) -> int:
        """Draft depth the current EWMA earns, in [0, k_max].  Calling
        this while disabled advances the probe clock — the engine calls
        it once per window per slot."""
        r = min(self.rate, 0.999)
        t = int(r / (1.0 - r))
        if t < 1:
            self.idle += 1
            if self.idle >= self.probe_every:
                self.idle = 0
                return 1               # periodic re-enable probe
            return 0
        return min(t, k_max)


class NGramSpec:
    """Per-engine speculative-decoding policy: draft depth, n-gram
    bounds, adaptive-K state and acceptance stats.  Weightless — the
    proposer never touches model state, only the request's token
    history."""

    def __init__(self, k: int = 8, max_n: int = 3, min_n: int = 1,
                 adaptive: bool = False, alpha: float = 0.3,
                 r0: float = 0.75, probe_every: int = 8):
        assert k >= 1 and max_n >= min_n >= 1
        self.k = k
        self.max_n = max_n
        self.min_n = min_n
        self.adaptive = adaptive
        self.alpha = alpha
        self.r0 = r0
        self.probe_every = probe_every
        self.stats = SpecStats()
        self._ak: Dict[str, AdaptiveK] = {}

    # -- adaptive-K state --------------------------------------------------
    def state(self, key: str) -> AdaptiveK:
        st = self._ak.get(key)
        if st is None:
            st = self._ak[key] = AdaptiveK(alpha=self.alpha, rate=self.r0,
                                           probe_every=self.probe_every)
        return st

    def rate_for(self, key: str) -> float:
        """The key's acceptance EWMA (the prior before any verify) —
        the e = 1 + r*K input of the engine's priced worth-it gate.
        The engine keys controllers by tenant: acceptance statistics
        are a workload property, so they carry across a tenant's
        requests instead of re-ramping from the prior each time."""
        return self.state(key).rate

    def draft_k(self, key: str, horizon: int) -> int:
        """Draft depth for this window: the adaptive target (or the
        fixed ``k``), clamped to the safe horizon (a verify may write at
        most ``horizon - 1`` draft positions — the last emitted token's
        KV plus K drafts all land inside the reserved window) and, when
        adaptive, snapped to the pow2 verify buckets (K+1 a power of
        two) so adaptation never compiles a new verify width."""
        cap = min(self.k, horizon - 1)
        if cap < 1:
            return 0
        if not self.adaptive:
            return cap
        t = self.state(key).target(self.k)
        if t < 1:
            return 0
        t = min(t, cap)
        up = _pow2_ceil(t + 1) - 1       # optimistic: round K+1 up
        return up if up <= cap else _pow2_floor(cap + 1) - 1

    def observe(self, key: str, requested: int, accepted: int):
        self.state(key).observe(requested, accepted)

    def forget(self, key: str):
        """Drop a controller's state (back to the optimistic prior)."""
        self._ak.pop(key, None)

    # -- host reference proposer (the oracle rung) -------------------------
    def propose(self, prompt: Sequence[int], tokens: Sequence[int],
                k_cap: int) -> List[int]:
        """Draft up to ``min(self.k, k_cap)`` tokens from the sequence's
        own prompt+output history."""
        k = min(self.k, k_cap)
        if k < 1:
            return []
        history = [int(t) for t in prompt] + [int(t) for t in tokens]
        return propose_ngram(history, k, max_n=self.max_n,
                             min_n=self.min_n)

    def accept(self, draft: Sequence[int],
               greedy: Sequence[int]) -> List[int]:
        """Greedy acceptance rule: keep the longest draft prefix that
        matches the verifier's argmax at each position, then append the
        verifier's own token at the first mismatch (or the bonus token
        when everything matched).  The result is therefore *exactly*
        the token sequence non-speculative greedy decode would emit —
        speculation changes dispatch count, never tokens."""
        a = 0
        while a < len(draft) and int(greedy[a]) == int(draft[a]):
            a += 1
        emitted = [int(t) for t in draft[:a]] + [int(greedy[a])]
        self.stats.drafted += len(draft)
        self.stats.accepted += a
        self.stats.verifies += 1
        return emitted
