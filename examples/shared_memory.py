"""Case study II (Swallow §X-B): shared memory emulated on distributed
memory — single controller vs address%n striping, and the overlay made
load-bearing: copy-on-write prefix sharing of KV pages.

Part 1 runs batches of random reads/writes against both stores, checks
they implement the same memory semantics, and prints the
traffic/contention model that makes the paper prefer striping.

Part 2 is the "more elegant strategy" grown up: the same address%n
striping carries the serving engine's KV pages, and the prefix cache
(:mod:`repro.serving.prefix_cache`) overlays *sharing* on top — requests
with a common system prompt read the same physical pages through their
block tables, copy-on-write protects the divergence page, and greedy
tokens stay bit-identical to a cache-less run.

Run:  PYTHONPATH=src python examples/shared_memory.py
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core.memory_server import (SingleController, StripedStore,
                                      striped_owner)


def striping_demo():
    size = 1 << 16
    n_nodes = 16
    n_access = 4096
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    addrs = jax.random.randint(k1, (n_access,), 0, size)
    vals = jax.random.normal(k2, (n_access,))

    single = SingleController(size)
    striped = StripedStore(size)

    single.write(addrs, vals)
    striped.write(addrs, vals)
    r1 = single.read(addrs)
    r2 = striped.read(addrs)
    assert jnp.allclose(r1, r2), "stores disagree"
    print(f"semantics check OK over {n_access} random accesses")

    print("\nowner mapping (address % n):",
          [int(striped_owner(a, n_nodes)) for a in range(8)])

    tm_s = single.traffic_model(n_access, n_nodes)
    tm_d = striped.traffic_model(n_access, n_nodes)
    print("\n                      single-controller   striped")
    print(f"remote fraction       {tm_s['remote_fraction']:<19.3f}"
          f"{tm_d['remote_fraction']:.3f}")
    print(f"contention points     {tm_s['contention_points']:<19d}"
          f"{tm_d['contention_points']}")
    print("\n-> striping removes the serialization point: remote traffic is "
          "the same,\n   but it spreads over n controllers instead of one "
          "(the paper's argument).")

    # micro-timing
    for name, store in (("single", single), ("striped", striped)):
        f = jax.jit(lambda a: store.read(a))
        f(addrs)
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(f(addrs))
        dt = (time.perf_counter() - t0) / 10
        print(f"{name:>8}: {n_access / dt / 1e6:.1f} M reads/s")


def prefix_sharing_demo():
    """The overlay in anger: three requests sharing a 10-token system
    prompt served through the prefix cache, checked token-for-token
    against a cache-less engine."""
    from repro.configs import get_tiny_config
    from repro.models import lm
    from repro.serving import PagedEngine

    cfg = get_tiny_config("tiny-100m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    S, gen, ps = 14, 4, 4
    system = np.asarray(jax.random.randint(jax.random.PRNGKey(42), (10,),
                                           2, cfg.vocab_size), np.int32)
    prompts = []
    for i in range(3):
        user = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i),
                                             (S - 10,), 2, cfg.vocab_size),
                          np.int32)
        prompts.append(np.concatenate([system, user]))

    def serve(prefix_cache):
        eng = PagedEngine(cfg, params, max_batch=3, page_size=ps,
                          n_pages=32, max_len=S + gen,
                          prefix_cache=prefix_cache)
        for i, p in enumerate(prompts):
            eng.submit(p, gen, rid=f"r{i}")
        finished = eng.run()
        return eng, {r.rid: list(r.tokens) for r in finished}

    eng_off, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off, "sharing must not change a single token"
    m = eng_on.metrics()
    print(f"\n3 requests, shared 10-token system prompt over {ps}-token "
          f"pages (address%n striped):")
    print(f"  tokens identical with cache on/off: "
          f"{toks_on == toks_off}")
    print(f"  prefill tokens computed: {m['prefill_tokens']} (vs "
          f"{eng_off.metrics()['prefill_tokens']} without sharing)")
    print(f"  hit rate {m['prefix_hit_rate'] * 100:.0f}%, "
          f"{m['cow_copies']} copy-on-write page copies, "
          f"{m['shared_pages']} pages owned by the radix tree, "
          f"{m['bytes_deduped']} KV bytes deduplicated")
    print("-> the paper's DSM overlay, load-bearing: one physical page "
          "serves every tenant\n   that shares its tokens; divergence "
          "inside a page is a COW copy, never a rewrite.")


def main():
    striping_demo()
    print("\n=== §X-B overlay, applied: KV prefix sharing "
          "(docs/PREFIX_CACHE.md) ===")
    prefix_sharing_demo()


if __name__ == "__main__":
    main()
