"""Architecture registry: ``get_config("qwen3-14b")`` / ``--arch qwen3-14b``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (SHAPES, MLAConfig, ModelConfig, MoEConfig,
                                ShapeConfig, cell_is_runnable)

_ARCH_MODULES: Dict[str, str] = {
    "qwen3-14b": "repro.configs.qwen3_14b",
    "minitron-8b": "repro.configs.minitron_8b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    # non-assigned utility configs
    "tiny-100m": "repro.configs.tiny_100m",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a != "tiny-100m"]


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_tiny_config(name: str) -> ModelConfig:
    """Reduced same-family config for smoke tests."""
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).tiny()


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def runnable_cells():
    """Yield (arch_name, shape) for every runnable dry-run cell."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_is_runnable(cfg, shape)
            if ok:
                yield arch, shape.name


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "ShapeConfig", "SHAPES",
    "get_config", "get_tiny_config", "list_archs", "runnable_cells",
    "cell_is_runnable", "ASSIGNED_ARCHS",
]
