"""Swallow §III-A + §X-B: the KV cache as a striped distributed store.

What is reproduced: the paper's "more elegant strategy" — an address
space striped ``address % n`` over per-node controllers — applied to KV
pages.  Physical page ``p`` is owned by node ``striped_owner(p, n)``
(:mod:`repro.core.memory_server` is the single source of truth for the
mapping), and the allocator hands a request's *logical* page ``j`` a
physical page on node ``j % n`` whenever one is free, so a sequence's
cache reads fan out over the mesh exactly like the paper's memory-server
traffic instead of hammering one contention point.

What is extrapolated: Swallow stores 32-bit words; here a "word" is a
(page_size, Kv*hd) KV page and the striping axis is the mesh "model"
dimension the pools are sharded over.  Page 0 is reserved as the null
page — padded block-table slots point at it so the paged attention
kernel always DMAs a real page and masks its contribution to exactly 0.

Sharing (§X-B's shared-memory overlay made real): every allocated page
carries a refcount.  A freshly allocated page has refcount 1 (its
owner's reference); :meth:`PageAllocator.share` adds a reference (a
prefix-cache node, or a second request reusing a cached prefix) and
:meth:`PageAllocator.release_page` drops one — the page returns to the
free list only at refcount 0, so shared pages survive their original
owner's completion or preemption.  The null page is never shared and
never refcounted.  ``reclaim`` is an optional callback (wired to
:meth:`repro.serving.prefix_cache.PrefixCache.evict`) invoked when the
free list runs short, so cold cache pages are evicted before any tenant
is preempted.

Node failure (§VIII's fault model applied to the store): when a node of
the striped DSM dies, every physical page whose stripe lands on it is
*quarantined* by :meth:`PageAllocator.fail_node` — pulled from the free
lists immediately, and marked so that pages still referenced (by a
request's block table or the prefix-cache tree) route to the quarantine
pool instead of the free list when their last reference drops.  A
quarantined page is never handed out again until
:meth:`PageAllocator.restore_node` re-joins the node, and the
conservation invariant is extended to a three-way partition: free +
allocated + quarantined-free == n_pages - 1.  The null page is a device
convention (its contribution is masked to zero), not striped state, so
it survives any node's failure.

Pure host-side logic: no jax imports, unit-testable anywhere.  The
device-side half (pools + block tables) lives in
:mod:`repro.serving.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.core.memory_server import striped_owner

NULL_PAGE = 0


@dataclass
class PageAllocator:
    """Fixed-size-page allocator over a striped pool.

    ``n_pages`` counts physical pages including the reserved null page;
    ``n_nodes`` is the striping width (mesh "model" extent).
    """
    n_pages: int
    page_size: int
    n_nodes: int = 1
    held: Dict[str, List[int]] = field(default_factory=dict)
    refcount: Dict[int, int] = field(default_factory=dict)
    reclaim: Optional[Callable[[int], int]] = None
    _free_by_node: List[List[int]] = field(default_factory=list)
    # fault plane: pages striped to a dead node (never re-allocated until
    # the node restores) and the set of currently-failed nodes
    quarantined: Set[int] = field(default_factory=set)
    failed_nodes: Set[int] = field(default_factory=set)
    # telemetry: occupancy/capacity exported as live gauge callables on
    # the owning engine's MetricsRegistry (or a private one)
    registry: Optional[object] = None

    def __post_init__(self):
        assert self.n_pages > 1, "need at least one page beyond the null page"
        if self.n_nodes > self.n_pages - 1:
            # a node whose stripe holds zero allocatable pages starves its
            # controller and skews conservation accounting (the paper's
            # striping assumes every node owns part of the address space)
            raise ValueError(
                f"n_nodes={self.n_nodes} > allocatable pages "
                f"{self.n_pages - 1}: every node needs at least one page "
                f"in its stripe (raise n_pages or lower n_nodes)")
        self._free_by_node = [[] for _ in range(self.n_nodes)]
        # LIFO free lists per owner node; page 0 is never handed out
        for p in range(self.n_pages - 1, NULL_PAGE, -1):
            self._free_by_node[self.owner(p)].append(p)
        if self.registry is None:
            from repro.serving.telemetry import MetricsRegistry
            self.registry = MetricsRegistry()
        # registered as callables: the registry snapshot samples the
        # allocator live instead of caching stale occupancy
        self.registry.register_gauge("pages_in_use",
                                     lambda: self.pages_in_use)
        self.registry.register_gauge("free_pages", lambda: self.free_pages)
        self.registry.register_gauge("pages_quarantined_now",
                                     lambda: self.pages_quarantined)
        self.registry.register_gauge("allocatable_pages",
                                     lambda: self.allocatable_pages)

    # -- the striping rule (one source of truth) ---------------------------
    def owner(self, page: int) -> int:
        """Node owning physical ``page`` — delegates to the paper's
        address%n rule in core/memory_server."""
        return striped_owner(page, self.n_nodes)

    # -- accounting --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free_by_node)

    @property
    def pages_in_use(self) -> int:
        """Distinct allocated pages — a page shared by N requests and the
        prefix cache counts once (refcount, not held-list, is truth)."""
        return len(self.refcount)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV entries.  Zero tokens
        need zero pages — a zero-length request is allocation-free, and
        the engine rejects empty prompts at submit anyway (a prompt must
        hold at least one token to prefill a first logit)."""
        if n_tokens <= 0:
            return 0
        return -(-n_tokens // self.page_size)

    def refcount_of(self, page: int) -> int:
        return self.refcount.get(page, 0)

    @property
    def pages_quarantined(self) -> int:
        """Pages currently striped to a dead node (allocated or idle)."""
        return len(self.quarantined)

    @property
    def allocatable_pages(self) -> int:
        """Pool capacity excluding the null page and the quarantine —
        what admission/feasibility checks must size against while a node
        is down."""
        return self.n_pages - 1 - len(self.quarantined)

    def occupancy_by_node(self) -> List[int]:
        """Allocated pages per owner node (load-balance observable).
        Shared pages count once — this is physical occupancy."""
        counts = [0] * self.n_nodes
        for p in self.refcount:
            counts[self.owner(p)] += 1
        return counts

    def check_conservation(self) -> bool:
        """Every non-null page is on exactly one side of a three-way
        partition: free list (refcount 0, healthy node), allocated
        (refcount >= 1 — possibly on a dead node, awaiting recovery), or
        quarantined-free (refcount 0 on a dead node, parked until
        :meth:`restore_node`)."""
        free = [p for f in self._free_by_node for p in f]
        if len(free) != len(set(free)):
            return False
        if set(free) & set(self.refcount):
            return False
        if set(free) & self.quarantined:
            return False              # quarantined pages never circulate
        if NULL_PAGE in self.refcount or NULL_PAGE in free \
                or NULL_PAGE in self.quarantined:
            return False
        if any(c < 1 for c in self.refcount.values()):
            return False
        quar_free = len(self.quarantined - set(self.refcount))
        return len(free) + len(self.refcount) + quar_free \
            == self.n_pages - 1

    # -- sharing (refcounts) ----------------------------------------------
    def share(self, page: int) -> None:
        """Add a reference to an allocated page (prefix-cache node or a
        second request reusing it).  The null page is never shared."""
        if page == NULL_PAGE:
            raise ValueError("the null page cannot be shared")
        if page in self.quarantined:
            # a dead node's page may be awaiting recovery but never gains
            # new readers — the "never re-served" half of the fault plane
            raise ValueError(f"page {page} is quarantined; cannot share")
        if self.refcount.get(page, 0) < 1:
            raise ValueError(f"page {page} is not allocated; cannot share")
        self.refcount[page] += 1

    def release_page(self, page: int) -> bool:
        """Drop one reference; free the page at refcount 0.  Returns True
        when the page actually returned to the free list.  Releasing an
        unallocated page is a double free and raises."""
        c = self.refcount.get(page, 0)
        if c < 1:
            raise ValueError(f"double free of page {page}")
        if c == 1:
            del self.refcount[page]
            if page in self.quarantined:
                return False          # parked until restore_node
            self._free_by_node[self.owner(page)].append(page)
            return True
        self.refcount[page] = c - 1
        return False

    # -- node failure / re-join (the fault plane's allocator half) ---------
    def fail_node(self, node: int) -> Set[int]:
        """Quarantine every physical page whose ``striped_owner`` stripe
        lands on ``node``.  Idle pages leave the free list immediately;
        pages still referenced (request block tables, prefix-cache tree)
        stay in ``refcount`` until their holders release them — the
        caller (engine recovery) is responsible for resetting those
        holders — and :meth:`release_page` then parks them in quarantine
        instead of recirculating them.  Returns the newly quarantined
        set.  Idempotent per node.  The null page is a device convention
        (masked, replicated), never quarantined."""
        if not 0 <= node < self.n_nodes:
            raise ValueError(f"node {node} outside stripe width "
                             f"{self.n_nodes}")
        if node in self.failed_nodes:
            return set()
        self.failed_nodes.add(node)
        newly = {p for p in range(1, self.n_pages) if self.owner(p) == node}
        # this node's refcount-0 pages are exactly its free list: pull
        # them from circulation in one move
        self._free_by_node[node] = []
        self.quarantined |= newly
        return newly

    def restore_node(self, node: int) -> int:
        """Re-join: the node's quarantined pages leave quarantine; those
        with no outstanding references return to its free list (LIFO,
        high to low, matching ``__post_init__``).  A page somehow still
        referenced simply resumes normal refcount life — it frees
        wherever its last release lands.  Returns how many pages
        re-entered the free list."""
        if node not in self.failed_nodes:
            return 0
        self.failed_nodes.discard(node)
        mine = {p for p in self.quarantined if self.owner(p) == node}
        self.quarantined -= mine
        restored = 0
        for p in sorted(mine, reverse=True):
            if p not in self.refcount:
                self._free_by_node[node].append(p)
                restored += 1
        return restored

    # -- alloc / grow / free ----------------------------------------------
    def _take(self, want_node: int) -> Optional[int]:
        """Pop a free page on ``want_node``, falling back to the richest
        node (work-conserving when the stripe is fragmented)."""
        if self._free_by_node[want_node]:
            return self._free_by_node[want_node].pop()
        best = max(range(self.n_nodes),
                   key=lambda n: len(self._free_by_node[n]))
        if self._free_by_node[best]:
            return self._free_by_node[best].pop()
        return None

    def _ensure(self, n: int) -> None:
        """Ask the reclaimer (prefix-cache LRU eviction) for pages when
        the free list cannot cover ``n`` — cold cache pages go before any
        tenant is preempted."""
        if n > self.free_pages and self.reclaim is not None:
            self.reclaim(n - self.free_pages)

    def alloc(self, rid: str, n: int,
              prefix: Optional[Sequence[int]] = None) -> Optional[List[int]]:
        """All-or-nothing: ``n`` *fresh* pages for ``rid``.  ``prefix``
        is an already-shared page run (refcounts bumped by the caller via
        the prefix cache) that fills logical pages 0..len(prefix)-1, so
        fresh logical page j lands on node (len(prefix)+j) % n_nodes.
        Returns the full page list (prefix + fresh) or None."""
        if rid in self.held:
            return None
        self._ensure(n)
        if n > self.free_pages:
            return None
        off = len(prefix) if prefix else 0
        pages = list(prefix) if prefix else []
        for j in range(n):
            p = self._take(striped_owner(off + j, self.n_nodes))
            assert p is not None
            self.refcount[p] = 1
            pages.append(p)
        self.held[rid] = pages
        return pages

    def grow(self, rid: str, n: int = 1) -> bool:
        """Append ``n`` pages to an existing allocation (decode crossing
        a page boundary)."""
        self._ensure(n)
        if n > self.free_pages:
            return False
        pages = self.held[rid]
        for _ in range(n):
            p = self._take(striped_owner(len(pages), self.n_nodes))
            assert p is not None
            self.refcount[p] = 1
            pages.append(p)
        return True

    def reserve(self, rid: str, n_tokens: int) -> int:
        """Horizon pre-reservation: grow ``rid`` (best-effort under page
        pressure) until its pages cover every write position below
        ``n_tokens``, so the block-table row is fixed for a whole fused
        decode window.  Returns the token capacity actually reserved —
        the caller shrinks the window to ``capacity - pos`` when the
        pool runs dry instead of preempting mid-window."""
        need = self.pages_for(n_tokens)
        while len(self.held[rid]) < need and self.grow(rid):
            pass
        return len(self.held[rid]) * self.page_size

    def truncate_to(self, rid: str, n_tokens: int) -> int:
        """Speculative rollback: shrink ``rid``'s allocation to exactly
        the pages covering token positions below ``n_tokens`` (whole
        rejected/over-reserved tail pages are released).  Only this
        request's references are dropped — a tail page another holder
        shares survives via its refcount (``release_page`` semantics),
        and the null page is never involved because it is never held.
        KV slots past ``n_tokens`` inside the *kept* tail page are not
        wiped: they are masked by position and overwritten before the
        sequence's write position ever reaches them (the same argument
        as COW page copies).  Returns how many pages actually returned
        to the free list."""
        pages = self.held[rid]
        keep = -(-max(n_tokens, 0) // self.page_size)
        freed = 0
        while len(pages) > keep:
            if self.release_page(pages.pop()):
                freed += 1
        return freed

    def free(self, rid: str) -> int:
        """Release every reference ``rid`` holds; returns how many pages
        actually returned to the free list (shared pages survive until
        their last reference — the prefix cache's or another request's —
        is dropped)."""
        pages = self.held.pop(rid, [])
        freed = 0
        for p in pages:
            if self.release_page(p):
                freed += 1
        return freed
