"""Grok-1 314B [hf:xai-org/grok-1; unverified].

MoE: 64L, d_model=6144, 48 Q heads / 8 KV heads, vocab=131072, 8 experts
top-2 (d_ff_expert=32768), GeGLU, attention + final logit softcap 30,
sqrt(d) embedding scale.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768, n_shared=0,
                  capacity_factor=1.25, score_func="softmax"),
    attn_softcap=30.0,
    logit_softcap=30.0,
    act="gelu",
    gated_ffn=True,
    embed_scale=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=0),
        param_dtype="float32", attn_block_q=16, attn_block_kv=32)
