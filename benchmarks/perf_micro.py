"""Micro-benchmarks: wall-time per call for the hot paths on this host
(CPU container — the numbers calibrate the harness, not the TPU target)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _timeit(fn, n=3) -> float:
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def micro_train_steps() -> List[Row]:
    from repro.configs import get_tiny_config
    from repro.models import lm
    rows = []
    for arch in ("qwen3-14b", "deepseek-v3-671b", "rwkv6-1.6b",
                 "recurrentgemma-2b"):
        cfg = get_tiny_config(arch)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 64
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size) \
            if cfg.embed_inputs else jax.random.normal(
                k1, (B, S, cfg.d_model))
        batch = {"tokens": tokens,
                 "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
                 "mask": jnp.ones((B, S), jnp.float32)}
        if cfg.mrope_sections is not None:
            batch["positions"] = lm.default_positions(cfg, B, S)
        f = jax.jit(jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0]))
        us = _timeit(lambda: jax.block_until_ready(f(params)))
        tok_s = B * S / (us / 1e6)
        rows.append((f"micro/train_grad_{arch}", us, f"{tok_s:.0f} tok/s"))
    return rows


def micro_kernels() -> List[Row]:
    from repro.kernels import ops
    rows = []
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 3)
    B, S, H, hd = 1, 512, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    kk = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, kk, v, block_q=128, block_kv=128)))
    flops = 4 * B * H * S * S * hd
    rows.append(("micro/flash_attention_512", us,
                 f"{flops/us*1e-3:.2f} GFLOP/s-interp"))
    E, C, D, F = 4, 128, 256, 512
    x = jax.random.normal(ks[0], (E, C, D))
    w = jax.random.normal(ks[1], (E, D, F))
    us = _timeit(lambda: jax.block_until_ready(ops.moe_gemm(x, w)))
    rows.append(("micro/moe_gemm_4x128x256x512", us,
                 f"{2*E*C*D*F/us*1e-3:.2f} GFLOP/s-interp"))
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 512, 256))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (2, 512, 256)) * 0.1
    h0 = jnp.zeros((2, 256))
    us = _timeit(lambda: jax.block_until_ready(ops.rglru_scan(a, b, h0)))
    rows.append(("micro/rglru_scan_512x256", us, "seq-scan"))
    return rows


def micro_serve() -> List[Row]:
    """Serving hot path: one paged decode step, the paged attention
    kernel vs its ref oracle (incl. the block_t page-sweep hook), and a
    fused K-step window vs K per-step dispatches with a host sync each —
    the host↔device ping-pong the fused engine eliminates."""
    import numpy as np
    from repro import steps as steps_mod
    from repro.configs import get_tiny_config
    from repro.kernels import ops, ref
    from repro.models import lm

    rows = []
    # -- paged decode attention: pallas(-interp) vs ref, block_t sweep --
    B, H, hd, Kv, ps, nmax = 4, 8, 64, 2, 8, 4
    P = 1 + B * nmax
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pages = jax.random.normal(ks[1], (P, ps, Kv, hd))
    v_pages = jax.random.normal(ks[2], (P, ps, Kv, hd))
    bt = (1 + jnp.arange(B * nmax, dtype=jnp.int32)).reshape(B, nmax)
    pos = jnp.full((B,), nmax * ps - 1, jnp.int32)
    ref_fn = jax.jit(ref.paged_decode_attention)
    us = _timeit(lambda: jax.block_until_ready(
        ref_fn(q, k_pages, v_pages, bt, pos)))
    rows.append(("micro/paged_attn_ref_oracle", us, "gather+dense"))
    for block_t in (ps, 2 * ps, 4 * ps):
        us = _timeit(lambda: jax.block_until_ready(
            ops.paged_decode_attention(q, k_pages, v_pages, bt, pos,
                                       block_t=block_t)))
        rows.append((f"micro/paged_attn_kernel_bt{block_t}", us,
                     f"{block_t // ps} pages/grid-step"))

    # -- engine-shaped decode: fused scan vs per-step dispatches --------
    cfg = get_tiny_config("tiny-100m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    Bb, S, K = 4, 16, 8
    ps2 = 8
    nmax2 = -(-(S + 2 * K) // ps2)
    n_pages = Bb * nmax2 + 1
    pools = lm.init_paged_caches(cfg, n_pages=n_pages, page_size=ps2)
    prefill = jax.jit(steps_mod.make_paged_prefill_step(cfg))
    block = np.full((Bb, nmax2), 0, np.int32)
    for b in range(Bb):
        row = 1 + b * nmax2 + np.arange(nmax2, dtype=np.int32)
        block[b] = row
        prompt = jax.random.randint(jax.random.PRNGKey(b), (1, S), 2,
                                    cfg.vocab_size)
        _, pools = prefill(params, prompt, pools, jnp.asarray(block[b]))
    block = jnp.asarray(block)
    tok0 = jnp.ones((Bb, 1), jnp.int32)
    pos0 = jnp.full((Bb,), S, jnp.int32)
    active = jnp.ones((Bb,), jnp.int32)
    serve1 = jax.jit(steps_mod.make_paged_serve_step(cfg))
    scan = jax.jit(steps_mod.make_paged_serve_scan(cfg),
                   static_argnames=("k",))

    def perstep():
        tok, p, pl = tok0, pos0, pools
        for _ in range(K):
            tok, _, pl = serve1(params, tok, pl, block, p)
            np.asarray(tok)          # the per-token host sync
            p = p + 1
        return tok

    def fused():
        toks, tok, p, pl = scan(params, tok0, pools, block, pos0, active,
                                k=K)
        np.asarray(toks)             # one host sync per window
        return tok

    paged_us = _timeit(lambda: jax.block_until_ready(
        serve1(params, tok0, pools, block, pos0)[0]))
    rows.append(("micro/paged_decode_step_b4", paged_us,
                 f"{Bb / (paged_us / 1e6):.0f} tok/s"))
    per_us = _timeit(perstep)
    fus_us = _timeit(fused)
    rows.append((f"micro/serve_perstep_{K}x", per_us,
                 f"{Bb * K / (per_us / 1e6):.0f} tok/s"))
    # speedup lives in the derived field: us_per_call stays microseconds
    rows.append((f"micro/serve_fused_window_k{K}", fus_us,
                 f"{Bb * K / (fus_us / 1e6):.0f} tok/s, "
                 f"{per_us / fus_us:.2f}x vs per-step"))
    return rows


def micro_data_pipeline() -> List[Row]:
    from repro.data import pipeline as dl
    cfg = dl.DataConfig(vocab_size=151936, seq_len=4096, global_batch=16)
    src = dl.make_source(cfg)
    us = _timeit(lambda: src.batch(3), n=3)
    rows = [("micro/data_batch_16x4096", us,
             f"{16*4096/(us/1e6)/1e6:.2f} Mtok/s")]
    return rows


def micro_checkpoint(tmp="/tmp/bench_ckpt") -> List[Row]:
    import shutil
    from repro.configs import get_tiny_config
    from repro.models import lm
    from repro.runtime import checkpoint as ckpt
    cfg = get_tiny_config("qwen3-14b").replace(d_model=256, d_ff=512,
                                               n_layers=4)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    shutil.rmtree(tmp, ignore_errors=True)
    us = _timeit(lambda: ckpt.save(tmp, 1, {"params": params}), n=3)
    rows = [("micro/checkpoint_save", us,
             f"{n_bytes/(us/1e6)/1e9:.2f} GB/s")]
    shutil.rmtree(tmp, ignore_errors=True)
    return rows
