"""Fault-tolerant training loop: checkpoint/restart, health, stragglers.

The loop is host-side nOS (Swallow C8): it owns placement, persistence and
recovery so the model code never sees any of it.  Deterministic data
(seed, step) + atomic checkpoints give exactly-once step semantics across
restarts; an injectable failure hook lets tests exercise the full
fail->detect->restore->reshard path on CPU.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import steps as steps_mod
from repro.data import pipeline as data_lib
from repro.models import lm
from repro.optim import adam as adam_lib
from repro.parallel.sharding import use_sharding
from repro.runtime import checkpoint as ckpt_lib
from repro.runtime import elastic as elastic_lib
from repro.runtime.health import (HeartbeatMonitor, RecoveryPolicy,
                                  StragglerDetector)


@dataclass
class TrainJobConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    peak_lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    metrics_path: Optional[str] = None


def run(cfg, shape, mesh=None, rules=None, job: TrainJobConfig = None,
        failure_hook: Optional[Callable[[int], None]] = None,
        impl: Optional[str] = None) -> Dict[str, Any]:
    """Train ``cfg`` at ``shape`` on ``mesh`` (None = single device)."""
    job = job or TrainJobConfig()
    adam_cfg = steps_mod.adam_config_for(cfg)
    schedule = lambda s: adam_lib.warmup_cosine(
        s, peak_lr=job.peak_lr, warmup=job.warmup, total=job.steps)

    data_cfg = data_lib.DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=shape.seq_len,
                                   global_batch=shape.global_batch,
                                   seed=job.seed)
    source = data_lib.make_source(data_cfg)

    with use_sharding(mesh, rules) as env:
        # --- state init / restore ----------------------------------------
        start_step = 0
        if job.ckpt_dir and ckpt_lib.latest(job.ckpt_dir):
            p_shape = steps_mod.abstract_params(cfg)
            o_shape = steps_mod.abstract_opt_state(cfg, adam_cfg, p_shape)
            shardings = None
            if env is not None:
                ps, os_ = elastic_lib.state_shardings(cfg, adam_cfg, env)
                shardings = {"params": ps, "opt": os_}
            start_step, state = ckpt_lib.restore(
                job.ckpt_dir, {"params": p_shape, "opt": o_shape},
                shardings=shardings)
            params, opt_state = state["params"], state["opt"]
        else:
            key = jax.random.PRNGKey(job.seed)
            params = lm.init_params(key, cfg)
            opt_state = adam_lib.init(params, adam_cfg)
            if env is not None:
                ps, os_ = elastic_lib.state_shardings(cfg, adam_cfg, env)
                params = jax.device_put(params, ps)
                opt_state = jax.device_put(opt_state, os_)

        step_fn = jax.jit(
            steps_mod.make_train_step(cfg, adam_cfg, schedule, impl=impl),
            donate_argnums=(0, 1))

        # --- runtime services ----------------------------------------------
        nodes = [f"host{i}" for i in range(max(1, jax.process_count()))]
        hb = HeartbeatMonitor(nodes, timeout_s=300.0)
        straggler = StragglerDetector(nodes)
        ckpt = ckpt_lib.AsyncCheckpointer(job.ckpt_dir, job.keep_last) \
            if job.ckpt_dir else None
        metrics_log = []
        prefetch = data_lib.Prefetcher(source, start_step=start_step)

        t_loop = time.time()
        last = {}
        try:
            for step, host_batch in prefetch:
                if step >= job.steps:
                    break
                if failure_hook is not None:
                    failure_hook(step)   # tests: raise to simulate a crash
                t0 = time.time()
                batch = jax.tree.map(lambda a: jax.numpy.asarray(a),
                                     host_batch)
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                dt = time.time() - t0
                hb.beat(nodes[0])
                evict = straggler.observe({nodes[0]: dt})
                if evict:
                    metrics["stragglers"] = len(evict)
                if (step + 1) % job.log_every == 0 or step == start_step:
                    last = {k: float(v) for k, v in metrics.items()}
                    last.update(step=step, sec_per_step=dt)
                    metrics_log.append(last)
                    print(f"step {step:6d} loss={last.get('loss', 0):.4f} "
                          f"gnorm={last.get('grad_norm', 0):.3f} "
                          f"{dt:.2f}s/step")
                if ckpt and (step + 1) % job.ckpt_every == 0:
                    ckpt.save(step + 1,
                              {"params": params, "opt": opt_state})
        finally:
            prefetch.close()
            if ckpt:
                ckpt.wait()

        if ckpt and job.steps > 0:
            ckpt.save(job.steps, {"params": params, "opt": opt_state})
            ckpt.wait()
        if job.metrics_path:
            os.makedirs(os.path.dirname(job.metrics_path) or ".",
                        exist_ok=True)
            with open(job.metrics_path, "w") as f:
                json.dump(metrics_log, f, indent=1)
        return {"final_metrics": last, "history": metrics_log,
                "params": params, "opt_state": opt_state,
                "wall_s": time.time() - t_loop}
