"""Qwen3-14B [hf:Qwen/Qwen3-8B family; hf-verified].

Dense decoder: 40L, d_model=5120, 40 Q heads / 8 KV heads (GQA), d_ff=17408,
vocab=151936, qk-norm on per-head q/k, SwiGLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
    gated_ffn=True,
)


def tiny() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_block_q=16, attn_block_kv=32)
