"""Griffin recurrent block: temporal conv1d + RG-LRU gated linear recurrence.

Recurrence (Griffin, arXiv:2402.19427):
    r_t = sigmoid(blockdiag(W_a) u_t + b_a)          (recurrence gate)
    i_t = sigmoid(blockdiag(W_x) u_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t            (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The block: x -> [linear gate branch -> GeLU] * [linear -> conv1d -> RG-LRU]
           -> linear out.

Implementations: ref = lax.scan over time (oracle); blocked = log-depth
``associative_scan`` over the sequence; pallas = chunked TPU kernel.
State is O(width) per sequence — this is what makes recurrentgemma
long_500k-eligible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.parallel.sharding import logical_constraint

C_FACTOR = 8.0


class RGLRUCache(NamedTuple):
    h: jnp.ndarray           # (B, W) recurrence state (fp32)
    conv: jnp.ndarray        # (B, conv_width-1, W) trailing conv inputs


def init(key, cfg, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    heads = cfg.n_heads
    hd = w // heads
    ks = jax.random.split(key, 8)
    # Lambda init so that a ~ U[0.9, 0.999]^(1/c) style (Griffin app. A)
    u = jax.random.uniform(ks[6], (w,), jnp.float32, 0.9, 0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / C_FACTOR))  # inv-softplus
    return {
        "lru_in_x": nn.dense_init(ks[0], d, w, dtype),
        "lru_in_gate": nn.dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, w), jnp.float32)
                   * (cfg.conv1d_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "lru_a_gate_w": (jax.random.normal(ks[3], (heads, hd, hd), jnp.float32)
                         * (hd ** -0.5)).astype(dtype),
        "lru_a_gate_b": jnp.zeros((heads, hd), jnp.float32),
        "lru_x_gate_w": (jax.random.normal(ks[4], (heads, hd, hd), jnp.float32)
                         * (hd ** -0.5)).astype(dtype),
        "lru_x_gate_b": jnp.zeros((heads, hd), jnp.float32),
        "lru_a_param": a_param,
        "lru_out": nn.dense_init(ks[5], w, d, dtype,
                                 scale=1.0 / max(1, cfg.n_layers) ** 0.5),
    }


def _conv1d(p, x, state=None):
    """Causal depthwise conv, width K. x (B,S,W); state (B,K-1,W) or None."""
    K = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][K - 1 - i]
              for i in range(K))
    return out + p["conv_b"].astype(x.dtype), xp[:, -(K - 1):]


def _gates(p, cfg, u):
    """u (B,S,W) -> log_a, gated_in (both fp32)."""
    B, S, W = u.shape
    heads = cfg.n_heads
    hd = W // heads
    uh = u.reshape(B, S, heads, hd)
    r = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", uh, p["lru_a_gate_w"],
                                  preferred_element_type=jnp.float32)
                       + p["lru_a_gate_b"])
    i = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", uh, p["lru_x_gate_w"],
                                  preferred_element_type=jnp.float32)
                       + p["lru_x_gate_b"])
    r = r.reshape(B, S, W)
    i = i.reshape(B, S, W)
    log_a = -C_FACTOR * jax.nn.softplus(p["lru_a_param"]) * r    # (B,S,W) fp32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u.astype(jnp.float32)
    return a, gated


def _scan_ref(a, b, h0):
    """h_t = a_t h_{t-1} + b_t via lax.scan over time. a,b (B,S,W) fp32."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    hT, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                     jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hT


def _scan_assoc(a, b, h0):
    """Blelloch associative scan over the sequence axis (log-depth)."""
    # fold h0 into the first step
    b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by
    As, Bs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return Bs, Bs[:, -1]


def apply(p, cfg, x, *, impl=None, cache: RGLRUCache = None):
    """Full-sequence path. x (B,S,D) -> (out, RGLRUCache)."""
    impl = impl or cfg.impl
    gate = jax.nn.gelu(nn.matmul(x, p["lru_in_gate"]), approximate=True)
    ux = nn.matmul(x, p["lru_in_x"])
    ux = logical_constraint(ux, "batch", None, "tp")
    conv_state = cache.conv if cache is not None else None
    u, conv_out = _conv1d(p, ux, conv_state)
    a, b = _gates(p, cfg, u)
    h0 = cache.h if cache is not None else jnp.zeros(
        (x.shape[0], u.shape[-1]), jnp.float32)
    if impl == "ref":
        hs, hT = _scan_ref(a, b, h0)
    elif impl == "blocked":
        hs, hT = _scan_assoc(a, b, h0)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        hs, hT = kops.rglru_scan(a, b, h0)
    else:
        raise ValueError(impl)
    out = (hs.astype(x.dtype) * gate)
    from repro.parallel.collectives import row_parallel
    out = row_parallel(out, p["lru_out"])
    return out, RGLRUCache(h=hT, conv=conv_out)


def apply_decode(p, cfg, x, cache: RGLRUCache):
    """Single-step path. x (B,1,D)."""
    gate = jax.nn.gelu(nn.matmul(x, p["lru_in_gate"]), approximate=True)
    ux = nn.matmul(x, p["lru_in_x"])
    u, conv_state = _conv1d(p, ux, cache.conv)
    a, b = _gates(p, cfg, u)
    h = a[:, 0] * cache.h + b[:, 0]
    out = (h[:, None].astype(x.dtype) * gate)
    out = nn.matmul(out, p["lru_out"])
    return out, RGLRUCache(h=h, conv=conv_state)


def cache_init(cfg, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return RGLRUCache(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype))
