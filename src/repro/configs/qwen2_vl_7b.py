"""Qwen2-VL-7B LM backbone [arXiv:2409.12191; hf-verified].

VLM: 28L, d_model=3584, 28 Q heads / 4 KV heads, d_ff=18944, vocab=152064,
M-RoPE with (temporal, height, width) sections (16, 24, 24) over the 64
rotary half-dims.  The vision frontend (dynamic-resolution ViT) is a STUB:
``input_specs()`` feeds precomputed patch/text embeddings (B, S, d_model)
plus 3-D M-RoPE position ids (3, B, S).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    act="silu",
    gated_ffn=True,
    embed_inputs=False,   # modality frontend stub supplies embeddings
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, mrope_sections=(4, 2, 2),
        attn_block_q=16, attn_block_kv=32)
