"""Swallow §X-B made load-bearing: a copy-on-write prefix-sharing overlay
on the striped page store.

The paper's second case study emulates *shared* memory on a
distributed-memory machine by striping one address space over per-node
controllers.  PR 2 reproduced the striping for KV pages but left every
request with private pages — the overlay was modeled, not used.  This
module is the sharing half: a radix tree over token IDs whose nodes own
ref-counted, immutable KV pages, so two requests whose prompts share a
prefix read the *same* physical pages through their block tables.  The
Pallas ``paged_decode_attention`` gather needs no kernel change — page
indirection (PR 2) already decouples a sequence's logical cache from
physical placement, which is exactly the payoff the paper claims for its
address%n overlay.

Structure: one radix node == one physical page.  A node's ``key`` is the
run of token IDs stored in its page (``fill`` of them, ``fill ==
page_size`` for interior nodes; partially filled nodes are leaves —
donated tails of completed sequences).  Children hang off full nodes
only, keyed by their first token.  Matching a prompt walks full-page
chunks; the first mismatch (or a partial node) ends the walk with an
optional mid-page partial match — the copy-on-write case: the request
COWs that page into a private copy and overwrites from the divergence
point, never mutating a shared page.

Lifecycle (refcounts live in :class:`~repro.serving.paged_kv.PageAllocator`):

* ``acquire(prompt)`` — walk, bump refcounts on every matched page (full
  matches *and* the COW source) so eviction cannot pull them out from
  under an admission in flight, and return a :class:`PrefixMatch`.
* ``insert(tokens, pages, ...)`` — after a prefill (full pages, which
  are immutable the moment they are written) or a completion (the
  partial tail too — immutable once the owner stops decoding), graft the
  sequence's pages into the tree; the tree takes its own reference, so
  shared pages survive the owner's free.
* ``evict(n)`` — LRU over leaves with no active users (refcount == the
  tree's own single reference): drop the tree's reference, page returns
  to the striped free list.  Wired as ``PageAllocator.reclaim`` so cold
  cache pages are reclaimed before any tenant is preempted.

Exact-token invariant: sharing only ever changes *where* a KV entry
lives, never its value — cache contents for a given (token, position)
are deterministic under greedy decode, so ``--prefix-cache on`` emits
bit-identical tokens to ``off`` (pinned by tests/test_prefix_cache.py).

Pure host-side logic: no jax imports.  The device-side COW copy and
suffix prefill live in :mod:`repro.serving.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.paged_kv import PageAllocator
from repro.serving.telemetry import MetricsRegistry, counter_attr


class RadixNode:
    """One cached page: ``key`` (the ``fill`` token IDs it stores), the
    physical ``page``, and children keyed by first token (full nodes
    only)."""
    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["RadixNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[int, RadixNode] = {}
        self.last_used = 0

    @property
    def fill(self) -> int:
        return len(self.key)


@dataclass
class PrefixMatch:
    """Result of :meth:`PrefixCache.acquire` — everything the scheduler
    and engine need to admit a request against the cache.

    ``length`` cached tokens are usable (capped at prompt_len - 1 so at
    least one token always runs through the model for first-token
    logits); the first ``length // page_size`` logical pages are the
    shared ``pages`` (refcounts already bumped, one reference per this
    request); when ``length % page_size != 0`` the divergence lands
    mid-page and ``cow_src`` names the page to copy-on-write (a
    temporary reference is held until the engine copies or the admission
    aborts)."""
    length: int = 0
    pages: List[int] = field(default_factory=list)
    cow_src: Optional[int] = None

    @property
    def hit(self) -> bool:
        return self.length > 0


class PrefixCacheStats:
    """Cache counters, registry-backed: each attribute is one
    ``prefix_*`` slot in a :class:`~repro.serving.telemetry
    .MetricsRegistry` (the owning engine's, so one reset covers the
    cache too), exposed under the historical attribute names."""

    lookups = counter_attr("prefix_lookups")
    hits = counter_attr("prefix_hits")
    tokens_cached = counter_attr("prefix_tokens_cached")
    cow_copies = counter_attr("prefix_cow_copies")
    inserts = counter_attr("prefix_inserts")         # nodes grafted
    evictions = counter_attr("prefix_evictions")     # LRU, refcount-0
    invalidations = counter_attr("prefix_invalidations")  # node failure

    def __init__(self, registry=None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.lookups = 0
        self.hits = 0
        self.tokens_cached = 0   # prefill tokens served from shared pages
        self.cow_copies = 0
        self.inserts = 0
        self.evictions = 0
        self.invalidations = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


class PrefixCache:
    """Radix-tree prefix index over token IDs on a striped page pool."""

    def __init__(self, alloc: PageAllocator, registry=None):
        self.alloc = alloc
        self.page_size = alloc.page_size
        self.root = RadixNode((), -1, None)     # sentinel, owns no page
        self._nodes: Dict[int, RadixNode] = {}  # page -> node
        self._clock = 0
        self.stats = PrefixCacheStats(registry)

    # -- bookkeeping -------------------------------------------------------
    def _touch(self, node: RadixNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    @property
    def n_nodes(self) -> int:
        return len(self._nodes)

    @property
    def shared_pages(self) -> int:
        """Pages currently owned by the tree."""
        return len(self._nodes)

    def users_of(self, node: RadixNode) -> int:
        """Active references beyond the tree's own (requests whose block
        tables point at this page)."""
        return self.alloc.refcount_of(node.page) - 1

    # -- matching ----------------------------------------------------------
    def _walk(self, tokens: Sequence[int]) -> Tuple[List[RadixNode], int,
                                                    Optional[RadixNode]]:
        """Longest cached prefix of ``tokens``: (full-page node path,
        matched length, partial node) — ``partial`` is the node the match
        ends inside (mid-key divergence, a partial leaf, or a full node
        whose tail the prompt doesn't reach past)."""
        node, path, i, n = self.root, [], 0, len(tokens)
        while i < n:
            child = node.children.get(int(tokens[i]))
            if child is None:
                break
            m = 0
            stop = min(child.fill, n - i)
            while m < stop and child.key[m] == int(tokens[i + m]):
                m += 1
            if m == child.fill == self.page_size:
                path.append(child)
                i += m
                node = child
                continue
            return path, i + m, (child if m else None)
        return path, i, None

    def peek(self, tokens: Sequence[int]) -> int:
        """Usable cached token count for a prompt, without taking
        references or touching LRU state (admission pricing / horizon
        checks)."""
        if tokens is None:
            return 0
        _, length, _ = self._walk(tokens)
        return min(length, max(len(tokens) - 1, 0))

    def acquire(self, tokens: Sequence[int]) -> PrefixMatch:
        """Match + lock: bump a reference on every page the request will
        use (full shared pages) or copy from (``cow_src``), so LRU
        eviction triggered by a later allocation in the same scheduler
        step cannot free them.  Balance with the request's
        ``PageAllocator.free`` (full pages ride in ``held``) and
        :meth:`release_cow` / :meth:`release_match`.  Stats are NOT
        recorded here — the caller commits them with
        :meth:`commit_match` once the admission actually sticks, so
        page-pressure retries don't inflate hit rate or dedup gauges."""
        if tokens is None:
            return PrefixMatch()
        path, raw, partial = self._walk(tokens)
        length = min(raw, max(len(tokens) - 1, 0))
        if length <= 0:
            return PrefixMatch()
        ps = self.page_size
        n_full = length // ps
        pages = []
        for node in path[:n_full]:
            self.alloc.share(node.page)
            self._touch(node)
            pages.append(node.page)
        cow_src = None
        if length % ps:
            # the node the (possibly capped) match ends inside: either the
            # divergent/partial node from the walk, or the last full node
            # of the path when the cap pulled the boundary back
            node = partial if n_full == len(path) else path[n_full]
            assert node is not None
            self.alloc.share(node.page)
            self._touch(node)
            cow_src = node.page
        return PrefixMatch(length=length, pages=pages, cow_src=cow_src)

    def commit_match(self, match: PrefixMatch) -> None:
        """Record the lookup in the stats — called once per *successful*
        admission (hit or miss), never for budget/page-pressure aborts,
        so ``hit_rate`` / ``tokens_cached`` / ``bytes_deduped`` count
        real savings only."""
        self.stats.lookups += 1
        if match.hit:
            self.stats.hits += 1
            self.stats.tokens_cached += match.length

    def release_match(self, match: PrefixMatch) -> None:
        """Undo :meth:`acquire` when the admission aborts (budget or page
        pressure)."""
        for p in match.pages:
            self.alloc.release_page(p)
        self.release_cow(match)

    def release_cow(self, match: PrefixMatch) -> None:
        """Drop the temporary COW-source reference (engine calls this
        right after the device copy)."""
        if match.cow_src is not None:
            self.alloc.release_page(match.cow_src)
            match.cow_src = None

    # -- insertion ---------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               n_tokens: Optional[int] = None, *,
               donate_partial: bool = False) -> int:
        """Graft a sequence's pages into the tree.  ``tokens[:n_tokens]``
        are the IDs whose KV actually lives in ``pages`` (logical order).
        Full pages are immutable the moment prefill writes them and are
        always inserted; the partial tail is inserted only with
        ``donate_partial`` (completion — the owner will never write the
        page again).  Idempotent: chunks already cached just refresh LRU.
        Returns the number of nodes grafted."""
        if tokens is None:
            return 0
        n = len(tokens) if n_tokens is None else n_tokens
        ps = self.page_size
        node, grafted = self.root, 0
        for j in range(-(-n // ps)):
            chunk = tuple(int(t) for t in tokens[j * ps:min((j + 1) * ps, n)])
            full = len(chunk) == ps
            if not full and not donate_partial:
                break
            child = node.children.get(chunk[0])
            if child is None:
                if j >= len(pages):
                    break
                child = RadixNode(chunk, pages[j], node)
                self.alloc.share(child.page)
                node.children[chunk[0]] = child
                self._nodes[child.page] = child
                self._touch(child)
                grafted += 1
                self.stats.inserts += 1
            elif child.fill < len(chunk) \
                    and child.key == chunk[:child.fill] \
                    and not child.children and j < len(pages) \
                    and self.users_of(child) == 0:
                # upgrade: a longer immutable run supersedes a donated
                # partial leaf nobody is using — swap the page in place
                self.alloc.share(pages[j])
                old = child.page
                del self._nodes[old]
                child.page, child.key = pages[j], chunk
                self._nodes[child.page] = child
                self.alloc.release_page(old)
                self._touch(child)
            elif child.key != chunk:
                break           # divergence inside the page: nothing to add
            else:
                self._touch(child)
            if not full or child.key != chunk:
                break
            node = child
        return grafted

    # -- eviction ----------------------------------------------------------
    def _evictable(self) -> List[RadixNode]:
        return [nd for nd in self._nodes.values()
                if not nd.children and self.users_of(nd) == 0]

    def evict(self, n_pages: int) -> int:
        """LRU eviction over refcount-0 leaves until ``n_pages`` pages
        returned to the free list (or nothing evictable remains).
        Interior nodes become leaves as their children go, so repeated
        passes peel the tree from the outside in."""
        freed = 0
        while freed < n_pages:
            victims = self._evictable()
            if not victims:
                break
            node = min(victims, key=lambda nd: nd.last_used)
            freed += self._drop(node)
        return freed

    def _drop(self, node: RadixNode) -> int:
        del self._nodes[node.page]
        node.parent.children.pop(node.key[0], None)
        self.stats.evictions += 1
        return 1 if self.alloc.release_page(node.page) else 0

    # -- fault-plane invalidation ------------------------------------------
    def invalidate_pages(self, pages) -> int:
        """Node-failure quarantine, tree-wide: drop every node whose page
        is in ``pages`` AND its whole subtree — descendants are only
        reachable for matching through the lost ancestor, so keeping them
        would strand pages the tree can never hand out again.  Unlike
        LRU eviction this ignores ``users_of``: the allocator's
        quarantine (not the free list) catches the released references,
        and live holders are reset by the scheduler's recovery pass.
        Returns the number of nodes dropped."""
        lost = {p for p in pages if p in self._nodes}
        if not lost:
            return 0
        dropped = 0
        for page in sorted(lost):
            node = self._nodes.get(page)
            if node is None:
                continue              # already gone via an ancestor
            dropped += self._drop_subtree(node)
        return dropped

    def _drop_subtree(self, node: RadixNode) -> int:
        n = 0
        for child in list(node.children.values()):
            n += self._drop_subtree(child)
        del self._nodes[node.page]
        node.parent.children.pop(node.key[0], None)
        self.stats.invalidations += 1
        self.alloc.release_page(node.page)
        return n + 1

    def clear(self) -> int:
        """Release every tree reference (e.g. after an engine warmup so
        benchmark runs start cold).  Pages still used by live requests
        survive via their own refcounts."""
        freed = 0
        for node in list(self._nodes.values()):
            if self.alloc.release_page(node.page):
                freed += 1
            del self._nodes[node.page]
        self.root = RadixNode((), -1, None)
        return freed

    # -- observability -----------------------------------------------------
    def metrics(self) -> dict:
        s = self.stats
        return {
            "prefix_lookups": s.lookups,
            "prefix_hits": s.hits,
            "prefix_hit_rate": s.hit_rate,
            "prefill_tokens_cached": s.tokens_cached,
            "cow_copies": s.cow_copies,
            "prefix_nodes": self.n_nodes,
            "shared_pages": self.shared_pages,
            "prefix_evictions": s.evictions,
            "prefix_invalidations": s.invalidations,
        }
