"""N-gram speculative decoding: proposer semantics, rollback
(``PageAllocator.truncate_to``) refcount safety, scheduler
``complete_spec`` bookkeeping, and the acceptance gate — greedy tokens
bit-identical with speculation on or off, under prefix-cache hits,
forced preemption and fused windows, with ``dispatches_per_token``
actually dropping on repetitive text."""
import numpy as np
import pytest

from conftest import dense_oracle, get_tiny_model, make_engine, \
    seeded_prompts
from repro.serving import (AdaptiveK, ContinuousBatchScheduler, NGramSpec,
                           PageAllocator, Request, device_propose,
                           propose_ngram)


# --- proposer: weightless prompt-lookup drafting -------------------------------
def test_propose_ngram_prefers_longest_ngram_and_earliest_match():
    #          0  1  2  3  4  5  6  7
    history = [1, 2, 3, 9, 1, 2, 3, 9]          # period-4 loop
    # last 3 tokens [2,3,9] occur earliest at i=1 -> continuation from 4
    assert propose_ngram(history, 4, max_n=3) == [1, 2, 3, 9]
    # k is clipped at the end of history
    assert propose_ngram(history, 99, max_n=3) == [1, 2, 3, 9]
    # the n=2 pattern [1,2] matches earliest at i=1 -> continuation from 3
    h = [5, 1, 2, 7, 7, 1, 2]
    assert propose_ngram(h, 3, max_n=3) == [7, 7, 1]
    # n=1 fallback when nothing longer matches
    assert propose_ngram([4, 8, 4], 2, max_n=3) == [8, 4]


def test_propose_ngram_empty_cases():
    assert propose_ngram([], 4) == []
    assert propose_ngram([7], 4) == []                 # no earlier history
    assert propose_ngram([1, 2, 3], 0) == []           # k = 0
    assert propose_ngram([1, 2, 3], 4) == []           # no repeat at all
    # min_n=2 refuses a unigram-only match
    assert propose_ngram([1, 5, 2, 5], 2, max_n=3, min_n=2) == []


def test_ngram_spec_accept_rule_is_greedy_exact():
    spec = NGramSpec(k=8)
    # full accept: drafts match greedy everywhere -> drafts + bonus token
    assert spec.accept([4, 5, 6], [4, 5, 6, 7]) == [4, 5, 6, 7]
    # first mismatch replaced by the verifier's own token, rest dropped
    assert spec.accept([4, 9, 6], [4, 5, 6, 7]) == [4, 5]
    # immediate mismatch still emits exactly the greedy token
    assert spec.accept([9], [4, 5]) == [4]
    s = spec.stats
    assert (s.drafted, s.accepted, s.verifies) == (7, 4, 3)
    assert s.accept_rate == pytest.approx(4 / 7)


# --- device proposer: deterministic differential rungs -------------------------
def _dev(history, k, *, max_n=3, min_n=1, H=16, k_max=9):
    """Run the jitted device proposer over a padded buffer and return
    the draft as a plain list (the host proposer's return shape)."""
    import jax
    import jax.numpy as jnp
    buf = np.zeros((H,), np.int32)
    buf[:len(history)] = history
    fn = jax.jit(device_propose,
                 static_argnames=("k_max", "max_n", "min_n"))
    draft, m = fn(jnp.asarray(buf), jnp.int32(len(history)),
                  jnp.int32(k), k_max=k_max, max_n=max_n, min_n=min_n)
    return [int(t) for t in np.asarray(draft)[:int(m)]]


def test_device_propose_matches_host_on_reference_cases():
    """The named host-proposer unit cases, replayed through the jitted
    device suffix match — the deterministic rung under the randomized
    hypothesis differential (tests/test_property_serving.py)."""
    cases = [
        ([1, 2, 3, 9, 1, 2, 3, 9], 4, {}),      # period-4 loop
        ([1, 2, 3, 9, 1, 2, 3, 9], 99, {}),     # k clipped at history end
        ([5, 1, 2, 7, 7, 1, 2], 3, {}),         # n=2 earliest match
        ([4, 8, 4], 2, {}),                     # n=1 fallback
        ([], 4, {}),                            # empty history
        ([7], 4, {}),                           # no earlier history
        ([1, 2, 3], 0, {}),                     # k = 0
        ([1, 2, 3], 4, {}),                     # aperiodic: no match
        ([1, 5, 2, 5], 2, {"min_n": 2}),        # min_n refuses unigram
    ]
    for history, k, kw in cases:
        want = propose_ngram(history, min(k, 9), max_n=3, **kw)
        assert _dev(history, k, **kw) == want, (history, k, kw)


def test_device_propose_ignores_padding_past_hist_len():
    """Tokens past ``hist_len`` (stale rolled-back drafts, junk) must
    never participate in a match: the buffer's tail repeats the
    history's own suffix, which a missing validity mask would treat as
    an earlier occurrence."""
    import jax
    import jax.numpy as jnp
    fn = jax.jit(device_propose,
                 static_argnames=("k_max", "max_n", "min_n"))

    def run(history, pad, k):
        buf = np.zeros((16,), np.int32)
        buf[:len(history)] = history
        buf[len(history):len(history) + len(pad)] = pad
        draft, m = fn(jnp.asarray(buf), jnp.int32(len(history)),
                      jnp.int32(k), k_max=9, max_n=3, min_n=1)
        return [int(t) for t in np.asarray(draft)[:int(m)]]

    # aperiodic history, padding repeats its tail [2,3]: the host finds
    # nothing, and the padded copy must not be mistaken for a match
    assert propose_ngram([1, 2, 3], 4, max_n=3) == []
    assert run([1, 2, 3], [2, 3, 9, 9], 4) == []
    # looping history: the legit draft clips at hist_len and must not
    # keep reading into the padding bytes that continue the loop
    assert propose_ngram([4, 6, 4, 6, 4], 4, max_n=3) == [6, 4]
    assert run([4, 6, 4, 6, 4], [6, 4, 6, 4], 4) == [6, 4]


# --- adaptive K: EWMA algebra, clamping, collapse ------------------------------
def test_adaptive_k_ewma_update_algebra():
    ak = AdaptiveK(alpha=0.5, rate=0.75)
    ak.observe(4, 4)                  # full accept: rate -> 0.875
    assert ak.rate == pytest.approx(0.875)
    ak.observe(4, 0)                  # full reject: halfway to 0
    assert ak.rate == pytest.approx(0.4375)
    r = ak.rate
    ak.observe(0, 0)                  # no-draft verify teaches nothing
    assert ak.rate == r
    ak.observe(2, 1)
    assert ak.rate == pytest.approx(r + 0.5 * (0.5 - r))


def test_adaptive_k_target_is_expected_accept_run_length():
    # geometric run length r/(1-r), clamped to k_max
    assert AdaptiveK(rate=0.75).target(k_max=16) == 3
    assert AdaptiveK(rate=0.9).target(k_max=16) == 9   # ~0.9/0.1
    assert AdaptiveK(rate=0.999).target(k_max=16) == 16
    assert AdaptiveK(rate=1.5).target(k_max=16) == 16  # saturates
    assert AdaptiveK(rate=0.4).target(k_max=16) == 0   # below break-even


def test_adaptive_k_collapses_then_probes():
    ak = AdaptiveK(alpha=0.5, rate=0.75, probe_every=3)
    for _ in range(4):
        ak.observe(3, 0)              # sustained rejection
    assert ak.rate < 0.1
    got = [ak.target(8) for _ in range(7)]
    # disabled (0) with a 1-token probe every probe_every windows
    assert got == [0, 0, 1, 0, 0, 1, 0]
    ak.observe(1, 1)                  # an accepted probe re-enables
    assert ak.target(8) >= 1


def test_draft_k_clamps_to_horizon_and_pow2_buckets():
    spec = NGramSpec(k=15, adaptive=True)
    # prior rate 0.75 -> target 3; K+1 = 4 is already a verify bucket
    assert spec.draft_k("r", horizon=16) == 3
    # horizon clamp: at most horizon-1 drafts, snapped DOWN to a bucket
    assert spec.draft_k("r", horizon=3) == 1    # cap 2 -> K+1 = 2
    assert spec.draft_k("r", horizon=2) == 1
    assert spec.draft_k("r", horizon=1) == 0    # no room to draft
    # a hot request earns the deep bucket, clamped to k then horizon
    spec.state("hot").rate = 0.97               # target 32 -> k=15
    assert spec.draft_k("hot", horizon=16) == 15
    assert spec.draft_k("hot", horizon=9) == 7  # pow2 snap under the cap
    # every K the controller emits verifies in an existing pow2 bucket
    for hz in range(1, 17):
        K = spec.draft_k("hot", horizon=hz)
        if K:
            assert (K + 1) & K == 0             # K+1 is a power of two
            assert K + 1 <= hz


def test_draft_k_sustained_rejection_disables_speculation():
    spec = NGramSpec(k=8, adaptive=True, probe_every=4)
    for _ in range(6):
        spec.observe("r", 4, 0)
    ks = [spec.draft_k("r", horizon=9) for _ in range(8)]
    assert ks.count(0) == 6 and ks.count(1) == 2   # probes only
    spec.forget("r")
    # fresh state after forget: back to the optimistic prior
    assert spec.draft_k("r", horizon=9) == 3


# --- allocator: speculative rollback -------------------------------------------
def test_truncate_to_releases_whole_rejected_pages():
    a = PageAllocator(n_pages=12, page_size=4, n_nodes=2)
    a.alloc("r", 5)                       # capacity 20 tokens
    assert a.truncate_to("r", 9) == 2     # keep ceil(9/4) = 3 pages
    assert len(a.held["r"]) == 3 and a.free_pages == 8
    assert a.truncate_to("r", 9) == 0     # idempotent
    assert a.truncate_to("r", 12) == 0    # already within bound
    assert a.check_conservation()
    a.free("r")
    assert a.pages_in_use == 0


def test_truncate_to_respects_refcounts_of_shared_pages():
    a = PageAllocator(n_pages=12, page_size=4, n_nodes=1)
    pages = list(a.alloc("r", 4))         # snapshot: held mutates in place
    a.share(pages[3])                     # e.g. a cache node took the tail
    freed = a.truncate_to("r", 4)         # drop pages 1..3 (keep 1)
    assert freed == 2                     # the shared page did NOT free
    assert a.refcount_of(pages[3]) == 1   # other holder's reference lives
    assert len(a.held["r"]) == 1
    assert a.check_conservation()
    a.release_page(pages[3])
    a.free("r")
    assert a.free_pages == 11


def test_truncate_to_zero_and_conservation():
    a = PageAllocator(n_pages=8, page_size=4, n_nodes=1)
    a.alloc("r", 3)
    assert a.truncate_to("r", 0) == 3     # keep nothing
    assert a.held["r"] == [] and a.check_conservation()
    a.free("r")


# --- scheduler: multi-token verified emission ----------------------------------
def test_complete_spec_advances_pos_and_finishes():
    a = PageAllocator(n_pages=16, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2)
    s.submit(Request(rid="r", prompt_len=4, gen=6))
    plan = s.plan_step()
    req = plan.admitted[0]
    s.note_first_token(req, 11)
    assert req.pos == 4
    assert s.complete_spec(req, [12, 13, 14]) == []
    assert req.pos == 7 and req.tokens == [11, 12, 13, 14]
    done = s.complete_spec(req, [15, 16])         # reaches gen = 6
    assert done == [req] and req.state == "finished"
    assert req.tokens == [11, 12, 13, 14, 15, 16]
    assert a.pages_in_use == 0 and s.conserved(1)


# --- engine acceptance gates: spec on == spec off == dense ---------------------
def _run(prompts, gens, *, n_pages=48, budget=2.0, fused=True,
         spec=False, cache=False, max_batch=3, spec_k=6, max_len=None):
    cfg, params = get_tiny_model()
    max_len = max_len or max(p.shape[0] + g for p, g in zip(prompts, gens))
    eng = make_engine(cfg, params, max_batch=max_batch, n_pages=n_pages,
                      max_len=max_len, prefill_budget=budget, fused=fused,
                      spec_decode=spec, spec_k=spec_k, prefix_cache=cache)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(np.asarray(p), g, rid=f"r{i}")
    fin = eng.run()
    return eng, {r.rid: list(r.tokens) for r in fin}


def test_spec_tokens_identical_and_dispatches_drop():
    """The base gate: speculation on/off/dense all emit the same tokens,
    and on the looping continuations the tiny model produces, verified
    windows cut model passes per token."""
    cfg, params = get_tiny_model()
    S, gens = 12, [14, 12, 16, 10]
    prompts = seeded_prompts(cfg, len(gens), S, motif=4)
    max_len = S + max(gens)
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng_off, toks_off = _run(prompts, gens, spec=False)
    eng_on, toks_on = _run(prompts, gens, spec=True)
    assert toks_on == toks_off == dense
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_on["spec_verifies"] >= 1 and m_on["accept_rate"] > 0.0
    assert m_on["model_passes"] < m_off["model_passes"]
    assert m_on["dispatches_per_token"] < m_off["dispatches_per_token"]
    assert eng_on.alloc.check_conservation()
    assert eng_on.alloc.pages_in_use == 0


def test_spec_tokens_identical_under_forced_preemption():
    """Tight pool + unthrottled admission: preemption occurs with
    speculation on, recompute (re-drafting from a shorter history) stays
    bit-exact, and every page is returned."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 12, 6, 6
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng, toks = _run(prompts, [gen] * n_req, n_pages=14, budget=0.0,
                     spec=True)
    assert toks == dense
    assert eng.metrics()["preemptions"] >= 1
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_spec_tokens_identical_with_prefix_cache_hits():
    """Speculation composed with COW prefix sharing: hits skip prefill,
    drafts verify against pages that start shared, and tokens still
    match the all-off run exactly."""
    cfg, params = get_tiny_model()
    total, shared = 14, 10            # divergence mid-page (page_size 4)
    gens = [10, 9, 11, 8]
    prompts = seeded_prompts(cfg, len(gens), total, shared=shared, seed=3)
    eng_off, toks_off = _run(prompts, gens)
    eng_on, toks_on = _run(prompts, gens, spec=True, cache=True)
    assert toks_on == toks_off
    m = eng_on.metrics()
    assert m["prefix_hits"] >= 1
    assert m["spec_verifies"] >= 1
    assert eng_on.alloc.check_conservation()
    assert eng_on.alloc.pages_in_use == eng_on.cache.shared_pages


def test_spec_rollback_releases_pages_and_stays_exact():
    """A rejected draft that crossed a page boundary rolls whole pages
    back to the free list (truncate_to) without perturbing tokens."""
    cfg, params = get_tiny_model()
    S, gens = 12, [18, 16]
    prompts = seeded_prompts(cfg, len(gens), S, motif=3, seed=11)
    max_len = S + max(gens)
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng, toks = _run(prompts, gens, spec=True, spec_k=8, max_batch=2)
    assert toks == dense
    m = eng.metrics()
    assert m["spec_rollbacks"] >= 1, "trace never exercised rollback"
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_spec_forced_rejection_invalidates_row_signature_and_stays_exact():
    """Adversarial proposer: every draft is wrong, so every verify
    rejects and rolls pages back.  Pop-then-regrow can restore the same
    page COUNT with different physical pages — invisible to the (rid,
    preemptions, len) dirty-tracking signature — so the engine must
    forget the slot signature on rollback (or a stale device block row
    would write one tenant's KV into another's page).  Tokens must stay
    bit-identical to dense throughout, and the signature must be
    observed invalidated on a rollback window."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 8, 8, 3
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S, seed=5)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng = make_engine(cfg, params, max_batch=2, n_pages=13,
                      max_len=max_len, prefill_budget=0.0,
                      spec_decode=True, spec_k=4, spec_proposer="host")

    def wrong(prompt, tokens, k_cap):
        if k_cap < 1 or not tokens:
            return []
        return [(int(tokens[-1]) + 1) % cfg.vocab_size] * min(3, k_cap)
    eng.spec.propose = wrong
    for i, p in enumerate(prompts):
        eng.submit(np.asarray(p), gen, rid=f"r{i}")
    saw_invalidation = False
    while eng.sched.waiting or eng.sched.running:
        before = eng.spec.stats.verifies
        eng.step()
        if eng.spec.stats.verifies > before and eng.sched.running:
            # the rejected slot's signature was forgotten this window
            saw_invalidation |= any(
                eng._slot_sig[s] is None for s in eng.sched.running)
    assert saw_invalidation
    assert eng.spec.stats.accepted == 0          # every draft was wrong
    assert eng.spec.stats.rollbacks >= 1
    toks = {r.rid: list(r.tokens) for r in eng.sched.finished}
    assert toks == dense
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_spec_shallow_drafts_never_cost_passes_at_wide_batch():
    """The worth-it gate: when the batch is wide and drafts are shallow
    (draft depth <= the fused window the slot rides for free), the
    engine must NOT pay a verify pass per slot — the batched scan
    amortizes better.  Speculation on may match but never materially
    exceed the plain path's model passes, and tokens stay identical."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 12, 12, 3
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S, motif=4, seed=2)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng_off, toks_off = _run(prompts, [gen] * n_req, spec=False,
                             max_len=max_len)
    # spec_k=2: drafts of at most 2 tokens against 4..8-token windows
    eng_on, toks_on = _run(prompts, [gen] * n_req, spec=True, spec_k=2,
                           max_len=max_len)
    assert toks_on == toks_off == dense
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_on["model_passes"] <= m_off["model_passes"]


def test_spec_off_by_default_and_metrics_gated():
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params)
    assert eng.spec is None
    m = eng.metrics()
    assert "accept_rate" not in m
    assert "model_passes" in m and "dispatches_per_token" in m
