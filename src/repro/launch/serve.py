"""CLI batched-serving driver: dense fixed batches or the paged engine.

  PYTHONPATH=src python -m repro.launch.serve --arch tiny-100m \
      --batch 4 --prompt-len 64 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --tiny --engine paged \
      --requests 16 --devices 4

``--engine dense`` (default) is the original fixed-size-batch loop: a
request queue feeds whole batches, finished batches are replaced
wholesale.  ``--engine paged`` routes through :mod:`repro.serving` — the
paged-KV continuous-batching engine (Swallow §III farmer-worker over the
§X-B striped store); both engines decode greedily and produce identical
tokens on the same prompts (pinned by tests/test_serving.py).
``--chunk-prefill on`` slices paged prefills into page-aligned chunks
co-scheduled with decode windows, with ``--slo`` stamping every request's
class (TTFT deadline + tolerable stall — docs/SERVING.md).
``--fault-plan chaos`` arms a seeded deterministic fault schedule (node
failures, transient admission errors, a straggler) against the paged
run and prints the recovery report — docs/FAULT_TOLERANCE.md; needs a
striped pool, i.e. ``--model N`` or ``--layout auto`` with model > 1.

``--layout auto`` asks the cost engine for the fastest (data, model)
mesh for the decode shape and reports predicted vs measured per-token
time.  Timing excludes the first (compile) step — a warmup prefill +
decode runs before the clock starts, so the predicted-vs-measured ratio
reflects steady state, not XLA compilation.

``--trace-out trace.json`` arms the step-clock flight recorder on the
paged engine and exports the run as Chrome trace-event JSON (load in
Perfetto or chrome://tracing — docs/OBSERVABILITY.md); every dispatch
span carries cost-engine predicted seconds and §VI energy alongside
measured wall time, rolled up into the per-phase model-error table.
``--metrics-out metrics.json`` dumps the unified metrics registry
snapshot (counters, gauges, percentile digests).
"""
import argparse
import os
import time


def make_prompts(n_requests: int, prompt_len: int, vocab_size: int,
                 shared_prefix: int = 0):
    """The shared request stream: request i is PRNGKey(i) — both engines
    see byte-identical prompts, which is what makes the token-equality
    acceptance check meaningful.  ``shared_prefix`` gives every request
    the same leading tokens (a system prompt) so ``--prefix-cache on``
    has something to share."""
    import jax
    import jax.numpy as jnp
    # clamp so an over-long system prompt never yields a negative tail
    shared_prefix = max(0, min(shared_prefix, prompt_len))
    base = jax.random.randint(jax.random.PRNGKey(757575),
                              (shared_prefix,), 2, vocab_size)
    return [jnp.concatenate([
        base, jax.random.randint(jax.random.PRNGKey(i),
                                 (prompt_len - shared_prefix,), 2,
                                 vocab_size)])
            for i in range(n_requests)]


def _stream_prompts(args, cfg):
    """The one prompt stream both engines consume — keep construction in
    one place so dense and paged always see byte-identical prompts."""
    return make_prompts(args.requests, args.prompt_len, cfg.vocab_size,
                        shared_prefix=getattr(args, "shared_prefix", 0))


def run_dense(args, cfg, mesh, params=None):
    """Fixed-batch loop.  Returns (per-request token lists, stats dict)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    from repro import steps as steps_mod
    from repro.parallel.sharding import use_sharding

    max_len = args.prompt_len + args.gen
    with use_sharding(mesh):
        if params is None:
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
        prefill = jax.jit(steps_mod.make_prefill_step(cfg, max_len=max_len))
        serve = jax.jit(steps_mod.make_serve_step(cfg), donate_argnums=(2,))

        prompts = _stream_prompts(args, cfg)
        # warmup: compile prefill + decode outside the timed region
        wl, wc = prefill(params, jnp.stack([prompts[0]] * args.batch))
        wt = jnp.argmax(wl, -1).astype(jnp.int32)
        wt, _, wc = serve(params, wt, wc, jnp.int32(args.prompt_len))
        jax.block_until_ready(wt)

        pending = list(enumerate(prompts))
        outputs = {}
        t0 = time.time()
        decode_steps = 0
        decode_s = 0.0
        tokens_out = 0
        while pending:
            batch = [pending.pop(0) for _ in
                     range(min(args.batch, len(pending)))]
            pad = len(batch)
            while len(batch) < args.batch:      # pad the worker pool
                batch.append(batch[-1])
            logits, caches = prefill(params, jnp.stack([p for _, p in batch]))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs = [tok]
            jax.block_until_ready(tok)          # decode-only timing below
            td = time.time()
            for i in range(args.gen - 1):
                tok, logits, caches = serve(params, tok, caches,
                                            jnp.int32(args.prompt_len + i))
                outs.append(tok)
                decode_steps += 1
            jax.block_until_ready(tok)
            decode_s += time.time() - td
            seq = jnp.concatenate(outs, -1)     # (batch, gen)
            for row, (rid, _) in enumerate(batch[:pad]):
                outputs[rid] = [int(t) for t in seq[row]]
                tokens_out += args.gen
        dt = time.time() - t0
    stats = dict(requests=len(outputs), tokens=tokens_out, seconds=dt,
                 decode_steps=decode_steps,
                 step_s=decode_s / max(decode_steps, 1))
    return outputs, stats


def run_paged(args, cfg, n_nodes: int = 1, params=None, mesh=None):
    """Paged continuous-batching path.  Returns (tokens, stats, engine).

    ``n_nodes`` is the page-striping width (the model-axis extent the
    cost engine prices and the allocator stripes over).  With ``mesh``
    the striping is literal: the engine places each KV page pool over
    the mesh's model axis (NamedSharding on the page axis) and decode
    runs the shard_map owner-partials merge."""
    import jax
    import numpy as np
    from repro.models import lm
    from repro.serving import PagedEngine

    if params is None:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    # auto pool: exact worst-case demand of a full batch + the null page
    n_pages = args.pages or (
        args.batch * (-(-max_len // args.page_size)) + 1)
    if n_nodes > 1 and n_pages % n_nodes:
        n_pages += n_nodes - n_pages % n_nodes   # stripe divisibility
    eng = PagedEngine(cfg, params, max_batch=args.batch,
                      page_size=args.page_size, n_pages=n_pages,
                      max_len=max_len, n_nodes=n_nodes, mesh=mesh,
                      link_mode=args.link_mode,
                      prefill_budget=args.prefill_budget,
                      fused=args.fused, max_window=args.window,
                      prefix_cache=args.prefix_cache == "on",
                      spec_decode=args.spec_decode == "on",
                      spec_k=args.spec_k,
                      chunked_prefill=args.chunk_prefill == "on",
                      chunk_tokens=args.chunk_tokens,
                      trace=bool(getattr(args, "trace_out", None)),
                      trace_capacity=getattr(args, "trace_capacity", 4096))
    prompts = _stream_prompts(args, cfg)
    # warmup both jitted paths (prefill + every fused-window bucket),
    # then reset clocks
    eng.warmup_windows()
    eng.submit(np.asarray(prompts[0]), min(2, args.gen), rid="warmup")
    eng.run()
    # compile the COW-copy + suffix-prefill bucket the measured hits will
    # use (no-op with the cache off or no shared prefix)
    eng.warmup_prefix(args.prompt_len, args.shared_prefix)
    eng.reset_metrics()
    if eng.cache is not None:
        eng.cache.clear()      # the measured run starts with a cold tree
    if getattr(args, "fault_plan", "off") == "chaos":
        from repro.serving import FaultPlan
        if n_nodes < 2:
            raise SystemExit("--fault-plan chaos needs a striped pool "
                             "(--model N >= 2 or --layout auto): node 0 "
                             "never fails, so a 1-node pool has nothing "
                             "to quarantine")
        # armed AFTER warmup/reset: plan step 0 is the first measured step
        eng.install_faults(FaultPlan.seeded(
            args.fault_seed, n_nodes=n_nodes, horizon=args.fault_horizon))

    for i, p in enumerate(prompts):
        eng.submit(np.asarray(p), args.gen, rid=f"req{i}", slo=args.slo)
    t0 = time.time()
    finished = eng.run()
    dt = time.time() - t0
    outputs = {int(r.rid[3:]): r.tokens for r in finished}
    m = eng.metrics()
    m.update(seconds=dt, step_s=m["decode_step_s"])
    return outputs, m, eng


def report_fleet(args, cfg, eng, tokens_out: int):
    """Register the serve job with the cost-aware nOS and print the
    fleet serving view (per-job pages, energy, queue latency)."""
    from repro.configs.base import ShapeConfig
    from repro.core import nos as nos_mod

    pod = nos_mod.NOS(data_rows=4, model_cols=max(args.devices or 1, 1))
    shape = ShapeConfig("serve", args.prompt_len + args.gen, args.batch,
                        "decode")
    pod.submit(cfg, name="serve", shape=shape, steps=eng.steps_run,
               mode=args.link_mode, max_rows=1)
    est = pod.jobs["serve"].estimate
    m = eng.metrics()
    from repro.serving.slo import get_slo
    slo = get_slo(args.slo)
    fin = eng.sched.finished
    met_tokens = sum(len(r.tokens) for r in fin
                     if r.first_token_step <= r.deadline_step)
    # predicted-vs-measured attribution from the flight recorder (when
    # the run was traced): per-phase rollup feeds the fleet-level table
    model_error = None
    pred_s = meas_s = pred_j = 0.0
    if eng.tracer is not None:
        model_error = eng.tracer.model_error_report()
        pred_s = sum(r["predicted_s"] for r in model_error.values())
        meas_s = sum(r["measured_s"] for r in model_error.values())
        pred_j = sum(r["predicted_j"] for r in model_error.values())
    pod.update_serving(
        "serve", pages_held=eng.alloc.pages_in_use,
        peak_pages=m["peak_pages"],
        tokens_out=tokens_out,
        queue_latency_s=m["ttft_steps_mean"] * est.step_time_s,
        preemptions=m["preemptions"],
        energy_j=eng.steps_run * est.energy.total_j * est.layout.n_chips,
        shared_pages=m.get("shared_pages"),
        prefix_hit_rate=m.get("prefix_hit_rate"),
        bytes_deduped=m.get("bytes_deduped"),
        accept_rate=m.get("accept_rate"),
        dispatches_per_token=m.get("dispatches_per_token"),
        spec_k=m.get("spec_k_mean"),
        ttft_p99_s=m["ttft_steps_p99"] * est.step_time_s,
        ttft_target_s=slo.ttft_steps * est.step_time_s,
        goodput_frac=met_tokens / max(tokens_out, 1),
        pages_quarantined=m.get("pages_quarantined"),
        requests_recovered=m.get("requests_recovered"),
        tokens_recomputed=m.get("tokens_recomputed"),
        recovery_steps_p99=m.get("recovery_steps_p99"),
        predicted_s=pred_s, measured_s=meas_s, predicted_j=pred_j,
        model_error=model_error)
    print("[nOS] fleet serving view:")
    print(pod.serving_table())
    if model_error:
        print("[nOS] predicted-vs-measured attribution:")
        print(pod.attribution_table())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--engine", default="dense", choices=["dense", "paged"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--layout", default="manual", choices=["manual", "auto"],
                    help="auto: let the cost engine pick (data, model)")
    ap.add_argument("--link-mode", default="circuit",
                    choices=["circuit", "packet"])
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged engine: tokens per KV page")
    ap.add_argument("--pages", type=int, default=0,
                    help="paged engine: pool size incl. null page (0=auto)")
    ap.add_argument("--prefill-budget", type=float, default=2.0,
                    help="prefill seconds admitted per step, in units of "
                         "one decode step (cost-engine priced)")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="paged engine: fused multi-token decode windows "
                         "(--no-fused = legacy per-step host loop)")
    ap.add_argument("--window", type=int, default=8,
                    help="paged engine: max fused window (tokens per "
                         "device dispatch)")
    ap.add_argument("--prefix-cache", default="off", choices=["on", "off"],
                    help="paged engine: radix-tree prefix sharing with "
                         "copy-on-write on the striped page store "
                         "(docs/PREFIX_CACHE.md)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every request the same leading N tokens "
                         "(a system prompt) so the prefix cache has "
                         "something to share")
    ap.add_argument("--spec-decode", default="off", choices=["on", "off"],
                    help="paged engine: n-gram speculative decoding — "
                         "draft from each sequence's own history, verify "
                         "K+1 positions in one dispatch, roll back "
                         "rejected pages (docs/SERVING.md)")
    ap.add_argument("--spec-k", default="auto",
                    help="max draft tokens per verification dispatch: an "
                         "integer for a fixed depth, or 'auto' (default) "
                         "for the per-tenant acceptance-EWMA adaptive "
                         "controller (AdaptiveK)")
    ap.add_argument("--chunk-prefill", default="off", choices=["on", "off"],
                    help="paged engine: split prefills into page-aligned "
                         "chunks co-scheduled with decode windows under "
                         "SLO-aware EDF admission (docs/SERVING.md; off = "
                         "monolithic priced-FIFO prefill)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="tokens per prefill chunk (0 = 2 pages)")
    ap.add_argument("--slo", default="standard",
                    choices=["interactive", "standard", "batch"],
                    help="SLO class stamped on every submitted request "
                         "(TTFT deadline + tolerable prefill stall; "
                         "drives the chunked scheduler)")
    ap.add_argument("--fault-plan", default="off", choices=["off", "chaos"],
                    help="paged engine: arm a seeded deterministic fault "
                         "schedule — node failures quarantining their "
                         "page stripe, transient admission rejections "
                         "under capped backoff, a straggler slowdown — "
                         "and print the recovery report "
                         "(docs/FAULT_TOLERANCE.md)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the chaos fault schedule")
    ap.add_argument("--fault-horizon", type=int, default=48,
                    help="steps the chaos fault schedule spans")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="paged engine: arm the step-clock flight "
                         "recorder and export the run as Chrome "
                         "trace-event JSON (Perfetto-loadable; "
                         "docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-out", default=None, metavar="METRICS.json",
                    help="paged engine: dump the unified metrics "
                         "registry snapshot (counters, gauges, "
                         "percentile digests) as JSON")
    ap.add_argument("--trace-capacity", type=int, default=4096,
                    help="flight-recorder ring size (spans kept; "
                         "oldest evicted first)")
    args = ap.parse_args()
    if args.prompt_len < 1:
        import sys
        print(f"error: --prompt-len must be >= 1 (got {args.prompt_len}): "
              "an empty prompt has no KV to prefill and no position to "
              "decode from", file=sys.stderr)
        raise SystemExit(2)
    if args.spec_k != "auto":
        args.spec_k = int(args.spec_k)

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    from repro.configs import get_config, get_tiny_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import autotune_layout, make_layout_mesh

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    predicted = None
    mesh = None
    if args.layout == "auto":
        decode_shape = ShapeConfig("serve", args.prompt_len + args.gen,
                                   args.batch, "decode")
        # serving=True prices the striped-KV traffic (§V link model on
        # the (n-1)/n remote write fraction + decode stats merge) on top
        # of the transformer collectives
        best, ranked = autotune_layout(cfg, decode_shape,
                                       mode=args.link_mode,
                                       serving=args.engine == "paged")
        predicted = best
        print(f"[cost-engine] {len(ranked)} candidate layouts for "
              f"{best.layout.n_chips} chips ({args.link_mode} mode):")
        for est in ranked:
            tag = " <= chosen" if est is ranked[0] else ""
            print(f"[cost-engine]   {est.describe()}{tag}")
        print(f"[cost-engine] predicted decode step "
              f"{best.step_time_s * 1e3:.3f} ms "
              f"({best.tokens_per_s:.0f} tok/s)")
        mesh = make_layout_mesh(best.layout)
    elif args.data * args.model > 1:
        import jax
        if args.engine == "dense" \
                or len(jax.devices()) >= args.data * args.model:
            mesh = make_test_mesh(args.data, args.model)
        # else: paged on a short host keeps host-side striping only
        # (allocator accounting without device placement)

    if args.engine == "paged":
        n_nodes = (predicted.layout.model if predicted is not None
                   else max(args.model, 1))
        outputs, m, eng = run_paged(args, cfg, n_nodes=n_nodes, mesh=mesh)
        tokens = sum(len(t) for t in outputs.values())
        print(f"[paged] served {m['finished']} requests, {tokens} tokens "
              f"in {m['seconds']:.2f}s "
              f"({tokens / max(m['seconds'], 1e-9):.1f} tok/s, "
              f"{m['steps']} engine steps)")
        print(f"[paged] TTFT mean {m['ttft_steps_mean']:.1f} / p95 "
              f"{m['ttft_steps_p95']:.1f} steps; peak pages "
              f"{m['peak_pages']} ({m['page_occupancy'] * 100:.0f}% of pool);"
              f" {m['preemptions']} preemptions")
        mode = "fused" if args.fused else "per-step"
        print(f"[paged] {mode}: {m['windows']} device dispatches for "
              f"{m['steps']} scheduler steps; host<->device syncs "
              f"{m['h2d_syncs']} h2d + {m['d2h_syncs']} d2h "
              f"({m['syncs_per_token']:.2f} per token); decode "
              f"{m['decode_tok_per_s']:.1f} tok/s")
        if eng.spec is not None:
            print(f"[paged] spec decode: {m['model_passes']} model passes "
                  f"for {m['tokens_out']} tokens "
                  f"({m['dispatches_per_token']:.2f} dispatches/token); "
                  f"accept rate {m['accept_rate'] * 100:.0f}% "
                  f"({m['spec_accepted']}/{m['spec_drafted']} drafts over "
                  f"{m['spec_verifies']} verifies, "
                  f"{m['spec_rollbacks']} page rollbacks)")
            if eng.spec.adaptive:
                print(f"[paged] spec depth: adaptive, mean K "
                      f"{m['spec_k_mean']:.1f}; draft+verify "
                      f"{m['spec_verify_s']:.3f}s of {m['decode_s']:.3f}s "
                      f"decode")
        if eng.sched.chunked:
            print(f"[paged] chunked prefill: {m['chunk_tasks']} chunks in "
                  f"{m['chunk_rounds']} rounds "
                  f"({m['chunk_dispatches']} dispatches, "
                  f"{m['chunk_preemptions']} mid-prefill preemptions); "
                  f"SLO class {args.slo}, p99 TTFT "
                  f"{m['ttft_steps_p99']:.1f} steps")
        if eng.cache is not None:
            print(f"[paged] prefix cache: {m['prefix_hit_rate'] * 100:.0f}%"
                  f" hit rate ({m['prefix_hits']}/{m['prefix_lookups']}), "
                  f"{m['prefill_tokens_cached']} prefill tokens served "
                  f"from shared pages ({m['prefill_tokens']} computed), "
                  f"{m['cow_copies']} COW copies, {m['shared_pages']} tree "
                  f"pages, {m['prefix_evictions']} evictions, "
                  f"{m['bytes_deduped'] / 1024:.0f} KiB deduped")
        if eng.faults is not None:
            print(f"[paged] fault plane: {m['node_failures']} node "
                  f"failures / {m['node_joins']} re-joins, "
                  f"{m['pages_quarantined']} pages quarantined, "
                  f"{m['requests_recovered']} requests recovered "
                  f"({m['tokens_recomputed']} tokens recomputed), "
                  f"{m['requests_shed']} shed, "
                  f"{m['transient_rejections']} transient rejections; "
                  f"recovery p99 {m['recovery_steps_p99']:.1f} steps, "
                  f"{m['quarantined_served']} stale reads")
        if eng.tracer is not None:
            from repro.serving.telemetry import format_model_error
            eng.tracer.finalize(eng.sched.step_idx)
            report = eng.tracer.model_error_report()
            if report:
                print("[trace] per-phase model error "
                      "(cost-engine predicted vs measured wall):")
                print(format_model_error(report))
            if args.trace_out:
                eng.tracer.write_chrome(args.trace_out)
                n = len(eng.tracer.chrome_trace()["traceEvents"])
                print(f"[trace] wrote {n} trace events to "
                      f"{args.trace_out} (load in Perfetto / "
                      f"chrome://tracing; {eng.tracer.recorded} spans "
                      f"recorded, {eng.tracer.dropped} evicted)")
        if args.metrics_out:
            import json
            with open(args.metrics_out, "w") as f:
                json.dump(eng.registry.snapshot(), f, indent=2,
                          sort_keys=True)
            print(f"[metrics] wrote registry snapshot to "
                  f"{args.metrics_out}")
        report_fleet(args, cfg, eng, tokens)
        measured = m["step_s"]
    else:
        outputs, stats = run_dense(args, cfg, mesh)
        print(f"served {stats['requests']} requests, {stats['tokens']} "
              f"tokens in {stats['seconds']:.2f}s "
              f"({stats['tokens'] / max(stats['seconds'], 1e-9):.1f} tok/s)")
        measured = stats["step_s"]
    if predicted is not None:
        # warmup ran before the clock: this ratio is steady-state only
        print(f"[cost-engine] predicted {predicted.step_time_s * 1e3:.3f}"
              f" ms vs measured {measured * 1e3:.3f} ms per decode step "
              f"(warmup excluded; ratio "
              f"{measured / predicted.step_time_s:.2f}x — the engine "
              f"models v5e-class chips, not this host)")


if __name__ == "__main__":
    main()
