"""HuBERT-XLarge backbone [arXiv:2106.07447; unverified].

Audio encoder-only transformer: 48L, d_model=1280, 16 heads (no GQA:
kv=16), d_ff=5120, output vocab (codebook targets) = 504.  Standard GELU
MLP (no GLU), bidirectional attention, no rotary (the conv feature
extractor + conv positional embedding frontend is a STUB: ``input_specs()``
feeds precomputed frame embeddings (B, S, d_model)).

Encoder-only: no decode shapes (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope=False,
    act="gelu",
    gated_ffn=False,
    embed_inputs=False,   # frame-embedding frontend stub
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, attn_block_q=16, attn_block_kv=32)
