"""Swallow §III + §X-B composed: the paged-KV continuous-batching engine.

What is reproduced: the farmer-worker loop (§III, C3) running against a
striped memory server (§X-B) — the device-side half of the serving
subsystem.  Per-layer KV pools (``lm.init_paged_caches``) are the
striped store, the block-table matrix is the address map, and the jitted
steps (``make_paged_serve_step`` / ``make_paged_serve_scan``) decode
every occupied slot of the batch while :mod:`repro.serving.scheduler`
refills freed slots with priced prefills.

Device-resident decode (the paper's C/C lesson applied to the
host↔device "interconnect"): slot state — tokens, positions, block
tables — lives in device arrays; the host keeps a numpy *mirror* that is
pushed only when scheduler bookkeeping dirties it (admission, growth,
preemption, completion), and results are pulled once per fused window,
not once per token.  ``h2d_syncs`` / ``d2h_syncs`` count those events:
per-step mode is O(1 per token), fused mode O(1 per window) — the same
per-message-overhead argument Swallow §V makes for its interconnect.

Fused windows decode K tokens in one ``lax.scan`` dispatch; K is the
scheduler's ``safe_horizon`` (no completion, page-boundary crossing
without a pre-reserved page, or pending priced admission inside the
window), bucketed to powers of two so at most log2(max_window)+1 scan
shapes ever compile.  ``fused=False`` keeps the original per-step
semantics as the K=1 fallback.

Prefix sharing (``prefix_cache=True``): admissions are matched against
:mod:`repro.serving.prefix_cache` — the radix tree over token IDs whose
nodes own ref-counted pages on the striped store.  A hit skips prefill
for the cached prefix (the block row simply points at the shared pages —
the paged attention gather needs no kernel change), COW-copies the
divergence page on device when the match ends mid-page, and prefills
only the uncached suffix through the teacher-forced decode scan.  Greedy
tokens are bit-identical with the cache on or off (pinned by
tests/test_prefix_cache.py) — sharing moves KV entries, never changes
them.

Speculative decoding (``spec_decode=True``): each running sequence
drafts up to K tokens from its own prompt+output history
(:mod:`repro.serving.spec_decode`, weightless n-gram lookup) and a
single ``verify_window_paged`` dispatch scores all K+1 positions against
the paged KV — the accepted prefix plus the verifier's bonus token land
from ONE model pass, cutting *model dispatches per emitted token* below
1.0 (the ``dispatches_per_token`` observable).  Drafting itself runs on
device by default (``spec_proposer="device"``): each slot's token
history is a device-resident row appended by the fused
draft+verify+accept dispatch chain (``make_spec_draft_verify``), so a
steady-state speculation window moves no draft bytes over the
host↔device link at all — the payload-per-message lesson applied to the
drafting path, which is what turns PR 5's dispatch-count win into a
wall-clock win.  K adapts per request from an acceptance EWMA
(``spec_k="auto"``) and a priced gate (:meth:`PagedEngine._spec_gate`,
on :func:`repro.core.costs.estimate` numbers) buys a verify only where
it beats the scan it displaces.  Speculation is capped by the
scheduler's ``safe_horizon`` (no scheduling event inside the window),
rejected KV is appended then rolled back (``PageAllocator.truncate_to``
releases whole rejected pages; partial slots are masked by position),
and slots the gate prices out ride the normal fused window — so greedy
tokens stay bit-identical with speculation on or off
(tests/test_spec_decode.py, tests/test_serving_fuzz.py).

Chunked prefill (``chunked_prefill=True``): admission no longer pays a
prompt's whole prefill up front.  Admitted requests sit in the
scheduler's ``prefilling`` state (pages fully allocated, slot held, no
device mirror entry) and each engine step dispatches a budgeted round of
page-aligned chunk slices (``make_chunk_prefill`` — the suffix-prefill
body at successive offsets) *before* the decode window, so a 10k-token
prompt costs each decoding tenant at most the SLO-priced chunk budget
per window instead of a full stall.  Only the final chunk's logits are
pulled (the first token); composing chunks writes bit-identical KV to
one monolithic dispatch, so greedy tokens match with chunking on or off
(tests/test_serving_fuzz.py's 32-config cube).

Greedy decoding throughout: fused vs per-step vs dense token equality is
an acceptance gate (tests/test_serving.py), and it is also what makes
recompute-preemption exact.
"""
from __future__ import annotations

import contextlib
import functools
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.memory_server import stripe_slab_index
from repro.serving.paged_kv import NULL_PAGE, PageAllocator
from repro.serving.scheduler import ContinuousBatchScheduler, Request
from repro.serving.spec_decode import NGramSpec, SpecStats
from repro.serving.telemetry import MetricsRegistry, StepTracer, counter_attr


@functools.lru_cache(maxsize=8)
def _jitted_steps(cfg, mesh=None):
    """One set of jitted step functions per (hashable, frozen) config —
    engines constructed with the same config share compile caches
    instead of re-tracing per instance (a large win for the test suite
    and for on/off A-B benchmark runs).  Donation is per-call, so
    sharing the jitted callables across engines is safe: each engine
    donates its own pools.  Bounded so a long-lived process sweeping
    many configs does not retain compiled executables forever.
    ``mesh`` (hashable) keys the cache too: the same config traced with
    striped pools compiles different (shard_map) programs than the
    single-device engine, and the two must never share executables."""
    import jax
    from repro import steps as steps_mod
    return {
        "prefill": jax.jit(steps_mod.make_paged_prefill_step(cfg),
                           donate_argnums=(2,)),
        "serve": jax.jit(steps_mod.make_paged_serve_step(cfg),
                         donate_argnums=(2,)),
        "scan": jax.jit(steps_mod.make_paged_serve_scan(cfg),
                        static_argnames=("k",), donate_argnums=(2,)),
        "suffix": jax.jit(steps_mod.make_paged_suffix_prefill(cfg),
                          donate_argnums=(2,)),
        "chunk": jax.jit(steps_mod.make_chunk_prefill(cfg),
                         donate_argnums=(2,)),
        "verify": jax.jit(steps_mod.make_verify_window(cfg),
                          donate_argnums=(2,)),
        "spec": jax.jit(steps_mod.make_spec_draft_verify(cfg),
                        static_argnames=("W", "max_n", "min_n"),
                        donate_argnums=(1, 2)),
        "copy_page": jax.jit(steps_mod.make_page_copy(),
                             donate_argnums=(0,)),
    }


class PagedEngine:
    """Paged-KV serving engine over one model + one device mesh.

    ``max_len`` bounds prompt+gen per sequence; the block table has
    ``ceil(max_len / page_size)`` entries per slot.  ``n_pages`` includes
    the reserved null page.  ``fused=True`` decodes in multi-token
    windows of up to ``max_window`` steps per dispatch; ``fused=False``
    is the per-step fallback with identical tokens.

    ``trace=True`` arms the :class:`~repro.serving.telemetry.StepTracer`
    flight recorder (request-lifecycle + dispatch spans, Chrome-trace
    export); tracing never feeds back into scheduling, so tokens are
    bit-identical on or off.
    """

    # every engine counter is one registry slot exposed as an attribute
    # (same external names, one implementation — see serving/telemetry.py)
    steps_run = counter_attr()
    windows_run = counter_attr()
    decode_steps = counter_attr()
    decode_tokens = counter_attr()
    tokens_emitted = counter_attr()
    decode_time_s = counter_attr()
    spec_time_s = counter_attr()       # draft+verify subset of decode_time_s
    h2d_syncs = counter_attr()
    d2h_syncs = counter_attr()
    block_row_writes = counter_attr()
    peak_pages = counter_attr()
    prefill_tokens = counter_attr()    # prompt tokens actually computed
    chunk_dispatches = counter_attr()  # chunked-prefill model dispatches
    # sequential model executions (a fused K-scan counts K): the
    # denominator-side of dispatches_per_token, the observable
    # speculative decoding attacks
    model_passes = counter_attr()
    # fault-plane counters (repro.serving.faults)
    node_failures = counter_attr()
    node_joins = counter_attr()
    pages_quarantined_total = counter_attr()
    requests_recovered = counter_attr()
    tokens_recomputed = counter_attr()  # emitted tokens discarded by resets
    quarantined_served = counter_attr()  # MUST stay 0: stale-read guard hits

    def __init__(self, cfg, params, *, max_batch: int = 4,
                 page_size: int = 16, n_pages: int = 64,
                 max_len: int = 256, n_nodes: int = 1,
                 link_mode: str = "circuit", prefill_budget: float = 2.0,
                 fused: bool = True, max_window: int = 8,
                 prefix_cache: bool = False, spec_decode: bool = False,
                 spec_k=8, spec_ngram: int = 3,
                 spec_proposer: str = "device",
                 chunked_prefill: bool = False, chunk_tokens: int = 0,
                 fault_plan=None, trace: bool = False,
                 trace_capacity: int = 4096, mesh=None):
        import jax.numpy as jnp
        from repro.models import lm, modules as nn

        assert lm.paged_decodable(cfg), \
            f"{cfg.name} is not paged-decodable (attention-only, causal)"
        assert spec_proposer in ("device", "host")
        # mesh: a jax Mesh whose "model" axis stripes the page pools
        # (page p lives on node p % M via core/memory_server
        # .stripe_slab_index — the host allocator's striped_owner
        # accounting and the device placement agree by construction).
        # The "data" axis, when present, just replicates engine work.
        self.mesh = mesh
        self._stripe = 1
        if mesh is not None and "model" in getattr(mesh, "axis_names", ()):
            self._stripe = int(mesh.shape["model"])
        if self._stripe > 1:
            if n_nodes == 1:
                n_nodes = self._stripe
            elif n_nodes != self._stripe:
                raise ValueError(
                    f"n_nodes={n_nodes} disagrees with the mesh's model "
                    f"degree {self._stripe}: the host allocator's stripe "
                    "and the device stripe must be the same partition")
            if n_pages % self._stripe:
                raise ValueError(
                    f"n_pages={n_pages} not divisible by the stripe "
                    f"degree {self._stripe}: every node must own an "
                    "equal contiguous slab shard")
        # the registry must exist before any counter_attr assignment below
        self.registry = MetricsRegistry()
        self.tracer = StepTracer(capacity=trace_capacity) if trace else None
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.nmax = -(-max_len // page_size)
        self.fused = fused
        self.max_window = max(1, int(max_window))
        self.spec = None
        self._spec_host = spec_proposer == "host"
        if spec_decode:
            # "auto": adapt K per request from its acceptance EWMA, with
            # headroom to draft past max_window (a deep verify is still
            # ONE dispatch — depth is nearly free when acceptance earns it)
            if spec_k == "auto":
                self.spec = NGramSpec(
                    k=max(2 * self.max_window - 1, 3), max_n=spec_ngram,
                    adaptive=True)
            else:
                self.spec = NGramSpec(k=int(spec_k), max_n=spec_ngram)
        self._jnp = jnp

        self.alloc = PageAllocator(n_pages=n_pages, page_size=page_size,
                                   n_nodes=n_nodes, registry=self.registry)
        self.cache = None
        if prefix_cache:
            from repro.serving.prefix_cache import PrefixCache
            self.cache = PrefixCache(self.alloc, registry=self.registry)
            # under pool pressure, LRU-evict cold cache pages before the
            # scheduler resorts to preempting tenants
            self.alloc.reclaim = self.cache.evict
        self.link_mode = link_mode
        self.n_nodes = n_nodes
        from repro.configs.base import ShapeConfig
        self.decode_estimate = self._estimate(
            ShapeConfig("serve_decode", max_len, max_batch, "decode"),
            link_mode, n_nodes)
        self.sched = ContinuousBatchScheduler(
            self.alloc, max_batch,
            prefill_cost_s=self._prefill_cost(link_mode, n_nodes),
            decode_cost_s=self.decode_estimate.step_time_s,
            prefill_budget=prefill_budget,
            prefix_cache=self.cache,
            chunked=chunked_prefill, chunk_tokens=chunk_tokens,
            registry=self.registry, tracer=self.tracer)

        self.pools = lm.init_paged_caches(cfg, n_pages=n_pages,
                                          page_size=page_size)
        if self._stripe > 1:
            # place each pool leaf's page axis (third-from-last) over the
            # mesh: node d holds slab rows [d*P/M, (d+1)*P/M) — exactly
            # the pages stripe_slab_index maps to it
            import jax
            from repro.parallel.sharding import SERVING_RULES, use_sharding
            with use_sharding(mesh, SERVING_RULES) as env:
                self.pools = jax.device_put(
                    self.pools,
                    jax.tree.map(
                        lambda a: env.sharding(
                            *(((None,) * (a.ndim - 3))
                              + ("pages", None, None))),
                        self.pools))
        steps = _jitted_steps(cfg, mesh if self._stripe > 1 else None)
        self._prefill = steps["prefill"]
        self._serve = steps["serve"]
        self._scan = steps["scan"]
        self._suffix = steps["suffix"]
        self._chunk = steps["chunk"]
        self._verify = steps["verify"]
        self._spec_step = steps["spec"]
        self._copy_page = steps["copy_page"]
        # KV bytes one token occupies across the whole stack (k + v, every
        # layer) — the unit behind the bytes_deduped gauge
        self.kv_bytes_per_token = (cfg.n_layers * 2 * cfg.n_kv_heads
                                   * cfg.head_dim
                                   * np.dtype(nn.dt(cfg.activation_dtype))
                                   .itemsize)
        # host MIRROR of slot state; the device copies are authoritative
        # between window boundaries
        self.block_tables = np.full((max_batch, self.nmax), NULL_PAGE,
                                    np.int32)
        self.tokens = np.zeros((max_batch, 1), np.int32)
        self.pos = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), np.int32)
        # device-resident slot state (synced from the mirror on demand)
        self.d_tokens = jnp.asarray(self.tokens)
        self.d_pos = jnp.asarray(self.pos)
        self.d_block = jnp.asarray(self.block_tables)
        self.d_active = jnp.asarray(self.active)
        self._dirty = False
        self._dirty_block = False
        # dirty-tracking signature per slot: (rid, preemptions, n_pages)
        self._slot_sig: List[Optional[tuple]] = [None] * max_batch
        # device-resident token histories for speculative drafting: row s
        # holds slot s's prompt+output tokens (the device proposer's
        # input AND output — accepted emissions are appended on device,
        # so steady-state speculation pushes no history at all).
        # _hist_state[s] = ((rid, preemptions), device-valid length):
        # the dirty-tracking key that decides when a row must be pushed
        self.d_hist = jnp.zeros((max_batch, max_len), jnp.int32) \
            if self.spec is not None else None
        self._hist_state: List[Optional[tuple]] = [None] * max_batch
        self._n_submitted = 0
        # seed every registry counter key (descriptors write through);
        # zeroing here keeps the snapshot schema complete from step 0
        self.steps_run = 0
        self.windows_run = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        self.tokens_emitted = 0
        self.decode_time_s = 0.0
        self.spec_time_s = 0.0
        self.h2d_syncs = 0
        self.d2h_syncs = 0
        self.block_row_writes = 0
        self.peak_pages = 0
        self.prefill_tokens = 0
        self.chunk_dispatches = 0
        self.model_passes = 0
        self.node_failures = 0
        self.node_joins = 0
        self.pages_quarantined_total = 0
        self.requests_recovered = 0
        self.tokens_recomputed = 0
        self.quarantined_served = 0
        # dispatch-span attribution: (predicted seconds, predicted §VI
        # joules across the fleet) per prefill-shaped width, memoized —
        # the cost engine prices each width once
        self._pred_cache: Dict[int, tuple] = {}
        # predicted stripe-interconnect cost per (tokens, merges) shape
        self._comms_cache: Dict[tuple, tuple] = {}
        self.faults = None
        if fault_plan is not None:
            self.install_faults(fault_plan)
        self.t0 = time.time()

    def install_faults(self, plan) -> None:
        """Attach a :class:`repro.serving.faults.FaultPlan`; its step-0
        is the *current* scheduler step, so install after warmup and
        ``reset_metrics`` to keep warmup traffic out of the chaos
        window."""
        from repro.serving.faults import FaultPlane
        self.faults = FaultPlane(plan, self.n_nodes,
                                 epoch=self.sched.step_idx,
                                 registry=self.registry)
        self.sched.transient_gate = self.faults.transient_gate

    def reset_metrics(self):
        """Zero every counter/clock/digest (e.g. after a warmup pass)
        while keeping the compiled steps, pools and allocator state.
        One registry reset covers the engine, scheduler, allocator
        gauges, prefix-cache and fault-plane counters AND the streaming
        histogram digests — warmup traffic must not survive into
        chaos/SLO percentiles.  The prefix-cache *tree* is kept (call
        ``cache.clear()`` to start cold); its counters restart.  The
        tracer ring restarts too, so an exported trace begins at the
        post-warmup epoch."""
        self.registry.reset()
        self.sched.finished.clear()
        self._n_submitted = 0
        self.sched.shed.clear()
        self.sched.recovery_steps.clear()
        if self.spec is not None:
            self.spec.stats = SpecStats()
        if self.tracer is not None:
            self.tracer.reset()
        self.t0 = time.time()

    # -- cost-engine pricing (the scheduler's admission inputs) ------------
    def _estimate(self, shape, link_mode, n_nodes):
        from repro.core import costs
        return costs.estimate(self.cfg, costs.Layout(data=1, model=n_nodes),
                              link_mode, shape)

    def _prefill_cost(self, link_mode, n_nodes):
        from repro.configs.base import ShapeConfig

        def cost(prompt_len: int) -> float:
            shape = ShapeConfig("serve_prefill", max(prompt_len, 1), 1,
                                "prefill")
            return self._estimate(shape, link_mode, n_nodes).step_time_s
        return cost

    # -- predicted-vs-measured attribution (telemetry spans) ---------------
    def _serving_comms(self, n_tokens: int, n_merges: int) -> tuple:
        """(predicted seconds, predicted wire bytes/device) of the stripe
        interconnect traffic one dispatch implies — the §V link model on
        the (M-1)/M remote fraction of ``n_tokens`` KV writes plus
        ``n_merges`` per-layer decode-partials merges.  (0, 0) on a
        single stripe.  Memoized per shape."""
        if self._stripe <= 1:
            return (0.0, 0.0)
        key = (int(n_tokens), int(n_merges))
        hit = self._comms_cache.get(key)
        if hit is None:
            from repro.core import costs
            hit = self._comms_cache[key] = costs.serving_comm_cost(
                self.cfg, costs.Layout(data=1, model=self._stripe),
                self.link_mode, n_tokens=key[0], n_merges=key[1])
        return hit

    def _predict_prefill(self, n_tokens: int) -> tuple:
        """(predicted seconds, predicted joules[, comms seconds, comms
        bytes]) for one prefill-shaped dispatch of ``n_tokens`` — prices
        prefill, suffix prefill, chunk slices and spec verify widths.
        Memoized per width; the comms tail appears only under a stripe."""
        n = max(int(n_tokens), 1)
        hit = self._pred_cache.get(n)
        if hit is None:
            from repro.configs.base import ShapeConfig
            est = self._estimate(
                ShapeConfig("serve_prefill", n, 1, "prefill"),
                self.link_mode, self.n_nodes)
            hit = (est.step_time_s, est.energy.total_j * self.n_nodes)
            if self._stripe > 1:
                hit = hit + self._serving_comms(n, 0)
            self._pred_cache[n] = hit
        return hit

    def _predict_scan(self, k: int) -> tuple:
        """(seconds, joules[, comms seconds, comms bytes]) for a fused
        K-step decode window — K times the admission-priced decode step
        (each step writes one KV entry per slot and merges the stripes'
        decode partials once)."""
        base = (k * self.sched.decode_cost_s,
                k * self.decode_estimate.energy.total_j * self.n_nodes)
        if self._stripe > 1:
            cs, cb = self._serving_comms(self.max_batch, 1)
            base = base + (k * cs, k * cb)
        return base

    def _predict_cow(self) -> tuple:
        """(seconds, joules) for one device page copy: read + write one
        page of KV through HBM (the §VI traffic term; no FLOPs)."""
        from repro.core.energy import step_energy
        from repro.launch.mesh import HBM_BW
        nbytes = 2 * self.page_size * self.kv_bytes_per_token
        secs = nbytes / HBM_BW
        return secs, step_energy(flops_per_chip=0.0,
                                 hbm_bytes_per_chip=nbytes,
                                 ici_bytes_per_chip=0.0,
                                 step_seconds=secs).total_j

    _NULLCTX = contextlib.nullcontext()

    def _span(self, phase: str, predfn=None, **extra):
        """Dispatch-span context: a no-op when tracing is off (predfn is
        never called — zero cost-model work), else a
        :meth:`StepTracer.dispatch` span stamped with the current step
        and the cost engine's (seconds, joules) prediction."""
        if self.tracer is None:
            return self._NULLCTX
        vals = predfn() if predfn is not None else (0.0, 0.0)
        ps, pj = vals[0], vals[1]
        if len(vals) >= 4:
            # striped engine: the predicted interconnect share rides the
            # span so rollup_dispatch_events can attribute it per phase
            extra = dict(extra, predicted_comms_s=vals[2],
                         comms_bytes=vals[3])
        return self.tracer.dispatch(phase, self.sched.step_idx,
                                    predicted_s=ps, predicted_j=pj, **extra)

    def _flight_dump(self, reason: str) -> Optional[str]:
        """Invariant-violation post-mortem: dump the flight recorder's
        last N spans + a registry snapshot before the caller raises.
        No tracer armed -> no dump (never mask the original error)."""
        if self.tracer is None:
            return None
        try:
            path = self.tracer.flight_dump(reason, registry=self.registry)
        except OSError:
            return None
        print(f"[flight-recorder] dumped last {len(self.tracer.spans)} "
              f"spans to {path}")
        return path

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, gen: int, *, tenant: str = "default",
               rid: Optional[str] = None, slo: str = "standard") -> Request:
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            # a request must carry at least one token: prefill needs a
            # position to produce the first logit, and the allocator's
            # pages_for(0) == 0 means an empty prompt would occupy a
            # scheduler slot while owning no pages at all
            raise ValueError(
                "empty prompt: zero-length requests are rejected at "
                "submit (a prompt needs >= 1 token to prefill a first "
                "logit)")
        assert prompt.shape[0] + gen <= self.max_len
        rid = rid or f"r{self._n_submitted}"
        self._n_submitted += 1
        key = tuple(int(t) for t in prompt) if self.cache is not None \
            else None
        req = Request(rid=rid, prompt_len=int(prompt.shape[0]), gen=gen,
                      tenant=tenant, prompt=prompt, prompt_key=key, slo=slo)
        self.sched.submit(req)
        return req

    # -- node failure / re-join (the fault plane's engine half) ------------
    def fail_node(self, node: int) -> set:
        """A stripe of the §X-B DSM went dark: quarantine its pages,
        invalidate the prefix-cache subtrees that lived on them, reset
        every RUNNING/PREFILLING request whose block table touches them
        (exact greedy recompute through whatever cache survived), and
        shed requests the shrunken pool can never fit again.  Idempotent
        per down node.  Called by the :mod:`repro.serving.faults`
        watchdog; callable directly by tests and operators."""
        quar = self.alloc.fail_node(node)
        if not quar:
            return quar
        self.node_failures += 1
        self.pages_quarantined_total += len(quar)
        if self.cache is not None:
            # tree-wide: a lost interior page strands its whole subtree
            self.cache.invalidate_pages(quar)
        victims = [r for r in (list(self.sched.running.values())
                               + list(self.sched.prefilling.values()))
                   if not quar.isdisjoint(self.alloc.held.get(r.rid, ()))]
        for req in victims:
            self.tokens_recomputed += len(req.tokens)
            self.sched.fault_reset(req)
        self.requests_recovered += len(victims)
        self.sched.shed_infeasible(self.alloc.allocatable_pages)
        self._assert_no_quarantined()
        return quar

    def join_node(self, node: int) -> int:
        """Elastic re-join: the node's quarantined pages return to the
        striped free lists.  Returns how many pages rejoined."""
        was_down = node in self.alloc.failed_nodes
        restored = self.alloc.restore_node(node)
        if was_down:
            self.node_joins += 1
        return restored

    def _assert_no_quarantined(self) -> None:
        """The never-re-served invariant: after recovery, no live block
        table references a quarantined page."""
        quar = self.alloc.quarantined
        if not quar:
            return
        for req in (list(self.sched.running.values())
                    + list(self.sched.prefilling.values())):
            bad = quar.intersection(self.alloc.held.get(req.rid, ()))
            if bad:
                self.quarantined_served += 1
                self._flight_dump("quarantined-served")
                raise RuntimeError(
                    f"request {req.rid} still references quarantined "
                    f"pages {sorted(bad)} after recovery")

    # -- stripe boundary (logical pages -> physical slab rows) -------------
    def _phys(self, pages):
        """Translate logical page ids to physical slab rows at the device
        boundary.  The host side — allocator, scheduler, prefix cache,
        fault plane — reasons entirely in logical ids (``striped_owner``
        accounting); arrays crossing to the device carry slab rows so the
        NamedSharding over the page axis places every page on its owner
        node.  Identity on a single stripe, and NULL_PAGE (0) maps to
        row 0 on node 0 always — no special case anywhere."""
        if self._stripe == 1:
            return pages
        return stripe_slab_index(np.asarray(pages), self._stripe,
                                 self.alloc.n_pages)

    def _use_env(self):
        """The sharding context every device dispatch runs under: the
        engine's mesh when the pools are striped (so the traced steps see
        the "pages" rule and take the shard_map decode path), else a
        no-op."""
        if self._stripe > 1:
            from repro.parallel.sharding import SERVING_RULES, use_sharding
            return use_sharding(self.mesh, SERVING_RULES)
        return contextlib.nullcontext()

    # -- host mirror maintenance -------------------------------------------
    def _block_row(self, rid: str) -> np.ndarray:
        row = np.full((self.nmax,), NULL_PAGE, np.int32)
        pages = self.alloc.held[rid]
        if self.alloc.quarantined \
                and not self.alloc.quarantined.isdisjoint(pages):
            # a quarantined page about to be served is a recovery bug,
            # never a runtime condition: fail fast, count the hit
            self.quarantined_served += 1
            bad = sorted(self.alloc.quarantined.intersection(pages))
            self._flight_dump("stale-block-row")
            raise RuntimeError(
                f"block row for {rid} references quarantined pages {bad}")
        row[:len(pages)] = pages
        return row

    def _sig(self, req: Request) -> tuple:
        return (req.rid, req.preemptions, len(self.alloc.held[req.rid]))

    def _clear_slot(self, slot: int):
        self.block_tables[slot] = NULL_PAGE
        self.tokens[slot] = 0
        self.pos[slot] = 0
        self.active[slot] = 0
        self._slot_sig[slot] = None
        self._hist_state[slot] = None
        self._dirty = True
        self._dirty_block = True

    def _occupy_slot(self, req: Request, row: np.ndarray, token: int):
        self.block_tables[req.slot] = row
        self.tokens[req.slot] = token
        self.pos[req.slot] = req.pos
        self.active[req.slot] = 1
        self._slot_sig[req.slot] = self._sig(req)
        self.block_row_writes += 1
        self._dirty = True
        self._dirty_block = True

    def _refresh_slots(self):
        """Re-sync the mirror with scheduler state, rewriting only block
        rows whose page set changed (admission/growth/preemption) —
        dirty-tracked, not rebuilt per slot per step."""
        for slot, req in self.sched.running.items():
            sig = self._sig(req)
            if self._slot_sig[slot] != sig:
                self.block_tables[slot] = self._block_row(req.rid)
                self._slot_sig[slot] = sig
                self.block_row_writes += 1
                self._dirty = True
                self._dirty_block = True
            last = req.tokens[-1] if req.tokens else 0
            if self.tokens[slot, 0] != last:
                self.tokens[slot, 0] = last
                self._dirty = True
            if self.pos[slot] != req.pos:
                self.pos[slot] = req.pos
                self._dirty = True
            if not self.active[slot]:
                self.active[slot] = 1
                self._dirty = True

    def _push(self, force: bool = False):
        """One host->device sync event covering the whole slot-state
        bundle (tokens, positions, block tables, active mask)."""
        if not (self._dirty or force):
            return
        jnp = self._jnp
        self.d_tokens = jnp.asarray(self.tokens)
        self.d_pos = jnp.asarray(self.pos)
        self.d_block = jnp.asarray(self._phys(self.block_tables))
        self.d_active = jnp.asarray(self.active)
        self.h2d_syncs += 1
        self._dirty = False
        self._dirty_block = False

    def _push_block(self):
        """Push only the block tables (the one array a pure-verify
        window reads) — the scan bundle can stay dirty host-side, so
        steady-state speculation syncs nothing but page growth."""
        if not self._dirty_block:
            return
        self.d_block = self._jnp.asarray(self._phys(self.block_tables))
        self.h2d_syncs += 1
        self._dirty_block = False

    def _sync_hist(self, slot: int, req: Request):
        """Ensure the slot's device history row covers the request's
        current ``pos + 1`` tokens (prompt + emitted so far; ``pos`` is
        the next KV write position, so the last emitted token is
        history's tail).  The fused draft+verify appends emissions on
        device, so in steady state this is a no-op; a push happens only
        when the row is behind — slot reuse, preemption/recompute, or a
        scan window having advanced the request host-side."""
        need = req.pos + 1
        key = (req.rid, req.preemptions)
        st = self._hist_state[slot]
        if st is not None and st[0] == key and st[1] >= need:
            return
        row = np.zeros((self.max_len,), np.int32)
        hist = list(int(t) for t in req.prompt) + \
            [int(t) for t in req.tokens]
        row[:len(hist)] = hist
        self.d_hist = self.d_hist.at[slot].set(self._jnp.asarray(row))
        self.h2d_syncs += 1
        self._hist_state[slot] = (key, len(hist))

    # -- fused-window warmup ----------------------------------------------
    def window_sizes(self) -> List[int]:
        """The power-of-two window buckets this engine will dispatch."""
        if not self.fused:
            return [1]
        sizes, k = [], 1
        while k <= self.max_window:
            sizes.append(k)
            k *= 2
        return sizes

    def warmup_prefix(self, prompt_len: int, shared_prefix: int,
                      seed: int = 424242):
        """Precompile the cache-hit path for prompts of this shape: one
        miss (full prefill) followed by one hit sharing ``shared_prefix``
        tokens, which dispatches the COW page copy and the pow2 suffix
        bucket ``_do_prefill`` will pick for ``prompt_len - match``
        uncached tokens.  Identical prompts (prefix covers everything)
        exercise the capped match's 1-token bucket.  Call before
        ``reset_metrics``/``cache.clear()`` — both warm requests run to
        completion and their pages/stats are the caller's to reset."""
        if self.cache is None or shared_prefix <= 0:
            return
        sp = min(shared_prefix, prompt_len)
        gen = max(1, min(2, self.max_len - prompt_len))
        rng = np.random.default_rng(seed)
        base = rng.integers(2, self.cfg.vocab_size, prompt_len,
                            dtype=np.int32)
        self._n_warm = getattr(self, "_n_warm", 0) + 1
        self.submit(base, gen, rid=f"warmsfx{self._n_warm}a")
        self.run()
        variant = base.copy()
        if sp < prompt_len:
            variant[sp:] = rng.integers(2, self.cfg.vocab_size,
                                        prompt_len - sp, dtype=np.int32)
        self.submit(variant, gen, rid=f"warmsfx{self._n_warm}b")
        self.run()

    def verify_buckets(self) -> List[int]:
        """The pow2 verify widths speculation will dispatch — derived
        from the same ``_pow2_ceil`` rule the runtime uses for drafts of
        1..spec_k tokens plus the last emitted token, so warmup can
        never compile a different width set than the decode loop
        requests."""
        if self.spec is None:
            return []
        return sorted({self._pow2_ceil(m + 1)
                       for m in range(1, self.spec.k + 1)})

    def warmup_windows(self):
        """Compile every scan bucket (and, with speculation on, every
        verify bucket) against inactive slots / null rows — null-page
        writes are masked by design — so trace timing is steady-state.
        Null rows need no stripe translation: pi(0) == 0."""
        with self._use_env():
            self._warmup_windows_impl()

    def _warmup_windows_impl(self):
        jnp = self._jnp
        if self.fused or self.spec is not None:
            zeros_tok = jnp.zeros((self.max_batch, 1), jnp.int32)
            zeros_pos = jnp.zeros((self.max_batch,), jnp.int32)
            null_rows = jnp.full((self.max_batch, self.nmax), NULL_PAGE,
                                 jnp.int32)
            inactive = jnp.zeros((self.max_batch,), jnp.int32)
            for k in self.window_sizes():
                toks, _, _, self.pools = self._scan(
                    self.params, zeros_tok, self.pools, null_rows,
                    zeros_pos, inactive, k=k)
                np.asarray(toks)
            self._dirty = True        # device state was clobbered
        if self.spec is not None and not self._spec_host:
            # warm the fused draft+verify chain, one compile per pow2
            # verify width, against null rows (writes masked by design);
            # the warmup clobbers d_hist rows, so mark them all stale
            null_rows = jnp.full((self.max_batch, self.nmax), NULL_PAGE,
                                 jnp.int32)
            for w in self.verify_buckets():
                emit, _, _, self.d_hist, self.pools = self._spec_step(
                    self.params, self.d_hist, self.pools, null_rows,
                    jnp.int32(0), jnp.int32(0), jnp.int32(w - 1),
                    W=w, max_n=self.spec.max_n, min_n=self.spec.min_n)
                np.asarray(emit)
            # warm the history row-set scatter _sync_hist dispatches
            self.d_hist = self.d_hist.at[0].set(
                jnp.zeros((self.max_len,), jnp.int32))
            self._hist_state = [None] * self.max_batch
        else:
            null_row = jnp.full((self.nmax,), NULL_PAGE, jnp.int32)
            for w in self.verify_buckets():
                logits, self.pools = self._verify(
                    self.params, jnp.zeros((1, w), jnp.int32), self.pools,
                    null_row, jnp.int32(0), jnp.int32(1))
                np.asarray(logits)

    # -- prefill (full, or cached-prefix COW + suffix) ---------------------
    def _do_prefill(self, req: Request, row: np.ndarray, jnp) -> int:
        """Write the request's prompt KV and return its first greedy
        token.  On a prefix-cache hit, the cached prefix is skipped: the
        block row already points at the shared pages, the divergence
        page (if the match ends mid-page) is COW-copied on device, and
        only the uncached suffix runs — through the teacher-forced
        decode scan, no kernel change."""
        L = req.cached_tokens
        match = req.prefix_match
        if self.cache is None or L <= 0:
            with self._span("prefill",
                            lambda: self._predict_prefill(req.prompt_len),
                            rid=req.rid, tokens=req.prompt_len):
                logits, self.pools = self._prefill(
                    self.params, jnp.asarray(req.prompt[None]), self.pools,
                    jnp.asarray(self._phys(row)))
                self.h2d_syncs += 1    # prompt + block row push
                self.model_passes += 1
                tok = int(jnp.argmax(logits, -1)[0, 0])
                self.d2h_syncs += 1    # blocking first-token pull
            self.prefill_tokens += req.prompt_len
            return tok
        if match is not None and match.cow_src is not None:
            # diverging inside a shared page: copy it into the request's
            # private page before any write can touch it
            dst = self.alloc.held[req.rid][L // self.page_size]
            with self._span("cow_copy", self._predict_cow, rid=req.rid):
                self.pools = self._copy_page(
                    self.pools, jnp.int32(self._phys(match.cow_src)),
                    jnp.int32(self._phys(dst)))
            self.cache.stats.cow_copies += 1
            self.cache.release_cow(match)
        suffix = np.asarray(req.prompt[L:], np.int32)
        slen = int(suffix.shape[0])
        k = self._pow2_ceil(slen)
        padded = np.zeros((1, k), np.int32)
        padded[0, :slen] = suffix
        with self._span("prefill", lambda: self._predict_prefill(k),
                        rid=req.rid, tokens=slen, cached=L):
            logits, self.pools = self._suffix(
                self.params, jnp.asarray(padded), self.pools,
                jnp.asarray(self._phys(row)), jnp.int32(L),
                jnp.int32(slen))
            self.h2d_syncs += 1        # suffix + block row push
            self.model_passes += 1
            tok = int(jnp.argmax(logits, -1)[0, 0])
            self.d2h_syncs += 1        # blocking first-token pull
        self.prefill_tokens += slen
        return tok

    # -- chunked prefill (page-aligned slices between decode windows) ------
    def _begin_chunked(self, req: Request):
        """Chunked admission: no model pass yet — only the COW copy when
        the prefix-cache match diverges mid-page, exactly as
        :meth:`_do_prefill`'s hit branch would have done.  The block row
        rides into each chunk dispatch directly (the device mirror never
        sees a prefilling slot)."""
        jnp = self._jnp
        match = req.prefix_match
        if self.cache is not None and match is not None \
                and match.cow_src is not None:
            dst = self.alloc.held[req.rid][req.cached_tokens
                                           // self.page_size]
            with self._span("cow_copy", self._predict_cow, rid=req.rid):
                self.pools = self._copy_page(
                    self.pools, jnp.int32(self._phys(match.cow_src)),
                    jnp.int32(self._phys(dst)))
            self.cache.stats.cow_copies += 1
            self.cache.release_cow(match)

    def _do_chunk(self, req: Request, start: int, n: int) -> Optional[int]:
        """Dispatch ONE page-aligned prefill chunk — positions ``start ..
        start+n-1`` — padded to a pow2 bucket (same rule as the suffix
        path, so a heavy-tailed length distribution compiles O(log)
        kernels).  Returns the first greedy token when this was the final
        chunk, else None (intermediate logits are never pulled: one d2h
        per request, not per chunk)."""
        jnp = self._jnp
        row = self._block_row(req.rid)
        seg = np.asarray(req.prompt[start:start + n], np.int32)
        w = self._pow2_ceil(n)
        padded = np.zeros((1, w), np.int32)
        padded[0, :n] = seg
        final = start + n == req.prompt_len
        with self._span("chunk_prefill", lambda: self._predict_prefill(w),
                        rid=req.rid, tokens=n, start=start):
            logits, self.pools = self._chunk(
                self.params, jnp.asarray(padded), self.pools,
                jnp.asarray(self._phys(row)), jnp.int32(start),
                jnp.int32(n))
            self.h2d_syncs += 1        # chunk + block row push
            self.model_passes += 1
            self.chunk_dispatches += 1
            tok = None
            if final:
                tok = int(jnp.argmax(logits, -1)[0, 0])
                self.d2h_syncs += 1    # blocking first-token pull
        self.prefill_tokens += n
        return tok

    def _chunk_round(self, max_window: Optional[int]) -> List[Request]:
        """One chunk round: ask the scheduler for this window's budgeted
        page-aligned slices and dispatch them before decode, so a
        request whose final chunk lands here joins the very next decode
        window."""
        k_budget = self.max_window if max_window is None \
            else max(1, min(self.max_window, max_window))
        if not self.fused:
            k_budget = 1
        finished: List[Request] = []
        for req, start, n in self.sched.plan_chunks(k_budget):
            tok = self._do_chunk(req, start, n)
            if tok is None:
                continue
            row = self._block_row(req.rid)
            if self.cache is not None:
                # all prompt pages are immutable now — graft them, same
                # as the monolithic path does right after prefill
                self.cache.insert(req.prompt_key,
                                  self.alloc.held[req.rid],
                                  req.prompt_len)
            self.sched.finish_prefill(req, tok)
            self.tokens_emitted += 1
            if req.state == "running":
                self._occupy_slot(req, row, tok)
            else:                      # gen == 1: finished at prefill
                finished.append(req)
        return finished

    # -- one engine step (a window of >= 1 scheduler steps) ----------------
    @staticmethod
    def _pow2_floor(k: int) -> int:
        # bucket to the largest power of two <= k: at most
        # log2(max_window)+1 scan shapes ever compile
        return 1 << (max(k, 1).bit_length() - 1)

    @staticmethod
    def _pow2_ceil(n: int) -> int:
        # smallest power of two >= n: the ONE bucket rule shared by the
        # suffix-prefill widths, the verify widths and verify warmup
        return 1 << max(n - 1, 0).bit_length()

    def _pick_window(self, max_window: Optional[int]) -> int:
        cap = self.max_window if max_window is None \
            else max(1, min(self.max_window, max_window))
        # quantizing inside safe_horizon keeps page reservation exact:
        # only the dispatched window's pages are grabbed ahead of need
        return self.sched.safe_horizon(cap, quantize=self._pow2_floor)

    def _spec_gate(self, active: Dict[int, Request],
                   ks: Dict[int, int], kk_est: int) -> Dict[int, int]:
        """The priced worth-it gate: a verify pass is bought only where
        the tokens it is *expected* to emit — ``e = 1 + accept_EWMA *
        K`` — beat the fused scan it displaces, on the cost engine's own
        seconds (``sched.decode_cost_s`` / ``sched.prefill_cost_s`` both
        come from :func:`repro.core.costs.estimate`).  Two regimes,
        compared in product form (no divisions):

        * pure speculation — every slot drafts, so the B verifies
          replace the scan outright: worth it iff the expected emission
          rate beats the scan's, ``sum(e) * t_scan > B * kk *
          sum(t_verify)``;
        * mixed — the scan runs anyway for the other slots, so a
          drafting slot pays its verify *on top* of the ``kk`` tokens
          the window would hand it for free, and must clear the
          marginal bar ``(e_s - kk) * t_scan > t_verify_s * B * kk``.
          Shallow drafts against a wide free window are priced out —
          the regime where PR 5's heuristic gate lost wall-clock.
        """
        if not ks:
            return ks
        price = getattr(self.sched, "prefill_cost_s", None)
        scan_s = kk_est * float(self.sched.decode_cost_s or 0.0)
        if price is None or scan_s <= 0.0:
            return dict(ks)            # unpriced scheduler: keep drafts
        n = len(active)
        e = {s: 1.0 + self.spec.rate_for(active[s].tenant) * K
             for s, K in ks.items()}
        tv = {s: float(price(self._pow2_ceil(K + 1)))
              for s, K in ks.items()}
        if len(ks) == n and sum(e.values()) * scan_s \
                > n * kk_est * sum(tv.values()):
            return dict(ks)
        return {s: K for s, K in ks.items()
                if (e[s] - kk_est) * scan_s > tv[s] * n * kk_est}

    def _spec_window(self, max_window: Optional[int]) -> List[Request]:
        """One speculative decode window: draft -> verify -> accept as a
        device-resident dispatch chain.

        Depth: each running slot asks its per-tenant controller
        (:meth:`repro.serving.spec_decode.NGramSpec.draft_k` — the
        acceptance-EWMA adaptive target, or the fixed ``spec_k``) for a
        draft depth K clamped to the scheduler's ``safe_horizon`` and
        snapped to the pow2 verify buckets; the priced gate
        (:meth:`_spec_gate`) then keeps only the verifies the cost model
        expects to beat the scan they displace.  Rejected slots ride the
        normal fused scan with speculating slots masked to null rows
        (their in-scan writes land on the null page, masked by design).

        Dispatch (``spec_proposer="device"``, the default): ONE jitted
        chain per slot — ``device_propose`` over the slot's
        device-resident history row, ``verify_window_paged`` over the
        draft, greedy acceptance, history append — with only ``(emitted,
        n_emit, m)`` pulled back; no draft ever materializes on the
        host, and steady-state windows push nothing but page growth
        (``_push_block``).  ``spec_proposer="host"`` keeps the PR-5
        reference path (host n-gram propose + padded ``_verify``): the
        middle rung of the differential oracle ladder and the hook
        adversarial tests monkeypatch.

        Speculation depth is capped by the scheduler's ``safe_horizon``
        — no scheduling event can land inside the window, and every
        write position is page-reserved up front (exact reservation, no
        pow2 quantize: a verify may write any horizon position).
        Rejected drafts roll their whole pages back via
        ``PageAllocator.truncate_to`` and forget the slot signature
        (pop-then-regrow can alias page counts).  Emitted tokens are
        bit-identical to the plain path in every mode by the acceptance
        rule (:meth:`repro.serving.spec_decode.NGramSpec.accept`)."""
        jnp = self._jnp
        finished: List[Request] = []
        cap = max(self.max_window, self.spec.k + 1)
        if max_window is not None:
            cap = max(1, min(cap, max_window))
        k = self.sched.safe_horizon(cap)
        self._refresh_slots()
        active = dict(self.sched.running)
        ks: Dict[int, int] = {}
        host_drafts: Dict[int, List[int]] = {}
        for slot, req in active.items():
            K = self.spec.draft_k(req.tenant, k)
            if K < 1:
                continue
            if self._spec_host:
                d = self.spec.propose(req.prompt, req.tokens, K)
                if not d:
                    continue          # no match: ride the scan
                host_drafts[slot] = d
                ks[slot] = len(d)
            else:
                ks[slot] = K          # draft length discovered on device
        kk_est = self._pow2_floor(min(k, self.max_window)) if self.fused \
            else 1
        ks = self._spec_gate(active, ks, kk_est)
        host_drafts = {s: d for s, d in host_drafts.items() if s in ks}
        scan_slots = [s for s in active if s not in ks]
        t_dec = time.time()
        advanced = 0          # scheduler-clock steps complete_step took
        emitted_max = 0       # largest per-slot emission this window
        tok_np = None
        if scan_slots:
            kk = kk_est
            if ks:
                # ONE sync event: canonical tokens/pos plus this window's
                # masked rows/mask (speculating slots write the null
                # page); the canonical d_block/d_active stay host-side —
                # the _dirty fold below re-pushes them next plain window
                bt = self.block_tables.copy()
                act = self.active.copy()
                for s in ks:
                    bt[s] = NULL_PAGE
                    act[s] = 0
                self.d_tokens = jnp.asarray(self.tokens)
                self.d_pos = jnp.asarray(self.pos)
                d_bt, d_act = jnp.asarray(self._phys(bt)), jnp.asarray(act)
                self.h2d_syncs += 1
            else:
                self._push(force=not self.fused)
                d_bt, d_act = self.d_block, self.d_active
            with self._span("scan", lambda: self._predict_scan(kk),
                            k=kk, slots=len(scan_slots)):
                toks, d_tok, d_pos, self.pools = self._scan(
                    self.params, self.d_tokens, self.pools, d_bt,
                    self.d_pos, d_act, k=kk)
                tok_np = np.asarray(toks).reshape(self.max_batch, kk)
            self.d2h_syncs += 1
            self.decode_steps += kk
            self.model_passes += kk
            self.windows_run += 1
            for j in range(kk):
                emitted: Dict[int, int] = {s: int(tok_np[s, j])
                                           for s in scan_slots}
                self.decode_tokens += len(emitted)
                self.tokens_emitted += len(emitted)
                finished += self.sched.complete_step(emitted)
            advanced = emitted_max = kk
            if not ks:
                # pure scan window: adopt the device carry, exactly like
                # the plain fused path
                self.d_tokens, self.d_pos = d_tok, d_pos
        st = self.spec.stats
        if ks and not self._spec_host:
            # device chain inputs: history rows for any slot that fell
            # behind (slot reuse / preemption / scan advance), plus page
            # growth — in steady state only the latter moves
            for slot in sorted(ks):
                self._sync_hist(slot, active[slot])
            self._push_block()
        for slot in sorted(ks):
            req = active[slot]
            K = ks[slot]
            t_sp = time.time()
            if self._spec_host:
                d = host_drafts[slot]
                m = len(d)
                W = self._pow2_ceil(m + 1)
                padded = np.zeros((1, W), np.int32)
                padded[0, 0] = req.tokens[-1]
                padded[0, 1:m + 1] = d
                with self._span("draft_verify",
                                lambda: self._predict_prefill(W),
                                rid=req.rid, k=K, width=W):
                    logits, self.pools = self._verify(
                        self.params, jnp.asarray(padded), self.pools,
                        jnp.asarray(self._phys(self.block_tables[slot])),
                        jnp.int32(req.pos), jnp.int32(m + 1))
                    self.h2d_syncs += 1   # draft + block row push
                    greedy = np.asarray(jnp.argmax(logits[0, :m + 1], -1),
                                        np.int32)
                    self.d2h_syncs += 1   # blocking verdict pull
                out = self.spec.accept(d, greedy)   # updates stats
            else:
                W = self._pow2_ceil(K + 1)
                with self._span("draft_verify",
                                lambda: self._predict_prefill(W),
                                rid=req.rid, k=K, width=W):
                    (emit_d, n_emit_d, m_d, self.d_hist,
                     self.pools) = self._spec_step(
                        self.params, self.d_hist, self.pools, self.d_block,
                        jnp.int32(slot), jnp.int32(req.pos), jnp.int32(K),
                        W=W, max_n=self.spec.max_n,
                        min_n=self.spec.min_n)
                    emit_np = np.asarray(emit_d)   # blocking verdict pull
                    n_emit, m = int(n_emit_d), int(m_d)
                    self.d2h_syncs += 1
                out = [int(t) for t in emit_np[:n_emit]]
                st.drafted += m
                st.accepted += n_emit - 1
                st.verifies += 1
            self.spec_time_s += time.time() - t_sp
            st.k_requested += K
            self.spec.observe(req.tenant, m, len(out) - 1)
            self.decode_steps += 1
            self.model_passes += 1
            self.windows_run += 1         # a verify IS a device dispatch
            self.decode_tokens += len(out)
            self.tokens_emitted += len(out)
            finished += self.sched.complete_spec(req, out)
            if req.state == "running" and len(out) <= m:
                # rejected drafts: release their whole pages (the kept
                # tail page's stale slots are masked by position and
                # overwritten before the write position reaches them)
                if self.alloc.truncate_to(req.rid, req.pos):
                    st.rollbacks += 1
                # pop-then-regrow can restore the same page COUNT with
                # different physical pages — invisible to the (rid,
                # preemptions, len) signature — so forget it: the next
                # refresh must rewrite the device block row
                self._slot_sig[req.slot] = None
            if not self._spec_host:
                # the fused step appended the emission on device, so the
                # row now covers exactly pos+1 tokens again — the history
                # holds only verified tokens, so rollback never touches it
                self._hist_state[slot] = ((req.rid, req.preemptions),
                                          req.pos + 1)
            emitted_max = max(emitted_max, len(out))
        if ks:
            # the device carry is stale for speculating slots (and the
            # scan saw masked rows): fold the mirror, re-push next window
            for slot, req in self.sched.running.items():
                self.tokens[slot, 0] = req.tokens[-1] if req.tokens else 0
                self.pos[slot] = req.pos
            self._dirty = True
        elif tok_np is not None:
            for slot, req in self.sched.running.items():
                self.tokens[slot, 0] = int(tok_np[slot, advanced - 1])
                self.pos[slot] = req.pos
        self.decode_time_s += time.time() - t_dec
        # the window consumed max(scan depth, deepest verified emission)
        # scheduler-clock steps; complete_step already advanced `advanced`
        self.sched.step_idx += max(emitted_max - advanced, 0)
        self.steps_run += max(emitted_max, 1)
        # adaptive state is keyed by tenant, not rid: acceptance
        # statistics are a workload property, so a tenant's next request
        # starts at the learned depth instead of re-ramping from the
        # prior (state is bounded by the tenant count — never forgotten)
        return finished

    def step(self, max_window: Optional[int] = None) -> List[Request]:
        """Plan, prefill admissions, decode one fused window (or one
        step when ``fused=False``).  ``max_window`` additionally caps
        this window (e.g. to the next trace arrival).  Returns requests
        finished this window.

        Under a striped mesh the whole step runs inside the sharding
        env, so every dispatch resolves the ``pages`` axis and routes
        paged attention through the shard_map owner-partial merge."""
        with self._use_env():
            return self._step_impl(max_window)

    def _step_impl(self, max_window: Optional[int]) -> List[Request]:
        jnp = self._jnp
        if self.faults is not None:
            # watchdog tick BEFORE planning: detections quarantine pages
            # and reset victims, so this step's plan sees the degraded
            # pool and never dispatches against a dead stripe
            self.faults.on_step(self)
        plan = self.sched.plan_step()
        finished: List[Request] = []
        for slot in range(self.max_batch):   # preempted/idle slots -> null
            if slot not in self.sched.running \
                    and self._slot_sig[slot] is not None:
                self._clear_slot(slot)
        for req in plan.admitted:
            if self.sched.chunked:
                self._begin_chunked(req)   # COW only; chunks do the rest
                continue
            row = self._block_row(req.rid)
            tok = self._do_prefill(req, row, jnp)
            if self.cache is not None:
                # the prompt's full pages are immutable from this moment
                # (decode writes land past them) — graft them so later
                # arrivals share instead of re-prefilling
                self.cache.insert(req.prompt_key,
                                  self.alloc.held[req.rid],
                                  req.prompt_len)
            self.sched.note_first_token(req, tok)
            self.tokens_emitted += 1
            if req.state == "running":     # gen > 1: occupy the slot
                self._occupy_slot(req, row, tok)
            else:                          # gen == 1: finished at prefill
                finished.append(req)
        if self.sched.chunked and self.sched.prefilling:
            # budgeted chunk round BEFORE the decode window: a prompt
            # finishing its last chunk decodes in this very window, and
            # decoding tenants see at most the budget's interference
            finished += self._chunk_round(max_window)
        if self.sched.running and self.spec is not None:
            finished += self._spec_window(max_window)
        elif self.sched.running:
            k = self._pick_window(max_window) if self.fused else 1
            self._refresh_slots()
            active = dict(self.sched.running)
            t_dec = time.time()
            with self._span("scan", lambda: self._predict_scan(k),
                            k=k, slots=len(active)):
                if self.fused:
                    self._push()
                    toks, self.d_tokens, self.d_pos, self.pools = \
                        self._scan(self.params, self.d_tokens, self.pools,
                                   self.d_block, self.d_pos, self.d_active,
                                   k=k)
                else:
                    # legacy per-step path: push the whole bundle and pull
                    # one token per scheduler step — O(1 syncs per token)
                    self._push(force=True)
                    toks, _, self.pools = self._serve(
                        self.params, self.d_tokens, self.pools,
                        self.d_block, self.d_pos)
                tok_np = np.asarray(toks)  # blocks: decode-only timing
            self.d2h_syncs += 1
            self.decode_time_s += time.time() - t_dec
            tok_np = tok_np.reshape(self.max_batch, k)
            self.decode_steps += k
            self.model_passes += k
            self.windows_run += 1
            for j in range(k):
                emitted: Dict[int, int] = {s: int(tok_np[s, j])
                                           for s in active}
                self.decode_tokens += len(emitted)
                self.tokens_emitted += len(emitted)
                finished += self.sched.complete_step(emitted)
            # fold the window's results back into the mirror; slots that
            # stayed running now match the device carry exactly, so a
            # quiet boundary pushes nothing next window
            for slot, req in self.sched.running.items():
                self.tokens[slot, 0] = int(tok_np[slot, k - 1])
                self.pos[slot] = req.pos
            self.steps_run += k
        else:
            self.sched.step_idx += 1
            self.steps_run += 1
        for slot in range(self.max_batch):   # finished slots -> null
            if slot not in self.sched.running \
                    and self._slot_sig[slot] is not None:
                self._clear_slot(slot)
        self.peak_pages = max(self.peak_pages, self.alloc.pages_in_use)
        if self.tracer is not None:
            # per-node occupancy counter track (Perfetto stacked counters)
            self.tracer.counter_sample(self.sched.step_idx,
                                       self.alloc.occupancy_by_node())
        return finished

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Step until every submitted request finished."""
        while (self.sched.waiting or self.sched.running
               or self.sched.prefilling) and self.steps_run < max_steps:
            self.step()
        if self.sched.waiting or self.sched.running or self.sched.prefilling:
            raise RuntimeError(
                f"engine wedged: {len(self.sched.waiting)} waiting / "
                f"{len(self.sched.prefilling)} prefilling / "
                f"{len(self.sched.running)} running after {max_steps} steps")
        assert self.sched.conserved(self._n_submitted)
        return self.sched.finished

    # -- observability -----------------------------------------------------
    def metrics(self) -> dict:
        from repro.serving.telemetry import HistogramDigest
        fin = self.sched.finished
        dt = max(time.time() - self.t0, 1e-9)
        ttft_d = HistogramDigest.of(
            r.first_token_step - r.arrived_step for r in fin
            if r.first_token_step is not None)
        emitted = self.tokens_emitted
        out = {
            "finished": len(fin),
            "wall_s": dt,
            "decode_s": self.decode_time_s,
            # emitted counts every token produced (prefill first tokens +
            # decode), including in-flight and preempt-discarded work;
            # finished-only is reported alongside, not silently dropped
            "tokens_out": emitted,
            "tokens_finished": sum(len(r.tokens) for r in fin),
            "steps": self.steps_run,
            "windows": self.windows_run,
            "tok_per_s": emitted / dt,
            "decode_step_s": self.decode_time_s / max(self.decode_steps, 1),
            "decode_tok_per_s": self.decode_tokens
            / max(self.decode_time_s, 1e-9),
            "h2d_syncs": self.h2d_syncs,
            "d2h_syncs": self.d2h_syncs,
            "syncs_per_token": (self.h2d_syncs + self.d2h_syncs)
            / max(emitted, 1),
            "block_row_writes": self.block_row_writes,
            # sequential model executions per emitted token — the
            # dispatch-amortization observable speculation attacks
            # (a fused K-scan is K passes; a K+1-wide verify is ONE)
            "model_passes": self.model_passes,
            "dispatches_per_token": self.model_passes / max(emitted, 1),
            "ttft_steps_mean": ttft_d.mean,
            "ttft_steps_p95": ttft_d.percentile(95),
            "ttft_steps_p99": ttft_d.percentile(99),
            "pages_in_use": self.alloc.pages_in_use,
            "peak_pages": self.peak_pages,
            "page_occupancy": self.peak_pages / max(self.alloc.n_pages - 1,
                                                    1),
            "preemptions": sum(r.preemptions for r in self.sched.all_requests),
            "prefill_tokens": self.prefill_tokens,
        }
        # recovery tail from the registry's streaming digest (observed at
        # note_first_token; same numpy semantics in the exact regime)
        out.update({
            # fault plane (repro.serving.faults): quarantine footprint,
            # recovery work, and the reset -> first-token latency tail
            "node_failures": self.node_failures,
            "node_joins": self.node_joins,
            "pages_quarantined": self.pages_quarantined_total,
            "pages_quarantined_now": self.alloc.pages_quarantined,
            "requests_recovered": self.requests_recovered,
            "requests_shed": len(self.sched.shed),
            "tokens_recomputed": self.tokens_recomputed,
            "transient_rejections": self.sched.transient_rejections,
            "quarantined_served": self.quarantined_served,
            "recovery_steps_p50": self.registry.percentile(
                "recovery_steps", 50),
            "recovery_steps_p99": self.registry.percentile(
                "recovery_steps", 99),
        })
        if self.sched.chunked:
            out.update({
                "chunk_dispatches": self.chunk_dispatches,
                "chunk_rounds": self.sched.chunk_rounds,
                "chunk_tasks": self.sched.chunk_tasks,
                "chunk_preemptions": self.sched.chunk_preemptions,
                "prefilling": len(self.sched.prefilling),
            })
        if self.spec is not None:
            s = self.spec.stats
            out.update({
                "spec_drafted": s.drafted,
                "spec_accepted": s.accepted,
                "spec_verifies": s.verifies,
                "spec_rollbacks": s.rollbacks,
                "accept_rate": s.accept_rate,
                # mean requested draft depth (the adaptive-K gauge) and
                # the draft+verify share of decode wall-clock — the
                # bench-honesty split BENCH_spec reports
                "spec_k_mean": s.k_mean,
                "spec_verify_s": self.spec_time_s,
            })
        if self.cache is not None:
            out.update(self.cache.metrics())
            out["bytes_deduped"] = (self.cache.stats.tokens_cached
                                    * self.kv_bytes_per_token)
        return out
