"""Batched serving example: continuous-batch prefill+decode over a request
queue (the farmer-worker paradigm applied to inference).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp

from repro.configs import get_tiny_config
from repro.models import lm
from repro import steps as steps_mod


def main():
    cfg = get_tiny_config("qwen3-14b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, prompt_len, gen = 4, 32, 16
    max_len = prompt_len + gen
    prefill = jax.jit(steps_mod.make_prefill_step(cfg, max_len=max_len))
    serve = jax.jit(steps_mod.make_serve_step(cfg), donate_argnums=(2,))

    requests = [jax.random.randint(jax.random.PRNGKey(i), (prompt_len,),
                                   2, cfg.vocab_size) for i in range(12)]
    served = 0
    t0 = time.time()
    while requests:
        batch = [requests.pop(0) for _ in range(min(B, len(requests) + 1))]
        while len(batch) < B:
            batch.append(batch[-1])          # pad the worker pool
        prompts = jnp.stack(batch)
        logits, caches = prefill(params, prompts)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(gen - 1):
            tok, logits, caches = serve(params, tok, caches,
                                        jnp.int32(prompt_len + i))
        served += len(batch)
    dt = time.time() - t0
    print(f"served {served} requests x {gen} tokens in {dt:.2f}s "
          f"({served * gen / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
