"""Logical-axis sharding rules (Swallow C1: explicit placement of every byte).

Model code annotates activations with *logical* axis names
(``logical_constraint(x, "batch", "seq", None)``); a rule table maps logical
names to physical mesh axes.  Outside a mesh context the annotations are
no-ops, so the same model runs on a single CPU device in tests.

Weight placement (Swallow C4 — every chip is both a compute node and a
storage node) is expressed the same way: ``param_specs`` assigns each
parameter leaf a PartitionSpec from its leaf name, giving 2-D
(FSDP x TP) sharding by default.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# ---------------------------------------------------------------------------
# Logical axis rules
# ---------------------------------------------------------------------------

# Baseline rule table (paper-faithful distributed-memory layout):
#   batch        -> farmer-worker axis (pod x data)
#   seq_sp       -> sequence-parallel residual stream (Megatron-SP)
#   heads/ffn/.. -> tensor-parallel "model" axis
#   fsdp         -> weight-shard storage axis (nodes-as-storage, C4)
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    # MoE: baseline is expert-TP ("expert_ff" over model, experts unsharded —
    # works for any expert count); the EP alternative maps "expert" -> model.
    "expert": None,
    "expert_ff": "model",
    # expert weights stay 2-D sharded (explicitly gathered inside the MoE
    # shard_map); dense weights are TP-only — dry-run HLO attribution showed
    # GSPMD gathering full weight stacks per scan iteration under 2-D
    # sharding, and the fully-sharded flat optimizer state (ZeRO-1) makes
    # dense-weight FSDP unnecessary for memory at these scales.
    "fsdp": ("pod", "data"),
    "fsdp_dense": None,
    "tp": "model",
    "stage": "pod",
    # paged-KV serving: the page axis of the KV pools is striped over the
    # TP axis with the paper's address%n rule (core/memory_server
    # .stripe_slab_index maps logical page -> physical slab row so the
    # NamedSharding over this axis places every stripe on its owner node)
    "pages": "model",
}

# Serving rule table: ONLY the paged-KV pools are sharded.  Decode
# activations are batch=1-per-request and tiny; striping them over the
# training TP rules would either fail divisibility (data axis vs
# batch 1) or force weight gathers per token.  The paper's serving
# story is the *store* that is distributed (C4 nodes-as-storage): KV
# pages live on their striped_owner node, parameters and activations
# replicate, and the decode kernel's owner-partials merge is the only
# cross-node collective.
SERVING_RULES: Dict[str, Axis] = dict(
    {k: None for k in DEFAULT_RULES}, pages="model")


@dataclass(frozen=True)
class ShardingEnv:
    mesh: Mesh
    rules: Mapping[str, Axis] = field(default_factory=lambda: DEFAULT_RULES)

    def resolve(self, logical: Axis) -> Axis:
        """Map a logical axis name to mesh axes present in this mesh."""
        if logical is None:
            return None
        mapped = self.rules.get(logical, None) if isinstance(logical, str) else logical
        if mapped is None:
            return None
        if isinstance(mapped, str):
            mapped = (mapped,)
        present = tuple(a for a in mapped if a in self.mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]

    def spec(self, *logical_axes: Axis) -> P:
        return P(*(self.resolve(a) for a in logical_axes))

    def sharding(self, *logical_axes: Axis) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical_axes))


_ENV: contextvars.ContextVar[Optional[ShardingEnv]] = contextvars.ContextVar(
    "sharding_env", default=None)


def current_env() -> Optional[ShardingEnv]:
    return _ENV.get()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[Mapping[str, Axis]] = None):
    """Activate a sharding environment (and the jax mesh context)."""
    if mesh is None:
        yield None
        return
    env = ShardingEnv(mesh, dict(DEFAULT_RULES, **(rules or {})))
    tok = _ENV.set(env)
    try:
        with mesh:
            yield env
    finally:
        _ENV.reset(tok)


def logical_constraint(x, *logical_axes: Axis):
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    env = current_env()
    if env is None:
        return x
    return jax.lax.with_sharding_constraint(x, env.sharding(*logical_axes))


# ---------------------------------------------------------------------------
# Parameter placement
# ---------------------------------------------------------------------------
# Leaf-name -> logical axes, matched on the last path component.  When the
# actual leaf has more dims than the spec (stacked scan params), leading
# dims are unsharded.
PARAM_RULES: Sequence[Tuple[str, Tuple[Axis, ...]]] = (
    # embeddings / head: vocab striped over TP = the paper's address%n
    (r"embed_table$", ("tp", "fsdp_dense")),
    (r"head_w$", ("fsdp_dense", "tp")),
    # attention
    (r"wq$", ("fsdp_dense", "tp")),
    (r"wk$", ("fsdp_dense", "tp")),
    (r"wv$", ("fsdp_dense", "tp")),
    (r"wo$", ("tp", "fsdp_dense")),
    (r"(q_norm|k_norm)$", (None,)),
    # MLA
    (r"q_a$", ("fsdp_dense", None)),
    (r"q_b$", ("fsdp_dense", "tp")),
    (r"kv_a$", ("fsdp_dense", None)),
    (r"kv_b$", ("fsdp_dense", "tp")),
    (r"(q_a_norm|kv_a_norm)$", (None,)),
    # dense FFN
    (r"w_gate$", ("fsdp_dense", "tp")),
    (r"w_up$", ("fsdp_dense", "tp")),
    (r"w_down$", ("tp", "fsdp_dense")),
    # MoE (experts striped over TP axis = expert parallelism)
    (r"router_w$", ("fsdp", None)),
    (r"router_b$", (None,)),
    (r"e_gate$", ("expert", "fsdp", "expert_ff")),
    (r"e_up$", ("expert", "fsdp", "expert_ff")),
    (r"e_down$", ("expert", "expert_ff", "fsdp")),
    # RG-LRU / Griffin
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"lru_in_(x|gate)$", ("fsdp_dense", "tp")),
    (r"lru_out$", ("tp", "fsdp_dense")),
    # block-diag gates are (heads, hd, hd) with heads=10 for recurrentgemma:
    # not divisible by TP=16, and small — replicate them
    (r"lru_(a_gate|x_gate)_w$", (None, None, None)),
    (r"lru_(a_gate|x_gate)_b$", (None, None)),
    (r"lru_a_param$", ("tp",)),
    # RWKV6 time-mix
    (r"rwkv_(wr|wk|wv|wg)$", ("fsdp_dense", "tp")),
    (r"rwkv_wo$", ("tp", "fsdp_dense")),
    (r"rwkv_mix_lora_a$", ("fsdp_dense", None, None)),
    (r"rwkv_mix_lora_b$", (None, None, "fsdp_dense")),
    (r"rwkv_decay_lora_a$", ("fsdp_dense", None)),
    (r"rwkv_decay_lora_b$", (None, "fsdp_dense")),
    (r"rwkv_(mix_base|decay_base|mix_x)$", (None,)),
    (r"rwkv_u$", ("tp", None)),
    (r"rwkv_ln_(scale|bias)$", (None,)),
    # RWKV6 channel-mix
    (r"rwkv_cm_wk$", ("fsdp_dense", "tp")),
    (r"rwkv_cm_wv$", ("tp", "fsdp_dense")),
    (r"rwkv_cm_wr$", ("fsdp_dense", None)),
    (r"rwkv_cm_mix_(k|r)$", (None,)),
    # norms & misc small
    (r"scale$", (None,)),
    (r"bias$", (None,)),
    (r"mtp_proj$", ("fsdp_dense", "tp")),
)




def _axis_size(env: ShardingEnv, resolved) -> int:
    if resolved is None:
        return 1
    axes = (resolved,) if isinstance(resolved, str) else resolved
    n = 1
    for a in axes:
        n *= env.mesh.shape[a]
    return n


def _leaf_spec(path: str, shape, env: ShardingEnv) -> P:
    ndim = len(shape)
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            axes = tuple(axes)
            if len(axes) < ndim:  # stacked scan params: leading dims unsharded
                axes = (None,) * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                raise ValueError(f"spec {axes} too long for {path} ndim={ndim}")
            # drop axes that don't divide the dim (e.g. hubert vocab=504
            # over TP=16) — the leaf is then replicated on that dim
            resolved = [env.resolve(a) for a in axes]
            resolved = [r if shape[i] % _axis_size(env, r) == 0 else None
                        for i, r in enumerate(resolved)]
            return P(*resolved)
    return P()  # replicate by default


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, env: Optional[ShardingEnv] = None):
    """PartitionSpec pytree for a parameter pytree (by leaf-name rules)."""
    env = env or current_env()
    if env is None:
        return jax.tree_util.tree_map(lambda _: P(), params)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path_str(path), leaf.shape, env),
        params)


def param_shardings(params, env: Optional[ShardingEnv] = None):
    env = env or current_env()
    if env is None:
        raise RuntimeError("param_shardings requires an active ShardingEnv")
    return jax.tree_util.tree_map(lambda s: NamedSharding(env.mesh, s),
                                  param_specs(params, env))


# ---------------------------------------------------------------------------
# shard_map version compat
# ---------------------------------------------------------------------------
def compat_shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``shard_map`` across jax versions: newer releases take ``check_vma``,
    older ones ``check_rep`` (same meaning for our purposes)."""
    import inspect

    try:
        from jax import shard_map as sm  # jax >= 0.6
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as sm
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in inspect.signature(sm).parameters:
        kw["check_vma"] = check_vma
    else:
        kw["check_rep"] = check_vma
    return sm(f, **kw)


# ---------------------------------------------------------------------------
# Layout autotuner (Swallow §II-B: pick the balanced design point)
# ---------------------------------------------------------------------------
def autotune_layout(cfg, shape=None, n_chips: Optional[int] = None,
                    mode: str = "circuit", link=None,
                    max_model: Optional[int] = None,
                    serving: bool = False):
    """Pick the (data, model) mesh factorization the cost engine scores
    fastest for ``cfg`` at ``shape``.

    Returns ``(best, ranked)`` where ``best`` is a
    :class:`repro.core.costs.CostEstimate` (``best.layout`` is the chosen
    :class:`~repro.core.costs.Layout`) and ``ranked`` is every candidate,
    fastest first.  ``n_chips`` defaults to the visible device count.
    Pure host-side arithmetic except that default — no arrays are placed.

    ``serving=True`` prices the paged-KV stripe traffic on top of the
    transformer collectives (:func:`repro.core.costs.rank_serving_layouts`
    — the §V link model applied to the (n-1)/n remote fraction of KV
    writes plus the per-window decode stats merge).
    """
    from repro.core import costs as costs_mod
    if n_chips is None:
        n_chips = len(jax.devices())
    link = link or costs_mod.LinkSpec()
    rank = (costs_mod.rank_serving_layouts if serving
            else costs_mod.rank_layouts)
    ranked = rank(cfg, shape, n_chips, mode, link, max_model)
    return ranked[0], ranked


def make_layout_mesh(layout):
    """Realise a :class:`~repro.core.costs.Layout` as a jax Mesh
    (None for the trivial single-chip layout)."""
    from repro.launch.mesh import make_test_mesh
    if layout.n_chips == 1:
        return None
    return make_test_mesh(layout.data, layout.model, layout.pod)
