"""Failure detection and straggler mitigation (nOS-style runtime policy).

At thousand-node scale the runtime must (a) notice dead hosts quickly,
(b) notice *slow* hosts before they become the step time, and (c) decide
deterministically what to do.  Both detectors are pure state machines so
the policies are unit-testable without a cluster; the train loop feeds
them wall-clock observations (heartbeats, per-step durations).

Policies follow the Swallow design rules: independent nodes (C1) mean a
straggler cannot slow others *except* through collectives — so the only
lever is eviction/rescale, never waiting.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


@dataclass
class HeartbeatMonitor:
    """Dead-host detector: miss `timeout_s` of heartbeats => failed."""
    nodes: List[str]
    timeout_s: float = 60.0
    _last: Dict[str, float] = field(default_factory=dict)
    _failed: Set[str] = field(default_factory=set)

    def __post_init__(self):
        now = time.time()
        for n in self.nodes:
            self._last[n] = now

    def beat(self, node: str, now: Optional[float] = None):
        if node in self._failed:
            self._failed.discard(node)   # node came back (elastic re-join)
        self._last[node] = now if now is not None else time.time()

    def check(self, now: Optional[float] = None) -> Set[str]:
        """Returns the set of newly-failed nodes."""
        now = now if now is not None else time.time()
        new = set()
        for n, t in self._last.items():
            if n not in self._failed and now - t > self.timeout_s:
                new.add(n)
                self._failed.add(n)
        return new

    @property
    def failed(self) -> Set[str]:
        return set(self._failed)

    def healthy(self) -> List[str]:
        return [n for n in self.nodes if n not in self._failed]


@dataclass
class StragglerDetector:
    """Flags nodes whose step time exceeds `ratio` x fleet median for
    `patience` consecutive observations."""
    nodes: List[str]
    ratio: float = 1.5
    patience: int = 3
    window: int = 20
    _hist: Dict[str, List[float]] = field(default_factory=dict)
    _strikes: Dict[str, int] = field(default_factory=dict)

    def observe(self, durations: Dict[str, float]) -> Set[str]:
        """Feed one step's per-node durations; returns nodes to evict.
        An empty observation (every node failed or held out) evicts
        nobody — there is no fleet median to straggle against."""
        if not durations:
            return set()
        med = statistics.median(durations.values())
        evict = set()
        for n, d in durations.items():
            self._hist.setdefault(n, []).append(d)
            self._hist[n] = self._hist[n][-self.window:]
            if med > 0 and d > self.ratio * med:
                self._strikes[n] = self._strikes.get(n, 0) + 1
            else:
                self._strikes[n] = 0
            if self._strikes.get(n, 0) >= self.patience:
                evict.add(n)
        return evict

    def summary(self) -> Dict[str, float]:
        return {n: statistics.median(h) for n, h in self._hist.items() if h}


@dataclass
class RecoveryPolicy:
    """What to do when nodes fail: restart-in-place if spares exist,
    otherwise shrink the data axis to the largest feasible mesh."""
    data_axis: int
    model_axis: int
    spares: int = 0

    def plan(self, n_failed: int) -> dict:
        if n_failed == 0:
            return {"action": "none"}
        if n_failed <= self.spares:
            return {"action": "replace", "use_spares": n_failed}
        # shrink: drop whole data rows (model groups must stay intact)
        lost_rows = -(-n_failed // self.model_axis)  # ceil
        new_data = self.data_axis - lost_rows
        if new_data < 1:
            return {"action": "abort"}
        return {"action": "shrink", "new_data_axis": new_data,
                "note": "restore from checkpoint with elastic resharding"}
