"""Swallow §III-A + §X-B: the KV cache as a striped distributed store.

What is reproduced: the paper's "more elegant strategy" — an address
space striped ``address % n`` over per-node controllers — applied to KV
pages.  Physical page ``p`` is owned by node ``striped_owner(p, n)``
(:mod:`repro.core.memory_server` is the single source of truth for the
mapping), and the allocator hands a request's *logical* page ``j`` a
physical page on node ``j % n`` whenever one is free, so a sequence's
cache reads fan out over the mesh exactly like the paper's memory-server
traffic instead of hammering one contention point.

What is extrapolated: Swallow stores 32-bit words; here a "word" is a
(page_size, Kv*hd) KV page and the striping axis is the mesh "model"
dimension the pools are sharded over.  Page 0 is reserved as the null
page — padded block-table slots point at it so the paged attention
kernel always DMAs a real page and masks its contribution to exactly 0.

Pure host-side logic: no jax imports, unit-testable anywhere.  The
device-side half (pools + block tables) lives in
:mod:`repro.serving.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.memory_server import striped_owner

NULL_PAGE = 0


@dataclass
class PageAllocator:
    """Fixed-size-page allocator over a striped pool.

    ``n_pages`` counts physical pages including the reserved null page;
    ``n_nodes`` is the striping width (mesh "model" extent).
    """
    n_pages: int
    page_size: int
    n_nodes: int = 1
    held: Dict[str, List[int]] = field(default_factory=dict)
    _free_by_node: List[List[int]] = field(default_factory=list)

    def __post_init__(self):
        assert self.n_pages > 1, "need at least one page beyond the null page"
        self._free_by_node = [[] for _ in range(self.n_nodes)]
        # LIFO free lists per owner node; page 0 is never handed out
        for p in range(self.n_pages - 1, NULL_PAGE, -1):
            self._free_by_node[self.owner(p)].append(p)

    # -- the striping rule (one source of truth) ---------------------------
    def owner(self, page: int) -> int:
        """Node owning physical ``page`` — delegates to the paper's
        address%n rule in core/memory_server."""
        return striped_owner(page, self.n_nodes)

    # -- accounting --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free_by_node)

    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self.held.values())

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def occupancy_by_node(self) -> List[int]:
        """Allocated pages per owner node (load-balance observable)."""
        counts = [0] * self.n_nodes
        for pages in self.held.values():
            for p in pages:
                counts[self.owner(p)] += 1
        return counts

    # -- alloc / grow / free ----------------------------------------------
    def _take(self, want_node: int) -> Optional[int]:
        """Pop a free page on ``want_node``, falling back to the richest
        node (work-conserving when the stripe is fragmented)."""
        if self._free_by_node[want_node]:
            return self._free_by_node[want_node].pop()
        best = max(range(self.n_nodes),
                   key=lambda n: len(self._free_by_node[n]))
        if self._free_by_node[best]:
            return self._free_by_node[best].pop()
        return None

    def alloc(self, rid: str, n: int) -> Optional[List[int]]:
        """All-or-nothing: ``n`` pages for ``rid``, logical page j on
        node j%n_nodes.  Returns the page list or None."""
        if n > self.free_pages or rid in self.held:
            return None
        pages = []
        for j in range(n):
            p = self._take(striped_owner(j, self.n_nodes))
            assert p is not None
            pages.append(p)
        self.held[rid] = pages
        return pages

    def grow(self, rid: str, n: int = 1) -> bool:
        """Append ``n`` pages to an existing allocation (decode crossing
        a page boundary)."""
        if n > self.free_pages:
            return False
        pages = self.held[rid]
        for _ in range(n):
            p = self._take(striped_owner(len(pages), self.n_nodes))
            assert p is not None
            pages.append(p)
        return True

    def reserve(self, rid: str, n_tokens: int) -> int:
        """Horizon pre-reservation: grow ``rid`` (best-effort under page
        pressure) until its pages cover every write position below
        ``n_tokens``, so the block-table row is fixed for a whole fused
        decode window.  Returns the token capacity actually reserved —
        the caller shrinks the window to ``capacity - pos`` when the
        pool runs dry instead of preempting mid-window."""
        need = self.pages_for(n_tokens)
        while len(self.held[rid]) < need and self.grow(rid):
            pass
        return len(self.held[rid]) * self.page_size

    def free(self, rid: str) -> int:
        """Release every page ``rid`` holds; returns the count."""
        pages = self.held.pop(rid, [])
        for p in pages:
            self._free_by_node[self.owner(p)].append(p)
        return len(pages)
