"""Gemma2-27B [arXiv:2408.00118; hf-verified].

Dense decoder: 46L, d_model=4608, 32 Q heads / 16 KV heads, d_ff=36864,
vocab=256000.  Alternating local (4096-window) / global attention, attention
logit softcap 50, final logit softcap 30, GeGLU, pre+post sublayer norms,
query scale 1/sqrt(d_model/n_heads)=1/sqrt(144), sqrt(d_model) embed scaling.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    layer_pattern=("local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    attn_logit_scale=(4608 // 32) ** -0.5,
    act="gelu",
    gated_ffn=True,
    post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    # 27B at TP-only sharding: bf16 params keep params+grads+ZeRO moments
    # within 16 GB/chip on the 256-chip pod
    param_dtype="bfloat16",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=32,
        attn_logit_scale=(64 // 4) ** -0.5,
        attn_block_q=16, attn_block_kv=32)
