"""Smoke tests for examples/ and benchmark CLIs — each example must run
end to end (they carry their own internal assertions, e.g.
shared_memory.py checks store semantics and cache-on/off token
identity).  The subprocess runner lives in conftest.run_example."""
import os
import subprocess
import sys

import pytest

from conftest import ROOT, run_example


def test_shared_memory_example_runs():
    proc = run_example("shared_memory.py")
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = proc.stdout
    assert "semantics check OK" in out
    assert "tokens identical with cache on/off: True" in out
    assert "hit rate" in out


def test_bench_run_only_rejects_unknown_section():
    """benchmarks/run.py --only with a name matching no section must
    fail loudly (listing the valid titles), not silently run nothing."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"),
         "--only", "definitely-not-a-section"],
        cwd=ROOT, capture_output=True, text=True, timeout=120)
    assert proc.returncode != 0
    err = proc.stderr + proc.stdout
    assert "definitely-not-a-section" in err
    assert "micro: serve" in err        # valid titles are listed
