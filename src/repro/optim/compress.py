"""Gradient compression with error feedback (distributed-optimization
trick for collective-bound training).

``compressed_all_reduce`` implements a quantized reduce-scatter +
all-gather decomposition inside shard_map:

  1. add the error-feedback residual to the local gradient,
  2. blockwise-int8 quantize (local absmax scales),
  3. reduce-scatter the int8 payload as int32 partials (each owner sums
     dequantized chunks — here expressed as psum_scatter of dequantized
     blocks with the scales exchanged separately),
  4. all-gather the requantized result,
  5. keep (local - dequant(quant)) as the next step's residual.

Wire bytes: ~2 x size x 1B (int8 both phases) vs 8 x size x 4B-equivalent
for a ring fp32 all-reduce — a 4x reduction.  Error feedback keeps the
long-run bias bounded (property-tested in tests/test_compress.py).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import quant
from repro.parallel.sharding import current_env

from repro.parallel.sharding import compat_shard_map as _shard_map


def _q8(x):
    """Blockwise int8 (BLOCK lanes share one absmax scale)."""
    xb = x.reshape(-1, quant.BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1)
    q = jnp.round(xb / jnp.maximum(scale[:, None], 1e-12) * 127.0)
    return q.astype(jnp.int8), scale


def _dq8(q, scale):
    return (q.astype(jnp.float32) * (scale[:, None] / 127.0)).reshape(-1)


def compressed_all_reduce(x, err, axis: str = "data"):
    """Mean-reduce ``x`` (replicated shape, per-shard values) over
    ``axis`` with int8 compression + error feedback.

    Returns (reduced, new_err).  Falls back to pmean off-mesh.
    """
    env = current_env()
    if env is None or axis not in env.mesh.axis_names \
            or env.mesh.shape[axis] == 1:
        return x, jnp.zeros_like(x)

    n = env.mesh.shape[axis]
    size = x.size
    blk = quant.BLOCK * n
    pad = (-size) % blk
    shape = x.shape

    def body(x_l, err_l):
        g = x_l.reshape(-1)
        if pad:
            g = jnp.pad(g, (0, pad))
        e = err_l.reshape(-1)
        if pad:
            e = jnp.pad(e, (0, pad))
        g = g + e
        # phase 1: quantize, reduce-scatter the dequantized blocks
        q, s = _q8(g)
        g_hat = _dq8(q, s)
        err_new = g - g_hat                       # error feedback residual
        own = jax.lax.psum_scatter(g_hat, axis, scatter_dimension=0,
                                   tiled=True) / n
        # phase 2: requantize the owner's chunk, all-gather
        q2, s2 = _q8(own)
        own_hat = _dq8(q2, s2)
        out = jax.lax.all_gather(own_hat, axis, axis=0, tiled=True)
        if pad:
            out = out[:size]
            err_new = err_new[:size]
        return out.reshape(shape), err_new.reshape(shape)

    return _shard_map(body, mesh=env.mesh, in_specs=(P(), P()),
                      out_specs=(P(), P()), check_vma=False)(x, err)


def wire_bytes(size: int, n: int, scheme: str = "int8_ef") -> float:
    """Per-device wire bytes for one reduction of ``size`` fp32 values."""
    f = (n - 1) / n
    if scheme == "fp32":
        return 2 * f * size * 4
    if scheme == "bf16":
        return 2 * f * size * 2
    if scheme == "int8_ef":
        scales = size / quant.BLOCK * 4
        return 2 * f * (size * 1 + scales)
    raise ValueError(scheme)
