"""Deterministic, restart-safe data pipeline.

Swallow principle C1 (independent processors): every host computes its
own shard of every batch from (seed, step) alone — no coordinator, no
state to replay on restart.  Sources:

  * SyntheticLM  — Zipf-distributed token documents packed into fixed-
    length rows with EOS boundaries (default; used by benchmarks & tests).
  * FileTokens   — memory-mapped uint16/uint32 token file, strided reads.

A background-thread prefetcher overlaps host batch assembly with device
compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

EOS = 1


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    path: Optional[str] = None     # set => FileTokens
    dtype: str = "int32"


class SyntheticLM:
    """Zipf token stream packed into (batch, seq) rows, EOS-delimited."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        ranks = np.arange(2, v, dtype=np.float64)  # 0=pad, 1=EOS reserved
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()
        self._vals = np.arange(2, v, dtype=np.int64)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(self._vals, size=(B, S + 1), p=self._probs)
        # plant EOS boundaries ~ geometric(1/mean_doc_len)
        eos_mask = rng.random((B, S + 1)) < (1.0 / cfg.mean_doc_len)
        toks = np.where(eos_mask, EOS, toks)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        mask = np.ones((B, S), np.float32)
        return {"tokens": tokens, "labels": labels, "mask": mask}


class FileTokens:
    """Strided reads over a flat token file (np.memmap); deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._data = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self._n = len(self._data) - 1

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        starts = rng.integers(0, self._n - S - 1, size=B)
        rows = np.stack([self._data[s:s + S + 1] for s in starts]).astype(
            np.int64) % cfg.vocab_size
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32),
                "mask": np.ones((B, S), np.float32)}


def make_source(cfg: DataConfig):
    return FileTokens(cfg) if cfg.path else SyntheticLM(cfg)


class Prefetcher:
    """Background-thread prefetch of ``source.batch(step)``."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._source.batch(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
