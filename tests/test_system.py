"""End-to-end system behaviour (deliverable c, integration layer)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_tiny_config, runnable_cells
from repro.configs.base import ShapeConfig, cell_is_runnable
from repro.core import paradigms
from repro.models import lm
from repro.runtime import train_loop


def test_cell_skip_rules():
    """DESIGN.md §4: exactly 31 runnable cells with the documented skips."""
    cells = list(runnable_cells())
    assert len(cells) == 31
    names = {(a, s) for a, s in cells}
    # encoder-only: no decode
    assert ("hubert-xlarge", "decode_32k") not in names
    assert ("hubert-xlarge", "long_500k") not in names
    assert ("hubert-xlarge", "prefill_32k") in names
    # long_500k only for sub-quadratic archs
    long_archs = {a for a, s in names if s == "long_500k"}
    assert long_archs == {"recurrentgemma-2b", "rwkv6-1.6b"}


def test_end_to_end_train_eval_serve():
    """Train a tiny model briefly, then serve greedily from it."""
    cfg = get_tiny_config("qwen3-14b")
    shape = ShapeConfig("t", 64, 4, "train")
    job = train_loop.TrainJobConfig(steps=20, log_every=10, peak_lr=2e-3,
                                    warmup=5)
    out = train_loop.run(cfg, shape, job=job)
    params = out["params"]
    prompts = jnp.ones((2, 8), jnp.int32) * 5
    logits, caches = lm.prefill(params, cfg, prompts, max_len=16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        logits, caches = lm.decode_step(params, cfg, tok, caches, 8 + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert jnp.isfinite(logits).all()


def test_farmer_worker_paradigm():
    data = jnp.arange(16.0)
    out = paradigms.farmer_worker(lambda x: (x ** 2).sum(), data)
    assert float(out) == float((data ** 2).sum())


def test_streaming_pipeline_paradigm():
    fns = [lambda x: x + 1, lambda x: x * 2]
    x = jnp.arange(8.0)[:, None]
    y1 = paradigms.streaming_pipeline(fns, x, microbatches=1)
    y4 = paradigms.streaming_pipeline(fns, x, microbatches=4)
    assert jnp.allclose(y1, y4)
    assert jnp.allclose(y1, (x + 1) * 2)


def test_scale_free_principles_checker():
    from repro.core import principles
    single = {"memory": {"temp_size_in_bytes": 100,
                         "argument_size_in_bytes": 50},
              "collectives": {"total_wire_bytes_per_device": 1000}}
    multi = {"memory": {"temp_size_in_bytes": 90,
                        "argument_size_in_bytes": 50},
             "collectives": {"total_wire_bytes_per_device": 1100}}
    checks = principles.check_scale_free(single, multi)
    assert len(checks) == 5
    assert all(c.holds for c in checks)


def test_overlay_planner_decisions():
    from repro.core import overlays
    from repro.configs import get_config
    cfg = get_config("qwen3-14b")
    p = overlays.plan(cfg, SHAPES["train_4k"], n_chips=256)
    assert p.remat            # 1M tokens of activations never fit
    assert p.extra_flops > 0
    p2 = overlays.plan(get_tiny_config("qwen3-14b"),
                       ShapeConfig("t", 64, 2, "train"), n_chips=1)
    assert not p2.remat       # tiny model: no overlay needed
