"""Swallow §III (farmer-worker, C3) + §VIII (nOS admission): the
continuous-batching scheduler.

What is reproduced: the farmer hands work to a fixed pool of compute
slots and refills a slot the moment it frees — here the "work" is one
decode step of one sequence, the slots are rows of the decode batch, and
the farmer refills them by prefilling waiting requests mid-flight.
Admission is priced, not guessed: each step spends at most
``prefill_budget x decode_cost_s`` seconds of prefill interference,
with both costs supplied by :func:`repro.core.costs.estimate` (the same
engine nOS uses for placement) so prefill bursts cannot starve decode
latency.

What is extrapolated: Swallow's farmer never revokes work; here page
pressure can *preempt* — the latest-arrived running request is evicted
(its pages freed, its generated tokens discarded) and re-queued for a
full recompute, vLLM-style.  Greedy decoding is deterministic, so a
preempted request's final output is unchanged — the conservation
property tests/test_serving.py pins down.

Prefix-cache integration (the §X-B sharing overlay,
:mod:`repro.serving.prefix_cache`): when a cache is attached, admission
is priced on *uncached* prefill tokens only (a request whose prompt is
mostly cached is nearly free to admit), matched pages are acquired as
shared references riding in the same ``held`` list as private pages,
and a finished request donates its now-immutable pages — including the
partially filled tail — to the cache before its references are
released.  Shared pages are non-reclaimable by preemption: preempting a
victim drops only its own references, so pages the cache (or another
tenant) still holds never return to the free list, and the pool-pressure
loop falls through to LRU cache eviction (``PageAllocator.reclaim``)
before killing further tenants.

Speculative decoding (:mod:`repro.serving.spec_decode`): the scheduler
records verified multi-token emissions through :meth:`complete_spec` —
each token in the batch is the greedy argmax at its position, so the
conservation and recompute-exactness properties are unchanged; only the
clock bookkeeping differs (the engine advances ``step_idx`` once per
window by the deepest per-slot emission).

Chunked prefill + SLO classes (the §III farmer made fair): with
``chunked=True`` a long prompt no longer stalls every decoding tenant
for its full duration.  Admitted requests enter a ``prefilling`` state
(slot held, pages fully allocated, KV filled page-aligned chunk by
chunk via :meth:`plan_chunks`), and the single ``prefill_budget`` scalar
is replaced by a *deadline-driven chunk budget*: each decode window
tolerates at most ``window_s * min(stall_frac)`` seconds of prefill
interference (both sides priced by :func:`repro.core.costs.estimate`,
the same engine nOS admission uses), distributed earliest-deadline-first
over per-tenant :class:`repro.serving.slo.SLOClass` targets.  Every
prefilling request is guaranteed at least one chunk per round regardless
of budget — progress is strict, so sustained overload cannot starve any
admitted request — and EDF over fixed deadlines keeps the waiting queue
starvation-free too.

Fault recovery (the robustness counterpart, :mod:`repro.serving.faults`):
node loss reuses the preemption machinery — a request whose block table
touches a quarantined page is reset to ``waiting`` through
:meth:`fault_reset` (greedy recompute is exact, so survivors' tokens are
bit-identical to a fault-free run), transient dispatch rejections
re-admit under capped exponential backoff (a backing-off head never
blocks later arrivals), and a pool shrunken by quarantine degrades
gracefully: requests that can never fit again are shed batch-class
first (:meth:`shed_infeasible`), and while any page is quarantined the
preemption victim rule prefers lower-priority SLO classes so batch
tenants absorb the pressure before interactive ones.

Pure host-side state machine: no jax imports.  The engine applies the
returned plan to device arrays.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.paged_kv import PageAllocator
from repro.serving.slo import DEFAULT_SLO, get_slo
from repro.serving.telemetry import MetricsRegistry, counter_attr


@dataclass
class Request:
    rid: str
    prompt_len: int
    gen: int
    tenant: str = "default"
    arrived_step: int = 0
    seq: int = 0                     # monotonic submission order (FIFO key)
    prompt: object = None            # (S,) int32 array; opaque to the host
    prompt_key: Optional[tuple] = None   # token ids (prefix-cache key)
    slo: str = DEFAULT_SLO           # repro.serving.slo class name
    # -- lifecycle ---------------------------------------------------------
    state: str = "waiting"  # waiting | prefilling | running | finished | shed
    slot: Optional[int] = None
    pos: int = 0                     # next KV write position
    prefilled: int = 0               # prompt tokens with KV written (chunked)
    tokens: List[int] = field(default_factory=list)
    deadline_step: int = 0           # arrived_step + slo.ttft_steps
    first_token_step: Optional[int] = None
    finished_step: Optional[int] = None
    preemptions: int = 0
    # -- fault-plane state (repro.serving.faults) --------------------------
    recoveries: int = 0              # fault resets (subset of preemptions)
    recovered_step: Optional[int] = None   # last fault-reset step, cleared
                                           # when the first token re-lands
    transient_rejections: int = 0    # dispatch faults absorbed by backoff
    backoff_until: int = 0           # not admissible before this step
    # wall stamps (telemetry only — scheduling never reads the wall clock)
    arrived_wall: float = 0.0
    first_token_wall: float = 0.0
    finished_wall: float = 0.0
    # -- prefix-cache state (set at admission, consumed by the engine) -----
    cached_tokens: int = 0           # prompt tokens served from shared pages
    prefix_match: Optional[object] = None   # prefix_cache.PrefixMatch

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.gen


@dataclass
class StepPlan:
    """What the engine must do this step, in order: clear the preempted
    slots, prefill the admitted requests, then run one decode step."""
    admitted: List[Request] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)


class ContinuousBatchScheduler:
    """Admission + page-pressure preemption over ``max_batch`` slots.

    ``registry`` (a :class:`~repro.serving.telemetry.MetricsRegistry`)
    is the single store behind the counter attributes below — the
    engine shares its own so one ``registry.reset()`` covers both;
    standalone schedulers get a private one.  ``tracer`` (optional
    :class:`~repro.serving.telemetry.StepTracer`) receives a
    request-lifecycle event at every state transition.
    """

    # registry-backed counters (pinned by tests under these names)
    chunk_rounds = counter_attr()
    chunk_tasks = counter_attr()
    chunk_preemptions = counter_attr()   # preempted while half-prefilled
    transient_rejections = counter_attr()

    def __init__(self, allocator: PageAllocator, max_batch: int,
                 prefill_cost_s: Optional[Callable[[int], float]] = None,
                 decode_cost_s: float = 0.0,
                 prefill_budget: float = 2.0,
                 prefix_cache=None,
                 chunked: bool = False,
                 chunk_tokens: int = 0,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.alloc = allocator
        self.max_batch = max_batch
        self.prefill_cost_s = prefill_cost_s
        self.decode_cost_s = decode_cost_s
        self.prefill_budget = prefill_budget
        self.cache = prefix_cache        # prefix_cache.PrefixCache or None
        self.chunked = chunked
        # page-aligned chunk quantum; a slice never splits a page except
        # at the prompt's tail
        self.chunk_tokens = chunk_tokens or 2 * allocator.page_size
        if self.chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}      # slot -> request
        self.prefilling: Dict[int, Request] = {}   # slot -> request (chunked)
        self.finished: List[Request] = []
        self.shed: List[Request] = []    # dropped by pool-shrink degradation
        self.step_idx = 0
        self._next_seq = 0
        # seed the registry keys (descriptors write through)
        self.chunk_rounds = 0
        self.chunk_tasks = 0
        self.chunk_preemptions = 0
        # fault plane: an injected transient-dispatch gate (request, step)
        # -> bool, and capped exponential backoff for its rejections
        self.transient_gate: Optional[Callable[[Request, int], bool]] = None
        self.backoff_base = 1
        self.backoff_cap = 8
        self.transient_rejections = 0
        self.recovery_steps: List[int] = []   # fault-reset -> first-token

    def _trace(self, req: Request, state: str) -> None:
        """Emit one lifecycle transition to the flight recorder (no-op
        without a tracer; never read back — tracing cannot perturb
        scheduling)."""
        if self.tracer is not None:
            self.tracer.request_event(req.rid, state, self.step_idx,
                                      tenant=req.tenant)

    # -- submission --------------------------------------------------------
    def submit(self, req: Request):
        max_need = self.alloc.pages_for(req.prompt_len + req.gen)
        if max_need > self.alloc.n_pages - 1:
            raise ValueError(
                f"request {req.rid} needs {max_need} pages at peak but the "
                f"pool only has {self.alloc.n_pages - 1} allocatable")
        req.arrived_step = self.step_idx
        req.seq = self._next_seq
        self._next_seq += 1
        req.deadline_step = get_slo(req.slo).deadline(req.arrived_step)
        req.arrived_wall = time.time()
        self.waiting.append(req)
        self._trace(req, "queued")
        self._sort_waiting()

    def _edf_key(self, r: Request):
        s = get_slo(r.slo)
        return (r.deadline_step, s.priority, r.arrived_step, r.seq)

    def _sort_waiting(self):
        if self.chunked:
            # earliest-deadline-first: deadlines are fixed at submission
            # on a monotonic clock, so EDF cannot starve — a waiting
            # request only ever moves toward the head
            self.waiting.sort(key=self._edf_key)
        else:
            self.waiting.sort(key=lambda r: (r.arrived_step, r.seq))

    def _slots_in_use(self) -> int:
        return len(self.running) + len(self.prefilling)

    # -- the per-step state machine ---------------------------------------
    def plan_step(self) -> StepPlan:
        """Growth/preemption for running requests, then priced admission.

        Growth runs first so decode always has its write page; admission
        runs second so freshly freed pages go to the grower, not a new
        tenant.
        """
        plan = StepPlan()
        if self.alloc.quarantined:
            # degraded pool: arrivals that can never fit the shrunken
            # capacity are shed up front instead of wedging admission
            self.shed_infeasible(self.alloc.allocatable_pages)
        self._grow_or_preempt(plan)
        self._admit(plan)
        return plan

    def _victim(self, protect: Request) -> Optional[Request]:
        """Latest-arrived running request — ``protect`` included.

        A grower never evicts an earlier-arrived request: when the
        grower itself is the latest arrival it self-preempts (the
        caller breaks out of the growth loop) and waits for the pool.
        The alternative — exempting the grower — is a priority
        inversion that can livelock: two requests filling a tight pool
        alternately evict each other one window before completion,
        forever.  With arrival order respected, the earliest running
        request is never preempted, so it always finishes, frees its
        pages, and the pool drains in arrival order.

        Chunked mode adds half-prefilled requests to the victim pool:
        they hold pages too, and they are usually the latest arrivals —
        a preempted chunk victim recomputes from scratch (through the
        prefix cache if its early pages were donated), exactly like a
        decode victim.

        Degraded mode (any page quarantined by a node failure): victims
        are picked by SLO class first — batch tenants absorb the
        shrunken pool's pressure before interactive ones.  Arrival order
        breaks ties within a class, so the livelock argument survives:
        the lowest-priority-number earliest request is never preempted,
        always finishes, and the pool still drains."""
        pool = list(self.running.values()) + list(self.prefilling.values())
        if not pool:
            return None
        if self.alloc.quarantined:
            return max(pool, key=lambda r: (get_slo(r.slo).priority,
                                            r.arrived_step, r.seq))
        return max(pool, key=lambda r: (r.arrived_step, r.seq))

    def _preempt(self, req: Request, plan: StepPlan):
        # drops only this request's references: pages the prefix cache or
        # another tenant shares survive (non-reclaimable by preemption)
        if self.cache is not None and req.prefix_match is not None:
            # engine-less flows can preempt between admission and first
            # token: drop acquire()'s temporary COW-source reference
            # (not in held) or the page leaks as permanently unevictable
            self.cache.release_cow(req.prefix_match)
        self.alloc.free(req.rid)
        if req.state == "prefilling":
            del self.prefilling[req.slot]
            self.chunk_preemptions += 1
        else:
            del self.running[req.slot]
        req.state, req.slot = "waiting", None
        req.pos = 0
        req.prefilled = 0
        req.tokens = []               # greedy decode: recompute is exact
        req.first_token_step = None
        req.cached_tokens, req.prefix_match = 0, None
        req.preemptions += 1
        self.waiting.append(req)
        self._trace(req, "preempted")
        self._sort_waiting()
        plan.preempted.append(req)

    # -- fault recovery (node loss rides the preemption machinery) ---------
    def fault_reset(self, req: Request, plan: Optional[StepPlan] = None
                    ) -> StepPlan:
        """Reset a RUNNING/PREFILLING request whose pages were quarantined
        by a node failure: exactly a preemption (pages released — the
        allocator parks the quarantined ones — state back to ``waiting``,
        greedy recompute through whatever prefix-cache pages survived),
        plus a recovery stamp so :meth:`note_first_token` can report the
        reset -> first-token latency distribution."""
        plan = plan if plan is not None else StepPlan()
        self._preempt(req, plan)
        req.recoveries += 1
        req.recovered_step = self.step_idx
        # lifecycle: the generic "preempted" span _preempt opened closes
        # immediately and "recovered" runs until re-admission, so a trace
        # distinguishes page-pressure eviction from fault recovery
        self._trace(req, "recovered")
        return plan

    def shed_infeasible(self, capacity: int) -> List[Request]:
        """Graceful degradation under a quarantine-shrunken pool: any
        request whose *peak* page need exceeds ``capacity`` can never be
        (re)admitted, so it is shed now — terminally, state ``shed`` —
        instead of wedging the engine in an un-admittable waiting queue.
        Shedding order follows SLO priority (batch before interactive),
        which only matters for observability: every infeasible request
        goes.  Live requests release their pages like a preemption."""
        pool = (list(self.waiting) + list(self.prefilling.values())
                + list(self.running.values()))
        doomed = [r for r in pool
                  if self.alloc.pages_for(r.prompt_len + r.gen) > capacity]
        doomed.sort(key=lambda r: (-get_slo(r.slo).priority,
                                   r.arrived_step, r.seq))
        for req in doomed:
            if req.state == "waiting":
                self.waiting.remove(req)
            else:
                if self.cache is not None and req.prefix_match is not None:
                    self.cache.release_cow(req.prefix_match)
                    req.prefix_match = None
                self.alloc.free(req.rid)
                if req.state == "prefilling":
                    del self.prefilling[req.slot]
                else:
                    del self.running[req.slot]
            req.state, req.slot = "shed", None
            req.finished_step = self.step_idx
            self.shed.append(req)
            self._trace(req, "shed")
        return doomed

    def _grow_or_preempt(self, plan: StepPlan):
        for req in sorted(self.running.values(),
                          key=lambda r: (r.arrived_step, r.seq)):
            if req.state != "running":
                continue
            needed = req.pos // self.alloc.page_size + 1
            while len(self.alloc.held[req.rid]) < needed:
                if self.alloc.grow(req.rid):
                    continue
                victim = self._victim(req)
                assert victim is not None
                self._preempt(victim, plan)
                if victim is req:
                    break

    def _uncached_len(self, req: Request) -> int:
        """Prefill tokens the request must actually compute — prompt
        minus the cached-prefix length (pricing sees only real work)."""
        if self.cache is None or req.prompt_key is None:
            return req.prompt_len
        return req.prompt_len - self.cache.peek(req.prompt_key)

    def _take_pages(self, req: Request):
        """Acquire the prefix-cache match and allocate the request's full
        page run (prompt + first decode page).  Returns True on success;
        on page pressure every acquired reference is released."""
        match = None
        shared = []
        if self.cache is not None and req.prompt_key is not None:
            match = self.cache.acquire(req.prompt_key)
            shared = match.pages
        n_fresh = self.alloc.pages_for(req.prompt_len + 1) - len(shared)
        pages = self.alloc.alloc(req.rid, n_fresh, prefix=shared)
        if pages is None:
            if match is not None:
                self.cache.release_match(match)
            return False              # page pressure: wait for frees
        if match is not None:
            self.cache.commit_match(match)
        req.cached_tokens = match.length if match is not None else 0
        req.prefix_match = match
        return True

    def _free_slot(self) -> int:
        used = set(self.running) | set(self.prefilling)
        return min(set(range(self.max_batch)) - used)

    def _transient_rejected(self, req: Request) -> bool:
        """Ask the fault plane's gate whether this dispatch transiently
        fails; on rejection, arm capped exponential backoff (1, 2, 4, ...
        ``backoff_cap`` steps) so the retry storm self-spaces.  Tokens are
        unaffected — admission merely lands later and greedy recompute is
        exact."""
        gate = self.transient_gate
        if gate is None or not gate(req, self.step_idx):
            return False
        req.transient_rejections += 1
        self.transient_rejections += 1
        back = min(self.backoff_cap,
                   self.backoff_base << (req.transient_rejections - 1))
        req.backoff_until = self.step_idx + max(back, 1)
        return True

    def _admit(self, plan: StepPlan):
        if self.chunked:
            self._admit_chunked(plan)
            return
        budget = self.prefill_budget * self.decode_cost_s
        spent = 0.0
        i = 0
        while i < len(self.waiting) and self._slots_in_use() < self.max_batch:
            req = self.waiting[i]
            if req.backoff_until > self.step_idx:
                i += 1                # backing off: never blocks the queue
                continue
            # admission is priced on UNCACHED prefill tokens only: a
            # request whose prompt is mostly shared pages is nearly free
            cost = (self.prefill_cost_s(self._uncached_len(req))
                    if self.prefill_cost_s else 0.0)
            starving = not self.running and not plan.admitted
            if budget > 0.0 and spent + cost > budget and not starving:
                break                 # interference budget exhausted
            if self._transient_rejected(req):
                i += 1                # dispatch fault: retry after backoff
                continue
            if not self._take_pages(req):
                break                 # page pressure: wait for frees
            self.waiting.pop(i)
            req.slot = self._free_slot()
            req.state = "running"
            req.pos = req.prompt_len
            self.running[req.slot] = req
            # lifecycle: admission starts the prefill; "running" begins
            # at note_first_token when its first token actually lands
            self._trace(req, "prefilling")
            plan.admitted.append(req)
            spent += cost

    def _admit_chunked(self, plan: StepPlan):
        """EDF admission into the ``prefilling`` state.  No interference
        budget here — that is the whole point: a long prompt's cost is
        paid chunk by chunk under :meth:`plan_chunks`'s per-window
        budget, so admission only needs a slot and pages.  This removes
        the monolithic path's head-of-line block, where one unaffordable
        long prompt at the FIFO head stalled every arrival behind it."""
        i = 0
        while i < len(self.waiting) and self._slots_in_use() < self.max_batch:
            req = self.waiting[i]
            if req.backoff_until > self.step_idx:
                i += 1                # backing off: never blocks the queue
                continue
            if self._transient_rejected(req):
                i += 1                # dispatch fault: retry after backoff
                continue
            if not self._take_pages(req):
                break                 # page pressure: wait for frees
            self.waiting.pop(i)
            req.slot = self._free_slot()
            req.state = "prefilling"
            # cached prefix pages already hold KV: chunking starts at the
            # first uncached token (mid-page after a COW divergence)
            req.prefilled = req.cached_tokens
            req.pos = req.prefilled
            self.prefilling[req.slot] = req
            self._trace(req, "prefilling")
            plan.admitted.append(req)

    # -- chunked prefill ----------------------------------------------------
    def _chunk_end(self, start: int, prompt_len: int) -> int:
        """Next chunk boundary: at most ``chunk_tokens`` ahead, aligned
        down to a page boundary so only the prompt's final slice may
        leave a partial page.  A misaligned start (COW divergence
        mid-page) realigns on its first chunk."""
        end = min(prompt_len, start + self.chunk_tokens)
        if end < prompt_len:
            aligned = end - end % self.alloc.page_size
            if aligned > start:
                end = aligned
        return end

    def plan_chunks(self, window: int = 1) -> List[Tuple[Request, int, int]]:
        """One chunk round: ``(request, start, n_tokens)`` tasks for the
        engine to dispatch before the next decode window.

        The budget is deadline-driven and priced: the tightest running
        tenant's ``stall_frac`` bounds how many seconds of prefill this
        ``window``-step decode window tolerates, and each chunk is priced
        by ``prefill_cost_s`` (cost engine) against it.  Distribution is
        earliest-deadline-first, but EVERY prefilling request gets at
        least one chunk per round regardless of budget — the strict-
        progress guarantee the no-starvation property test pins.  With
        nothing decoding (or an unpriced scheduler at idle) the budget is
        unbounded and a prompt drains at full speed, recovering the
        monolithic fast path.  Unpriced schedulers under decode load fall
        back to strict round-robin: one chunk each."""
        if not self.chunked or not self.prefilling:
            return []
        self.chunk_rounds += 1
        priced = bool(self.running) and self.prefill_cost_s is not None \
            and self.decode_cost_s > 0.0
        budget_s = 0.0
        if priced:
            frac = min(get_slo(r.slo).stall_frac
                       for r in self.running.values())
            budget_s = max(window, 1) * self.decode_cost_s * frac
        tasks: List[Tuple[Request, int, int]] = []
        spent = 0.0
        for req in sorted(self.prefilling.values(), key=self._edf_key):
            first = True
            while req.prefilled < req.prompt_len:
                start = req.prefilled
                end = self._chunk_end(start, req.prompt_len)
                cost = (self.prefill_cost_s(end - start)
                        if self.prefill_cost_s is not None else 0.0)
                if not first and priced and spent + cost > budget_s:
                    break             # budget exhausted: back to decode
                tasks.append((req, start, end - start))
                req.prefilled = end
                req.pos = end
                spent += cost
                first = False
                if not priced and self.running:
                    break             # unpriced under load: round-robin
        self.chunk_tasks += len(tasks)
        return tasks

    def finish_prefill(self, req: Request, token: int) -> bool:
        """Final chunk landed: promote ``prefilling -> running`` and
        record the first token.  Returns True if the request finished
        outright (``gen == 1``)."""
        assert req.prefilled == req.prompt_len
        del self.prefilling[req.slot]
        req.state = "running"
        req.pos = req.prompt_len
        self.running[req.slot] = req
        self.note_first_token(req, token)
        return req.state == "finished"

    # -- fused decode windows ---------------------------------------------
    def safe_horizon(self, max_window: int, quantize=None) -> int:
        """Largest K (``<= max_window``) such that no scheduling event can
        occur strictly inside a K-step decode window:

        * **completion** — K never exceeds any running request's remaining
          tokens, so the earliest finish lands exactly on the window's
          last step;
        * **priced admission** — the interference budget resets every
          step, so if the head of the waiting queue has a free slot and
          free pages, it could be admitted next step: horizon is 1;
        * **page-boundary crossing** — every running request gets its
          window's pages pre-reserved (:meth:`PageAllocator.reserve`) in
          arrival order, fixing the block tables; if the pool runs dry
          the horizon shrinks to the reserved capacity instead of
          preempting mid-window.

        ``quantize`` (e.g. the engine's power-of-two bucketing) is
        applied to the event horizon *before* pages are reserved — so
        reservation never grabs pages a smaller dispatched window won't
        write — and again to the capacity-shrunk result.

        Interplay with adaptive speculation: the horizon is computed
        for the *largest* window the engine might dispatch (its
        ``max(max_window, spec_k + 1)`` cap), and the per-tenant
        adaptive controller then clamps each slot's draft depth to
        ``horizon - 1`` — a verify emits at most K accepted drafts plus
        one corrected token, all landing inside the reserved window.
        The derivation above is unchanged: completion still bounds K by
        the smallest remaining generation (a deep verify may *finish* a
        request mid-buffer, but emission is truncated at ``gen`` so the
        finish lands on the window's last emitted step); admission
        pressure still collapses the horizon to 1 (shallow drafts near
        admission events are exactly what the priced worth-it gate then
        prices out); and page reservation is exact over the horizon, so
        a rejected draft rolls back pages that were reserved, never
        pages another slot could have claimed mid-window.  Adaptive K
        never widens the horizon — it only chooses how much of the
        already-safe window to spend on drafts.

        Call after :meth:`plan_step` (growth already guaranteed the
        current write page, so the result is always >= 1 while anything
        runs).  Returns 0 when nothing is running.
        """
        quantize = quantize or (lambda n: n)
        if not self.running:
            return 0
        k = max(1, max_window)
        for req in self.running.values():
            k = min(k, req.gen - len(req.tokens))
        k = max(quantize(max(k, 1)), 1)
        if k > 1 and self.waiting and self._slots_in_use() < self.max_batch:
            head = next((r for r in self.waiting
                         if r.backoff_until <= self.step_idx), None)
            if head is None:
                # every waiting request is backing off: cap the window at
                # the earliest backoff expiry so re-admission lands on a
                # window boundary, then fall through to reservation
                expiry = min(r.backoff_until
                             for r in self.waiting) - self.step_idx
                k = max(min(k, expiry), 1)
        else:
            head = None
        if head is not None:
            if self.chunked:
                # chunked admission is unpriced (slot + pages only), so
                # any head with capacity could land next step
                admissible = True
            else:
                budget = self.prefill_budget * self.decode_cost_s
                cost = (self.prefill_cost_s(self._uncached_len(head))
                        if self.prefill_cost_s else 0.0)
                # mirror _admit with spent=0: a head whose prefill alone
                # busts the budget cannot land while anything runs, so it
                # must not collapse every window to K=1
                admissible = not (budget > 0.0 and cost > budget)
            need = self.alloc.pages_for(head.prompt_len + 1)
            if self.cache is not None and head.prompt_key is not None:
                # cached full pages arrive as shared references, not
                # fresh allocations (cache eviction could free more — a
                # conservative miss just delays admission, never tokens)
                need -= self.cache.peek(head.prompt_key) \
                    // self.alloc.page_size
            if admissible and need <= self.alloc.free_pages:
                return 1              # admission could land next step
        if k == 1:
            return 1
        for req in sorted(self.running.values(),
                          key=lambda r: (r.arrived_step, r.seq)):
            capacity = self.alloc.reserve(req.rid, req.pos + k)
            k = min(k, capacity - req.pos)
        return max(quantize(max(k, 1)), 1)

    # -- completion callbacks (engine -> scheduler) ------------------------
    def note_first_token(self, req: Request, token: int):
        if self.cache is not None and req.prefix_match is not None:
            # prefill is done.  In engine flows this release is a no-op —
            # _do_prefill drops the COW-source reference right after its
            # device copy — but the scheduler is also driven engine-less
            # (host-only tests, cost studies), and there this is the ONLY
            # balance point for acquire()'s temporary COW reference.
            self.cache.release_cow(req.prefix_match)
            req.prefix_match = None
        req.tokens.append(token)
        req.first_token_step = self.step_idx
        req.first_token_wall = time.time()
        self._trace(req, "running")
        if req.recovered_step is not None:
            # recovery latency: fault reset -> the recompute's first token.
            # The list is the raw record (pinned by tests); the registry
            # digest is the streaming percentile view metrics() reports.
            steps = self.step_idx - req.recovered_step
            self.recovery_steps.append(steps)
            self.registry.observe("recovery_steps", steps)
            req.recovered_step = None
        self._maybe_finish(req)

    def complete_step(self, emitted: Dict[int, int]) -> List[Request]:
        """Record one decode step: ``emitted`` maps slot -> token.  The
        KV write for the token happened at ``pos``; advance it.  Returns
        the requests that just finished."""
        done = []
        for slot, token in emitted.items():
            req = self.running.get(slot)
            if req is None:
                continue
            req.pos += 1
            req.tokens.append(token)
            if self._maybe_finish(req):
                done.append(req)
        self.step_idx += 1
        return done

    def complete_spec(self, req: Request, tokens: List[int]) -> List[Request]:
        """Record one verified speculative emission for ONE request:
        ``tokens`` is the accepted draft prefix plus the verifier's
        bonus/correction token — every element is the greedy argmax of
        the model at its position, so speculation never changes emitted
        tokens, only how many model passes produced them.  The verify
        dispatch wrote KV for positions ``pos .. pos+len(tokens)-2``
        (the last token's KV is not yet written — the same invariant as
        :meth:`complete_step`); rejected-draft KV past that is masked by
        position and its whole pages are rolled back by the engine via
        :meth:`PageAllocator.truncate_to`.  Does NOT advance
        ``step_idx`` — the engine advances the clock once per window by
        the largest per-slot emission.  Returns ``[req]`` on finish."""
        req.pos += len(tokens)
        req.tokens.extend(int(t) for t in tokens)
        return [req] if self._maybe_finish(req) else []

    def _maybe_finish(self, req: Request) -> bool:
        if not req.done:
            return False
        if self.cache is not None and req.prompt_key is not None:
            # donate before free: every page is immutable now (the last
            # emitted token's KV is never written, so the valid run is
            # prompt + tokens[:-1]) and the tree takes its own reference
            # — shared pages survive the owner's completion
            valid = tuple(req.prompt_key) + tuple(req.tokens[:-1])
            self.cache.insert(valid, self.alloc.held.get(req.rid, []),
                              donate_partial=True)
        self.alloc.free(req.rid)
        if req.slot is not None:
            self.running.pop(req.slot, None)
        req.state, req.slot = "finished", None
        req.finished_step = self.step_idx
        req.finished_wall = time.time()
        self.finished.append(req)
        self._trace(req, "finished")
        return True

    # -- invariants (pinned by tests) --------------------------------------
    @property
    def all_requests(self) -> List[Request]:
        seen = {r.rid: r for r in self.waiting}
        seen.update({r.rid: r for r in self.prefilling.values()})
        seen.update({r.rid: r for r in self.running.values()})
        seen.update({r.rid: r for r in self.finished})
        seen.update({r.rid: r for r in self.shed})
        return list(seen.values())

    def conserved(self, submitted: int) -> bool:
        """No request dropped or duplicated across queues (``shed`` is a
        terminal queue too — degradation is accounted, never silent)."""
        rids = ([r.rid for r in self.waiting]
                + [r.rid for r in self.prefilling.values()]
                + [r.rid for r in self.running.values()]
                + [r.rid for r in self.finished]
                + [r.rid for r in self.shed])
        return len(rids) == len(set(rids)) == submitted
