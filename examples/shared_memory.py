"""Case study II (Swallow §X-B): shared memory emulated on distributed
memory — single controller vs address%n striping.

Runs batches of random reads/writes against both stores, checks they
implement the same memory semantics, and prints the traffic/contention
model that makes the paper prefer striping.

Run:  PYTHONPATH=src python examples/shared_memory.py
"""
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core.memory_server import (SingleController, StripedStore,
                                      striped_owner)


def main():
    size = 1 << 16
    n_nodes = 16
    n_access = 4096
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    addrs = jax.random.randint(k1, (n_access,), 0, size)
    vals = jax.random.normal(k2, (n_access,))

    single = SingleController(size)
    striped = StripedStore(size)

    single.write(addrs, vals)
    striped.write(addrs, vals)
    r1 = single.read(addrs)
    r2 = striped.read(addrs)
    assert jnp.allclose(r1, r2), "stores disagree"
    print(f"semantics check OK over {n_access} random accesses")

    print("\nowner mapping (address % n):",
          [int(striped_owner(a, n_nodes)) for a in range(8)])

    tm_s = single.traffic_model(n_access, n_nodes)
    tm_d = striped.traffic_model(n_access, n_nodes)
    print("\n                      single-controller   striped")
    print(f"remote fraction       {tm_s['remote_fraction']:<19.3f}"
          f"{tm_d['remote_fraction']:.3f}")
    print(f"contention points     {tm_s['contention_points']:<19d}"
          f"{tm_d['contention_points']}")
    print("\n-> striping removes the serialization point: remote traffic is "
          "the same,\n   but it spreads over n controllers instead of one "
          "(the paper's argument).")

    # micro-timing
    for name, store in (("single", single), ("striped", striped)):
        f = jax.jit(lambda a: store.read(a))
        f(addrs)
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(f(addrs))
        dt = (time.perf_counter() - t0) / 10
        print(f"{name:>8}: {n_access / dt / 1e6:.1f} M reads/s")


if __name__ == "__main__":
    main()
