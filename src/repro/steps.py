"""Step builders: train_step / prefill_step / serve_step + abstract specs.

These are the units the launcher jits, the dry-run lowers, and the
roofline analyzer reads.  Everything here works on ShapeDtypeStructs
(no allocation) so a 671B-parameter model can be lowered on one CPU.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ATTN, LOCAL, MLA, RGLRU, RWKV6, ModelConfig,
                                ShapeConfig)
from repro.models import lm, modules as nn
from repro.optim import adam as adam_lib
from repro.parallel.sharding import (ShardingEnv, current_env, param_specs)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, adam_cfg: adam_lib.AdamConfig,
                    schedule=None, impl: Optional[str] = None):
    schedule = schedule or (lambda s: 3e-4)

    def train_step(params, opt_state, batch):
        def lf(p):
            return lm.loss_fn(p, cfg, batch, impl=impl)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        lr = schedule(opt_state.step)
        params2, opt_state2, om = adam_lib.update(
            grads, opt_state, params, lr=lr, cfg=adam_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr"] = lr
        return params2, opt_state2, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      impl: Optional[str] = None):
    def prefill_step(params, tokens, positions=None):
        return lm.prefill(params, cfg, tokens, max_len=max_len,
                          positions=positions, impl=impl)
    return prefill_step


def make_serve_step(cfg: ModelConfig, impl: Optional[str] = None):
    """One decode step: greedy-sample next token given the KV cache."""
    def serve_step(params, tokens, caches, pos):
        logits, caches = lm.decode_step(params, cfg, tokens, caches, pos,
                                        impl=impl)
        if cfg.embed_inputs:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, caches
    return serve_step


def constrain_paged_pools(pools):
    """Pin the page-axis stripe of every KV pool leaf.

    No-op without a mesh.  Every pool-returning step ends with this so
    the scatters/gathers inside never let GSPMD resolve the output pools
    to a different (e.g. replicated) layout — the device-side analogue
    of ``StripedStore.write`` re-pinning its slab.  The page axis is
    third-from-last in both unstacked (P, ps, F) and scan-stacked
    (C, P, ps, F) leaves.
    """
    env = current_env()
    if env is None:
        return pools

    def pin(a):
        spec = ((None,) * (a.ndim - 3)) + ("pages", None, None)
        return jax.lax.with_sharding_constraint(a, env.sharding(*spec))
    return jax.tree.map(pin, pools)


def make_paged_prefill_step(cfg: ModelConfig, impl: Optional[str] = None):
    """Prefill ONE sequence straight into the paged KV pools.

    (params, tokens (1,S), pools, block_row (nmax,)) ->
    (next-token logits (1,1,V), updated pools).  Jit with the pools
    donated — the scatter is in-place on device.
    """
    def prefill_paged(params, tokens, pools, block_row):
        h, raw, _ = lm.forward(params, cfg, tokens, mode="prefill",
                               impl=impl)
        pools = lm.paged_from_prefill(cfg, pools, raw, block_row)
        h_last = nn.rmsnorm(h[:, -1:], params["final_norm"]["scale"],
                            cfg.norm_eps)
        return lm.head_logits(params, cfg, h_last), \
            constrain_paged_pools(pools)
    return prefill_paged


def make_paged_serve_step(cfg: ModelConfig):
    """One continuous-batch paged decode step (greedy sampling).

    (params, tokens (B,1), pools, block_tables (B,nmax), pos (B,)) ->
    (next tokens (B,1), logits, updated pools).
    """
    def serve_paged(params, tokens, pools, block_tables, pos):
        logits, pools = lm.decode_step_paged(params, cfg, tokens, pools,
                                             block_tables, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, constrain_paged_pools(pools)
    return serve_paged


def make_paged_suffix_prefill(cfg: ModelConfig):
    """Batched suffix prefill for prefix-cache hits.

    (params, tokens (1,W) padded suffix ids, pools, block_row (nmax,),
     start, n_valid) -> (first-token logits (1,1,V), updated pools).
    Only the uncached suffix runs through the model — one dispatch,
    attending the shared-prefix KV through the block row.  Jit with the
    pools donated; the padded width W is the only retrace axis (the
    engine buckets it to powers of two).
    """
    def suffix_prefill(params, tokens, pools, block_row, start, n_valid):
        logits, pools = lm.prefill_suffix_paged(params, cfg, tokens, pools,
                                                block_row, start, n_valid)
        return logits, constrain_paged_pools(pools)
    return suffix_prefill


def make_chunk_prefill(cfg: ModelConfig):
    """One page-aligned prefill chunk for a prefilling slot.

    (params, tokens (1,W) padded chunk ids, pools, block_row (nmax,),
     start, n_valid) -> (last-position logits (1,1,V), updated pools).
    The chunk is a suffix continuation — positions ``start ..
    start+n_valid-1`` run through ``lm.chunk_prefill_paged``
    (== ``prefill_suffix_paged``: same layer path, same paged scatter,
    same causal attention over the page run), which is why chunked
    prefill is bit-identical to monolithic: each chunk writes exactly
    the KV a single prefill would have written at those positions, and
    only the final chunk's logits are read (the first generated token).
    Jit with the pools donated; the padded width W is the only retrace
    axis (the engine buckets it to powers of two), so a heavy-tailed
    prompt-length distribution compiles O(log max_chunk) kernels instead
    of one per length.
    """
    def chunk_prefill(params, tokens, pools, block_row, start, n_valid):
        logits, pools = lm.chunk_prefill_paged(params, cfg, tokens, pools,
                                               block_row, start, n_valid)
        return logits, constrain_paged_pools(pools)
    return chunk_prefill


def make_verify_window(cfg: ModelConfig):
    """Speculative-decoding verification window (one sequence, one
    dispatch).

    (params, tokens (1,W) [last token + K padded drafts], pools,
     block_row (nmax,), start, n_valid) -> (logits (1,W,V) at every
    position, updated pools).  Reuses the suffix-prefill layer path
    (``attention.apply_prefill_paged``) so scoring K+1 positions costs
    one model pass with decode-identical arithmetic.  Jit with the pools
    donated; the padded width W is the only retrace axis (the engine
    buckets it to powers of two).
    """
    def verify_window(params, tokens, pools, block_row, start, n_valid):
        logits, pools = lm.verify_window_paged(params, cfg, tokens, pools,
                                               block_row, start, n_valid)
        return logits, constrain_paged_pools(pools)
    return verify_window


def make_spec_draft_verify(cfg: ModelConfig):
    """Fused speculative draft+verify for ONE slot (device-resident
    drafting — no host materialization of candidate drafts).

    (params, history (B,H) device token-history rows, pools,
     block_tables (B,nmax), slot, start, k) ->
    (emitted (W,), n_emit, m, history, pools), with the verify width
    ``W`` static (the engine buckets it to powers of two) and
    ``max_n``/``min_n`` static n-gram bounds.  ``slot``/``start``/``k``
    are traced scalars: one compilation per width serves every slot,
    position and draft depth.

    One dispatch chains the whole speculation round on device:

    1. ``device_propose`` suffix-matches the slot's history row
       (``hist_len = start + 1`` — ``start`` is the next KV write
       position, whose token's KV is not yet written) for a draft of up
       to ``min(k, W-1)`` tokens;
    2. ``verify_window_paged`` scores last-token + draft (``n_valid =
       m+1`` positions) against the paged KV in one model pass;
    3. the greedy acceptance rule keeps the longest matching prefix and
       appends the verifier's bonus/correction token — ``emitted[:n_emit]``
       with ``n_emit = accepted + 1`` is exactly what non-speculative
       greedy decode would emit;
    4. the accepted tokens are appended to the slot's history row, so
       the next window drafts from an already-current device history.

    Jit with history and the pools donated; the host pulls only
    ``(emitted, n_emit, m)`` — one d2h event per verify.
    """
    from repro.serving.spec_decode import device_propose

    def draft_verify(params, history, pools, block_tables, slot, start, k,
                     *, W: int, max_n: int, min_n: int):
        H = history.shape[-1]
        row = jax.lax.dynamic_index_in_dim(history, slot, 0,
                                           keepdims=False)
        block_row = jax.lax.dynamic_index_in_dim(block_tables, slot, 0,
                                                 keepdims=False)
        hist_len = jnp.asarray(start, jnp.int32) + 1
        draft, m = device_propose(row, hist_len, k, k_max=W - 1,
                                  max_n=max_n, min_n=min_n)
        last = row[jnp.clip(hist_len - 1, 0, H - 1)]
        tokens = jnp.concatenate([last[None], draft])[None, :]   # (1, W)
        logits, pools = lm.verify_window_paged(params, cfg, tokens, pools,
                                               block_row, start, m + 1)
        greedy = jnp.argmax(logits[0], -1).astype(jnp.int32)     # (W,)
        offs = jnp.arange(W, dtype=jnp.int32)
        draft_w = jnp.concatenate([draft, jnp.zeros((1,), jnp.int32)])
        ok = (offs < m) & (greedy == draft_w)
        a = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))   # accepted prefix
        bonus = greedy[a]                # correction (or bonus) token
        emitted = jnp.where(offs < a, draft_w, 0)
        emitted = jnp.where(offs == a, bonus, emitted)
        n_emit = a + 1
        # append the emission to the history row on device: positions
        # hist_len .. hist_len+n_emit-1 take emitted[0..n_emit-1]
        rel = jnp.arange(H, dtype=jnp.int32) - hist_len
        src = emitted[jnp.clip(rel, 0, W - 1)]
        new_row = jnp.where((rel >= 0) & (rel < n_emit), src, row)
        history = jax.lax.dynamic_update_index_in_dim(history, new_row,
                                                      slot, 0)
        return emitted, n_emit, m, history, constrain_paged_pools(pools)
    return draft_verify


def make_page_copy():
    """Copy-on-write: duplicate one physical page across every layer's
    k/v pool in a single device dispatch.

    (pools, src, dst) -> pools with page ``dst`` := page ``src``
    everywhere.  The page axis is third-from-last in both unstacked
    (P, ps, F) and scan-stacked (C, P, ps, F) pool leaves, so one
    ellipsis-indexed scatter covers the whole pytree.  The whole page is
    copied — slots past the shared fill point hold stale values the
    diverging request overwrites before its position ever reaches them.
    Jit with the pools donated; src/dst are traced scalars (one compile).
    """
    def copy_page(pools, src, dst):
        pools = jax.tree.map(
            lambda a: a.at[..., dst, :, :].set(a[..., src, :, :]), pools)
        return constrain_paged_pools(pools)
    return copy_page


def make_paged_serve_scan(cfg: ModelConfig):
    """Fused K-step paged decode window (device-resident serving).

    (params, tokens (B,1), pools, block_tables (B,nmax), pos (B,),
     active (B,), k) -> (emitted (B,K), last tokens (B,1), pos (B,),
    updated pools).  ``k`` is the scan length — jit with
    ``static_argnames=("k",)`` and the pools donated; one dispatch and
    one host sync then cover K decode steps instead of one.
    """
    def serve_scan(params, tokens, pools, block_tables, pos, active, *,
                   k: int):
        emitted, last, pos, pools = lm.decode_window_paged(
            params, cfg, tokens, pools, block_tables, pos, active, k)
        return emitted, last, pos, constrain_paged_pools(pools)
    return serve_scan


# ---------------------------------------------------------------------------
# abstract state + sharding specs
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), key)


def abstract_opt_state(cfg: ModelConfig, adam_cfg, params_shape):
    return jax.eval_shape(lambda p: adam_lib.init(p, adam_cfg), params_shape)


def with_shardings(shape_tree, spec_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def mk(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, shape_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _prepend_none(spec: P, n: int = 1) -> P:
    return P(*(((None,) * n) + tuple(spec)))


def cache_specs(cfg: ModelConfig, env: ShardingEnv):
    """PartitionSpec pytree mirroring lm.init_caches."""
    def kind_spec(kind):
        if kind in (ATTN, LOCAL):
            # split-T layout: time dim sharded over "model" so decode scans
            # the cache in place (flash-decoding; §Perf iteration 5)
            s = env.spec("batch", "seq_sp", None)
            from repro.models.attention import AttnCache
            return AttnCache(k=s, v=s)
        if kind == MLA:
            from repro.models.mla import MLACache
            return MLACache(ckv=env.spec("batch", "seq_sp", None),
                            k_rope=env.spec("batch", "seq_sp", None))
        if kind == RGLRU:
            from repro.models.rglru import RGLRUCache
            return RGLRUCache(h=env.spec("batch", "tp"),
                              conv=env.spec("batch", None, "tp"))
        if kind == RWKV6:
            from repro.models.rwkv6 import RWKVCache
            return RWKVCache(state=env.spec("batch", "heads", None, None),
                             x_tm=env.spec("batch", None),
                             x_cm=env.spec("batch", None))
        raise ValueError(kind)

    out = []
    for seg in lm.make_segments(cfg):
        cyc = tuple(kind_spec(k) for k in seg.kinds)
        if seg.scanned:
            cyc = jax.tree.map(lambda s: _prepend_none(s), cyc,
                               is_leaf=lambda x: isinstance(x, P))
        out.append(cyc)
    return out


def batch_specs(cfg: ModelConfig, env: ShardingEnv):
    tok = env.spec("batch", None) if cfg.embed_inputs \
        else env.spec("batch", None, None)
    b = {"tokens": tok,
         "labels": env.spec("batch", None),
         "mask": env.spec("batch", None)}
    if cfg.mrope_sections is not None:
        b["positions"] = env.spec(None, "batch", None)
    return b


def make_batch_struct(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      env: ShardingEnv):
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_inputs:
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                   nn.dt(cfg.activation_dtype))
    batch = {"tokens": tok,
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    if cfg.mrope_sections is not None:
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    return with_shardings(batch, batch_specs(cfg, env), mesh)


def make_decode_structs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                        env: ShardingEnv):
    """(tokens, caches, pos) ShapeDtypeStructs for serve_step."""
    B, T = shape.global_batch, shape.seq_len
    if cfg.embed_inputs:
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        tok_spec = env.spec("batch", None)
    else:
        tok = jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                   nn.dt(cfg.activation_dtype))
        tok_spec = env.spec("batch", None, None)
    tok = with_shardings(tok, tok_spec, mesh)
    caches_shape = jax.eval_shape(lambda: lm.init_caches(cfg, B, T))
    caches = with_shardings(caches_shape, cache_specs(cfg, env), mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return tok, caches, pos


def make_state_structs(cfg: ModelConfig, adam_cfg, mesh, env: ShardingEnv):
    """(params, opt_state) ShapeDtypeStructs with shardings (no alloc)."""
    p_shape = abstract_params(cfg)
    p_spec = param_specs(p_shape, env)
    params = with_shardings(p_shape, p_spec, mesh)
    o_shape = abstract_opt_state(cfg, adam_cfg, p_shape)
    o_spec = adam_lib.state_specs(p_shape, adam_cfg, p_spec)
    opt = with_shardings(o_shape, o_spec, mesh)
    return params, opt


def adam_config_for(cfg: ModelConfig) -> adam_lib.AdamConfig:
    return adam_lib.AdamConfig(state_dtype=cfg.opt_state_dtype)
