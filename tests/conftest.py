"""Shared test fixtures and serving-test helpers.

NOTE: no XLA_FLAGS here — tests must see ONE device; multi-device
behaviour is tested via subprocesses (test_multidevice.py).

The serving suites (test_serving.py, test_prefix_cache.py,
test_spec_decode.py, test_serving_fuzz.py) share one tiny config + one
set of params (``get_tiny_model``), one prompt builder
(``seeded_prompts``), one engine factory (``make_engine``) and one
greedy reference (``dense_oracle``) — the
dense oracle is the root of the exactness ladder documented in
docs/TESTING.md (dense -> paged -> fused -> cached -> speculative).
Engines built from the same config share jitted step functions
(``repro.serving.engine._jitted_steps``), so the first test pays the
compile and the rest run warm.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

TINY_ARCH = "tiny-100m"
_TINY = {}
_DENSE_STEPS = {}


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=64, seed=7):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if cfg.embed_inputs:
        tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    else:
        tokens = jax.random.normal(k1, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.mrope_sections is not None:
        import repro.models.lm as lm
        batch["positions"] = lm.default_positions(cfg, B, S)
    return batch


# --- shared serving fixtures ---------------------------------------------------
def get_tiny_model():
    """(cfg, params) for the tiny serving config — initialized once per
    process.  Module-level (not only a fixture) so helpers and
    module-scope oracles can reach it too."""
    if "cfg" not in _TINY:
        from repro.configs import get_tiny_config
        from repro.models import lm
        cfg = get_tiny_config(TINY_ARCH)
        _TINY["cfg"] = cfg
        _TINY["params"] = lm.init_params(jax.random.PRNGKey(0), cfg)
    return _TINY["cfg"], _TINY["params"]


def seeded_prompts(cfg, n, length, *, seed=0, shared=0, motif=0):
    """``n`` deterministic int32 prompts of ``length`` tokens.

    ``shared`` > 0 gives every prompt the same leading tokens (prefix-
    cache fodder; pick a non-page-aligned value to force COW).
    ``motif`` > 0 instead tiles a per-prompt ``motif``-token pattern
    (speculation fodder: n-gram lookup drafts the period).
    """
    out = []
    base = None
    if shared > 0:
        base = np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed + 10_000), (min(shared, length),), 2,
            cfg.vocab_size), np.int32)
    for i in range(n):
        if motif > 0:
            pat = np.asarray(jax.random.randint(
                jax.random.PRNGKey(seed + i), (motif,), 2,
                cfg.vocab_size), np.int32)
            p = np.tile(pat, -(-length // motif))[:length]
        else:
            tail_len = length - (len(base) if base is not None else 0)
            tail = np.asarray(jax.random.randint(
                jax.random.PRNGKey(seed + i), (tail_len,), 2,
                cfg.vocab_size), np.int32)
            p = tail if base is None else np.concatenate([base, tail])
        out.append(np.asarray(p, np.int32))
    return out


def make_engine(cfg, params, **kw):
    """PagedEngine with small-test defaults; any kwarg overrides."""
    from repro.serving import PagedEngine
    defaults = dict(max_batch=3, page_size=4, n_pages=48, max_len=32)
    defaults.update(kw)
    return PagedEngine(cfg, params, **defaults)


def dense_oracle(cfg, params, prompts, gens, max_len):
    """Greedy reference through the dense (non-paged) path: request i ->
    ``"r{i}"`` -> its token list.  ``gens`` is an int or a per-request
    list.  This is the root oracle every serving configuration must
    match bit-for-bit."""
    from repro import steps as steps_mod
    key = (cfg, max_len)
    if key not in _DENSE_STEPS:
        _DENSE_STEPS[key] = (
            jax.jit(steps_mod.make_prefill_step(cfg, max_len=max_len)),
            jax.jit(steps_mod.make_serve_step(cfg)))
    prefill, serve = _DENSE_STEPS[key]
    if isinstance(gens, int):
        gens = [gens] * len(prompts)
    out = {}
    for i, (p, gen) in enumerate(zip(prompts, gens)):
        p = jnp.asarray(p)
        S = p.shape[0]
        logits, caches = prefill(params, p[None])
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(tok[0, 0])]
        for j in range(gen - 1):
            tok, logits, caches = serve(params, tok, caches,
                                        jnp.int32(S + j))
            toks.append(int(tok[0, 0]))
        out[f"r{i}"] = toks
    return out


def run_example(name: str, timeout: int = 300):
    """Run examples/<name> in a subprocess with src on PYTHONPATH."""
    import subprocess
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout)
