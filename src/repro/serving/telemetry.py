"""Swallow §IV made first-class: the instrumentation plane.

The paper's contribution is not the 480 cores but the *measurement* of
them — per-core power rails, instruction counters, and the §V/§VI
models that make performance attributable to communication and energy.
This module is that plane for the serving stack: one metrics
implementation, one event tracer, and the predicted-vs-measured hooks
that let every dispatch answer "did the cost model price you right?".

Three pieces, all pure host-side (no jax imports — unit-testable
anywhere, importable from CI scripts):

* :class:`HistogramDigest` — a streaming percentile digest.  Up to
  ``exact_max`` observations it keeps the raw samples and computes
  percentiles exactly (``numpy.percentile`` semantics, so values are
  bit-equal to the hand-rolled call sites it replaces); past that it
  spills to log-spaced buckets with bounded relative error
  (``rel_err``), keeping memory O(log range) no matter how long the
  server runs.

* :class:`MetricsRegistry` — counters, gauges (stored or computed), and
  named digests behind one snapshot/reset surface.  The
  :func:`counter_attr` / :func:`gauge_attr` descriptors expose registry
  slots as plain attributes, so ``self.h2d_syncs += 1`` in the engine
  and ``eng.h2d_syncs == 10`` in tests keep working verbatim while the
  storage moves into the registry ("same external names, one
  implementation").

* :class:`StepTracer` — a bounded ring-buffer flight recorder of spans
  on the *step clock* (plus wall stamps for rendering).  Two span
  categories: request-lifecycle states
  (queued→prefilling→running→preempted/recovered→finished/shed), one
  lane per request under a per-tenant track group; and dispatch spans
  (scan / draft_verify / chunk_prefill / cow_copy / prefill), each
  carrying the cost engine's predicted seconds and §VI energy next to
  measured wall time.  Exports Chrome trace-event JSON (loads in
  Perfetto), dumps the last N spans to a timestamped post-mortem file
  on invariant violation, and rolls dispatch spans into a per-phase
  model-error report.

Scheduling never reads the tracer and the tracer never touches the step
clock, so tokens are bit-identical tracing on or off — the property
``BENCH_obs.json`` pins.  See docs/OBSERVABILITY.md for the span
taxonomy and metrics schema.
"""
from __future__ import annotations

import json
import math
import os
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "HistogramDigest", "MetricsRegistry", "counter_attr", "gauge_attr",
    "Span", "StepTracer", "validate_chrome_trace", "rollup_dispatch_events",
    "format_model_error",
]


# ---------------------------------------------------------------------------
# streaming percentiles
# ---------------------------------------------------------------------------
class HistogramDigest:
    """Streaming p50/p95/p99 with an exact regime and a bounded spill.

    Observations up to ``exact_max`` are kept verbatim and percentiles
    use ``numpy.percentile`` (linear interpolation) — identical to the
    scattered call sites this class replaces, so committed benchmark
    gate values do not move.  Beyond that the digest folds into
    log-spaced buckets: value ``v`` lands in bucket
    ``ceil(log_gamma v)`` with ``gamma = (1+rel_err)/(1-rel_err)``, and
    a bucket's representative value is the geometric midpoint, so any
    reported percentile is within ``rel_err`` of the true sample
    (DDSketch's guarantee).  Non-positive observations share one
    underflow bucket (measured durations and step counts are >= 0).
    """

    def __init__(self, exact_max: int = 4096, rel_err: float = 0.01):
        assert exact_max >= 1 and 0.0 < rel_err < 1.0
        self.exact_max = exact_max
        self.rel_err = rel_err
        self._gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._lg = math.log(self._gamma)
        self.reset()

    def reset(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._exact: Optional[List[float]] = []
        self._buckets: Dict[int, int] = {}   # key -> count (spilled regime)
        self._zeros = 0                      # v <= 0 underflow bucket

    # -- ingest ------------------------------------------------------------
    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if self._exact is not None:
            self._exact.append(v)
            if len(self._exact) > self.exact_max:
                self._spill()
        else:
            self._bucket_add(v)

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    @classmethod
    def of(cls, values: Iterable[float], **kw) -> "HistogramDigest":
        d = cls(**kw)
        d.observe_many(values)
        return d

    # -- spill machinery ---------------------------------------------------
    def _key(self, v: float) -> int:
        return int(math.ceil(math.log(v) / self._lg))

    def _rep(self, key: int) -> float:
        # geometric midpoint of (gamma^(k-1), gamma^k]
        return 2.0 * self._gamma ** key / (self._gamma + 1.0)

    def _bucket_add(self, v: float) -> None:
        if v <= 0.0:
            self._zeros += 1
        else:
            k = self._key(v)
            self._buckets[k] = self._buckets.get(k, 0) + 1

    def _spill(self) -> None:
        samples, self._exact = self._exact, None
        for v in samples:
            self._bucket_add(v)

    @property
    def exact(self) -> bool:
        """True while percentiles are still computed on raw samples."""
        return self._exact is not None

    # -- read --------------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if self._exact is not None:
            return float(np.percentile(np.asarray(self._exact, np.float64), q))
        # nearest-rank over the spilled buckets (rel_err-bounded values)
        target = q / 100.0 * (self.count - 1)
        cum = 0
        if self._zeros:
            cum += self._zeros
            if cum - 1 >= target:
                return max(self.vmin, 0.0) if self.vmin < math.inf else 0.0
        for k in sorted(self._buckets):
            cum += self._buckets[k]
            if cum - 1 >= target:
                return min(max(self._rep(k), self.vmin), self.vmax)
        return self.vmax

    def percentiles(self, qs: Sequence[float]) -> List[float]:
        return [self.percentile(q) for q in qs]

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": self.count, "mean": self.mean,
            "min": self.vmin, "max": self.vmax,
            "p50": self.percentile(50), "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
class MetricsRegistry:
    """Counters, gauges, and digests behind one snapshot/reset surface.

    Counters are monotonic-ish numbers owned by the instrumented code
    (the descriptors below let ``self.x += 1`` write straight through).
    Gauges are either stored values (:meth:`set_gauge`) or zero-argument
    callables (:meth:`register_gauge`) sampled at snapshot time — the
    allocator registers ``pages_in_use`` etc. as callables so the
    registry never caches stale occupancy.  Histograms are
    :class:`HistogramDigest` instances created on first
    :meth:`observe`.
    """

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self._gauge_fns: Dict[str, Callable[[], float]] = {}
        self.hists: Dict[str, HistogramDigest] = {}

    # -- counters ----------------------------------------------------------
    def counter(self, name: str) -> float:
        return self.counters.get(name, 0)

    def inc(self, name: str, amount: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + amount

    def set_counter(self, name: str, value: float) -> None:
        self.counters[name] = value

    # -- gauges ------------------------------------------------------------
    def gauge(self, name: str, default: float = 0.0) -> float:
        fn = self._gauge_fns.get(name)
        if fn is not None:
            return fn()
        return self.gauges.get(name, default)

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def register_gauge(self, name: str, fn: Callable[[], float]) -> None:
        self._gauge_fns[name] = fn

    # -- histograms --------------------------------------------------------
    def hist(self, name: str, **kw) -> HistogramDigest:
        d = self.hists.get(name)
        if d is None:
            d = self.hists[name] = HistogramDigest(**kw)
        return d

    def observe(self, name: str, value: float) -> None:
        self.hist(name).observe(value)

    def percentile(self, name: str, q: float, default: float = 0.0) -> float:
        d = self.hists.get(name)
        if d is None or d.count == 0:
            return default
        return d.percentile(q)

    # -- lifecycle ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        gauges = {n: fn() for n, fn in self._gauge_fns.items()}
        gauges.update(self.gauges)
        return {
            "counters": dict(self.counters),
            "gauges": gauges,
            "histograms": {n: d.snapshot() for n, d in self.hists.items()},
        }

    def reset(self) -> None:
        """Zero counters and stored gauges, reset digests; registered
        gauge callables (live views) are untouched.  Keys persist so
        the snapshot schema is stable across a warmup reset."""
        for n in self.counters:
            self.counters[n] = 0
        for n in self.gauges:
            self.gauges[n] = 0
        for d in self.hists.values():
            d.reset()


class counter_attr:
    """Data descriptor exposing a registry counter as a plain attribute.

    ``class Eng: h2d_syncs = counter_attr()`` makes ``self.h2d_syncs``
    read/write ``self.registry.counters["h2d_syncs"]`` — existing
    increment sites and tests that poke the attribute keep working
    while the registry becomes the single storage.
    """

    def __init__(self, name: Optional[str] = None, registry: str = "registry"):
        self.name = name
        self.registry = registry

    def __set_name__(self, owner, attr):
        if self.name is None:
            self.name = attr

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.registry).counters.get(self.name, 0)

    def __set__(self, obj, value):
        getattr(obj, self.registry).counters[self.name] = value


class gauge_attr:
    """Like :func:`counter_attr` but over the registry's stored gauges
    (point-in-time values: occupancy, rates, percentiles-at-report)."""

    def __init__(self, name: Optional[str] = None, registry: str = "registry",
                 default: float = 0.0):
        self.name = name
        self.registry = registry
        self.default = default

    def __set_name__(self, owner, attr):
        if self.name is None:
            self.name = attr

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.registry).gauges.get(self.name, self.default)

    def __set__(self, obj, value):
        getattr(obj, self.registry).gauges[self.name] = value


# ---------------------------------------------------------------------------
# the flight recorder
# ---------------------------------------------------------------------------
@dataclass
class Span:
    """One closed interval on a track.

    ``group``/``track`` name the Perfetto process/thread lanes;
    ``start_step``/``end_step`` are deterministic step-clock stamps;
    ``t0``/``t1`` are wall (perf_counter) stamps used only for
    rendering.  ``args`` carries per-span payload — for dispatch spans
    the predicted/measured attribution triple."""
    name: str
    cat: str              # "dispatch" | "request" | "marker"
    group: str            # process lane, e.g. "dispatch" or "tenant:acme"
    track: str            # thread lane, e.g. "scan" or the request id
    start_step: int
    end_step: int
    t0: float
    t1: float
    args: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "cat": self.cat, "group": self.group,
            "track": self.track, "start_step": self.start_step,
            "end_step": self.end_step, "t0": self.t0, "t1": self.t1,
            "args": dict(self.args),
        }


# terminal request states close the lane instead of opening a new span
_TERMINAL = ("finished", "shed")


class StepTracer:
    """Bounded ring-buffer flight recorder on the step clock.

    The engine/scheduler call :meth:`request_event` at every lifecycle
    transition and wrap device dispatches in :meth:`dispatch`; the ring
    (``capacity`` spans, FIFO eviction) always holds the most recent
    history, which :meth:`flight_dump` writes out on an invariant
    violation and :meth:`chrome_trace` exports for Perfetto.
    """

    def __init__(self, capacity: int = 4096, dump_dir: str = "."):
        self.capacity = int(capacity)
        self.dump_dir = dump_dir
        self.reset()

    def reset(self) -> None:
        self.spans: deque = deque(maxlen=self.capacity)
        self.recorded = 0                     # total ever recorded
        self.samples: deque = deque(maxlen=self.capacity)  # (step, wall, [per-node])
        self._open: Dict[str, Span] = {}      # rid -> open lifecycle span
        self._origin = time.perf_counter()

    @property
    def dropped(self) -> int:
        """Spans evicted from the ring (recorded - retained)."""
        return self.recorded - len(self.spans)

    @property
    def open_spans(self) -> Dict[str, Span]:
        return dict(self._open)

    def _record(self, span: Span) -> None:
        self.spans.append(span)
        self.recorded += 1

    # -- dispatch spans ----------------------------------------------------
    @contextmanager
    def dispatch(self, phase: str, step: int, *, predicted_s: float = 0.0,
                 predicted_j: float = 0.0, **extra):
        """Wrap one device dispatch; measured wall time is the context
        body's duration, recorded next to the cost engine's prediction."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            args = {"predicted_s": float(predicted_s),
                    "predicted_j": float(predicted_j),
                    "measured_s": t1 - t0}
            args.update(extra)
            self._record(Span(phase, "dispatch", "dispatch", phase,
                              step, step, t0, t1, args))

    # -- request lifecycle spans ------------------------------------------
    def request_event(self, rid: str, state: str, step: int, *,
                      tenant: str = "default", **args) -> None:
        """Close the request's current state span (if any) and open the
        next — or record a zero-length terminal marker for
        finished/shed.  One lane per request id under a per-tenant
        group, so spans on a lane never overlap by construction."""
        now = time.perf_counter()
        group = f"tenant:{tenant}"
        prev = self._open.pop(rid, None)
        if prev is not None:
            prev.end_step = step
            prev.t1 = now
            self._record(prev)
        if state in _TERMINAL:
            self._record(Span(state, "marker", group, rid, step, step,
                              now, now, dict(args)))
        else:
            self._open[rid] = Span(state, "request", group, rid, step, step,
                                   now, now, dict(args))

    def finalize(self, step: int) -> None:
        """Close every still-open lifecycle span (end of run)."""
        for rid in list(self._open):
            span = self._open.pop(rid)
            span.end_step = step
            span.t1 = time.perf_counter()
            self._record(span)

    # -- counter tracks ----------------------------------------------------
    def counter_sample(self, step: int, values: Sequence[int]) -> None:
        """Per-node page occupancy sample (rendered as a stacked
        Perfetto counter track)."""
        self.samples.append((int(step), time.perf_counter(), list(values)))

    # -- model error -------------------------------------------------------
    def model_error_report(self) -> Dict[str, Dict[str, float]]:
        """Per-phase predicted-vs-measured rollup over the dispatch
        spans still in the ring."""
        return rollup_dispatch_events(
            {"cat": s.cat, "name": s.name, "args": s.args}
            for s in self.spans)

    # -- exports -----------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (dict).  ``ph:"X"`` complete events
        on integer pid/tid lanes named by metadata events; counter
        samples become ``ph:"C"`` events.  Load the written file in
        Perfetto (ui.perfetto.dev) or chrome://tracing."""
        events: List[Dict[str, Any]] = []
        pids: Dict[str, int] = {}
        tids: Dict[Tuple[str, str], int] = {}

        def pid_of(group: str) -> int:
            if group not in pids:
                pid = pids[group] = len(pids) + 1
                events.append({"name": "process_name", "ph": "M", "pid": pid,
                               "tid": 0, "args": {"name": group}})
            return pids[group]

        def tid_of(group: str, track: str) -> int:
            key = (group, track)
            if key not in tids:
                tid = tids[key] = sum(g == group for g, _ in tids) + 1
                events.append({"name": "thread_name", "ph": "M",
                               "pid": pid_of(group), "tid": tid,
                               "args": {"name": track}})
            return tids[key]

        def us(t: float) -> float:
            return round((t - self._origin) * 1e6, 3)

        for s in self.spans:
            pid = pid_of(s.group)
            tid = tid_of(s.group, s.track)
            args = {"start_step": s.start_step, "end_step": s.end_step}
            args.update(s.args)
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "pid": pid, "tid": tid,
                "ts": us(s.t0), "dur": max(round((s.t1 - s.t0) * 1e6, 3), 0.0),
                "args": args,
            })
        for step, wall, values in self.samples:
            events.append({
                "name": "pages_in_use", "cat": "occupancy", "ph": "C",
                "pid": pid_of("nodes"), "tid": 0, "ts": us(wall),
                "args": {f"node{i}": v for i, v in enumerate(values)},
            })
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"clock": "perf_counter_us",
                              "spans_recorded": self.recorded,
                              "spans_dropped": self.dropped}}

    def write_chrome(self, path: str) -> str:
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def flight_dump(self, reason: str,
                    registry: Optional[MetricsRegistry] = None,
                    directory: Optional[str] = None) -> str:
        """Post-mortem: write the last N spans (+ a registry snapshot)
        to ``flight-<reason>-<stamp>.json`` and return the path.  Wall
        clock is fine here — dump naming is telemetry, not
        scheduling."""
        stamp = time.strftime("%Y%m%d-%H%M%S")
        path = os.path.join(directory or self.dump_dir,
                            f"flight-{reason}-{stamp}.json")
        doc = {
            "reason": reason,
            "dumped_at": stamp,
            "spans": [s.to_dict() for s in self.spans],
            "open_spans": [s.to_dict() for s in self._open.values()],
            "counter_samples": [list(s) for s in self.samples],
            "spans_recorded": self.recorded,
            "spans_dropped": self.dropped,
        }
        if registry is not None:
            doc["metrics"] = registry.snapshot()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return path


# ---------------------------------------------------------------------------
# model-error rollup + trace validation (shared by bench, CI, report tool)
# ---------------------------------------------------------------------------
def rollup_dispatch_events(events: Iterable[Dict[str, Any]]
                           ) -> Dict[str, Dict[str, float]]:
    """Aggregate dispatch events (Span dicts or Chrome events — anything
    with ``cat == "dispatch"`` and the attribution triple in ``args``)
    into a per-phase model-error table."""
    acc: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("cat") != "dispatch":
            continue
        args = ev.get("args", {})
        if "measured_s" not in args:
            continue
        row = acc.setdefault(ev["name"], {
            "count": 0, "predicted_s": 0.0, "measured_s": 0.0,
            "predicted_j": 0.0, "predicted_comms_s": 0.0,
            "comms_bytes": 0.0})
        row["count"] += 1
        row["predicted_s"] += float(args.get("predicted_s", 0.0))
        row["measured_s"] += float(args.get("measured_s", 0.0))
        row["predicted_j"] += float(args.get("predicted_j", 0.0))
        # striped-serving interconnect attribution (§V link model): spans
        # dispatched under a mesh carry the window's predicted stripe
        # traffic; single-device spans simply contribute 0
        row["predicted_comms_s"] += float(args.get("predicted_comms_s", 0.0))
        row["comms_bytes"] += float(args.get("comms_bytes", 0.0))
    for row in acc.values():
        row["err_ratio"] = (row["measured_s"] / row["predicted_s"]
                            if row["predicted_s"] > 0 else float("inf"))
    return acc


def format_model_error(report: Dict[str, Dict[str, float]]) -> str:
    """Fixed-width per-phase attribution table (the §IV 'measured vs
    modeled' view)."""
    hdr = (f"{'phase':<14} {'count':>6} {'pred_s':>10} {'meas_s':>10} "
           f"{'meas/pred':>9} {'pred_J':>10} {'comm_s':>9}")
    lines = [hdr, "-" * len(hdr)]
    for phase in sorted(report):
        r = report[phase]
        ratio = r["err_ratio"]
        lines.append(
            f"{phase:<14} {int(r['count']):>6} {r['predicted_s']:>10.4f} "
            f"{r['measured_s']:>10.4f} "
            f"{ratio if math.isfinite(ratio) else float('nan'):>9.2f} "
            f"{r['predicted_j']:>10.3f} "
            f"{r.get('predicted_comms_s', 0.0):>9.4f}")
    return "\n".join(lines)


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema check for an exported trace; returns a list of problems
    (empty == valid).  Used by tests and ``check_bench.py::check_obs``."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with a traceEvents array"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents must be an array"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "C"):
            errs.append(f"event {i}: unexpected ph {ph!r}")
            continue
        for k in ("name", "pid", "tid"):
            if k not in ev:
                errs.append(f"event {i}: missing {k}")
        if not isinstance(ev.get("name"), str):
            errs.append(f"event {i}: name must be a string")
        if ph in ("X", "C"):
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"event {i}: ts must be a number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: dur must be a number >= 0")
            if not isinstance(ev.get("args"), dict):
                errs.append(f"event {i}: args must be an object")
        if ph == "M" and ev.get("name") not in ("process_name", "thread_name"):
            errs.append(f"event {i}: metadata name {ev.get('name')!r}")
        if len(errs) > 20:
            errs.append("... (truncated)")
            break
    return errs
