"""One benchmark per Swallow table/figure, each returning CSV rows
(name, us_per_call, derived)."""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

Row = Tuple[str, float, str]


def _timeit(fn, n=5) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# --- Table II: per-bit link energies ----------------------------------------
def table2_link_energy() -> List[Row]:
    from repro.core import energy
    rows = []
    for link, pj in energy.SWALLOW_LINK_PJ_PER_BIT.items():
        rows.append((f"tab2/swallow_{link}_pJ_per_bit", 0.0, f"{pj}"))
    # off-board/on-board ratio ~50x (paper's observation)
    ratio = energy.SWALLOW_LINK_PJ_PER_BIT["off_board_ffc"] / \
        energy.SWALLOW_LINK_PJ_PER_BIT["on_board_h"]
    rows.append(("tab2/off_on_board_ratio", 0.0, f"{ratio:.1f}"))
    # TPU analogues per byte
    rows.append(("tab2/tpu_hbm_pJ_per_byte", 0.0,
                 f"{energy.TPU_HBM_PJ_PER_BYTE*1e12:.1f}"))
    rows.append(("tab2/tpu_ici_pJ_per_byte", 0.0,
                 f"{energy.TPU_ICI_PJ_PER_BYTE*1e12:.1f}"))
    rows.append(("tab2/tpu_dcn_pJ_per_byte", 0.0,
                 f"{energy.TPU_DCN_PJ_PER_BYTE*1e12:.1f}"))
    return rows


# --- Table III: e/c and E/C ratios ------------------------------------------
def table3_ec_ratio() -> List[Row]:
    from repro.core import ratio
    rows = []
    for name, t in ratio.SWALLOW_TABLE_III.items():
        ec = t["ec"] if t["ec"] is not None else float("nan")
        EC = t["EC"][1] if isinstance(t["EC"], tuple) else t["EC"]
        rows.append((f"tab3/{name}_ec", 0.0, f"{ec}"))
        rows.append((f"tab3/{name}_EC", 0.0, f"{EC}"))
    # our dry-run cells (if the sweep results exist)
    path = "results/dryrun.json"
    if os.path.exists(path):
        recs = [r for r in json.load(open(path))
                if "roofline" in r and r["mesh"] == "16x16"]
        for r in recs[:40]:
            rl = r["roofline"]
            rep = ratio.analyze_cell(
                f"{r['arch']}x{r['shape']}",
                rl["wire_bytes_per_device"],
                rl["t_compute"], r["chips"],
                {"data": 16, "model": 16})
            rows.append((f"tab3/{r['arch']}.{r['shape']}_ec", 0.0,
                         f"{rep.ec:.3f}"))
    return rows


# --- Table IV: per-core power -------------------------------------------------
def table4_power() -> List[Row]:
    from repro.core import energy
    paper = {"Swallow": (193, 500, 300), "SpiNNaker": (87, 200, 435),
             "Tilera": (300, 1000, 300), "Epiphany": (31, 800, 38.8)}
    rows = []
    for name, (mw, mhz, uw_per_mhz) in paper.items():
        rows.append((f"tab4/{name}_mW_per_core", 0.0, f"{mw}"))
        rows.append((f"tab4/{name}_uW_per_MHz", 0.0, f"{uw_per_mhz}"))
    # our Eqn-3 model vs the measured 193 mW
    model = energy.swallow_core_power_mw(500)
    rows.append(("tab4/swallow_eqn3_mW@500", 0.0, f"{model:.1f}"))
    rows.append(("tab4/tpu_chip_W_active", 0.0, f"{energy.TPU_TDP_W}"))
    return rows


# --- Fig. 3: memory per task ---------------------------------------------------
def fig3_memory_per_task() -> List[Row]:
    from repro.core.memory_server import memory_per_task
    rows = []
    for p, t in [(16, 1), (256, 1), (4096, 1), (256, 256), (4096, 256),
                 (4096, 4096)]:
        rows.append((f"fig3/procs{p}_tasks{t}_kB", 0.0,
                     f"{memory_per_task(p, t):.0f}"))
    return rows


# --- Fig. 5: thread throughput scaling -----------------------------------------
def fig5_thread_throughput() -> List[Row]:
    """Swallow: per-thread MIPS constant to 4 threads then 500/n; aggregate
    maxed at >=4.  TPU analogue: pipeline bubble vs microbatch count."""
    from repro.parallel.pipeline import bubble_fraction
    rows = []
    for n in (1, 2, 4, 6, 8):
        per = 125.0 if n <= 4 else 500.0 / n
        rows.append((f"fig5/threads{n}_MIPS_per_thread", 0.0, f"{per:.1f}"))
        rows.append((f"fig5/threads{n}_MIPS_total", 0.0,
                     f"{min(n, 4) * 125.0:.0f}"))
    for m in (1, 2, 4, 8, 16):
        eff = 1.0 - bubble_fraction(4, m)
        rows.append((f"fig5/pipeline4_micro{m}_efficiency", 0.0,
                     f"{eff:.3f}"))
    return rows


# --- Fig. 9/10: DVFS -----------------------------------------------------------
def fig9_fig10_dvfs() -> List[Row]:
    from repro.core import energy
    rows = []
    for f in (71, 150, 250, 350, 500):
        rows.append((f"fig9/loaded_{f}MHz_mW", 0.0,
                     f"{energy.swallow_core_power_mw(f):.1f}"))
        rows.append((f"fig10/dvfs_{f}MHz_mW", 0.0,
                     f"{energy.swallow_dvfs_power_mw(f):.1f}"))
    # energy proportionality at pod scale
    for load in (0.0, 0.25, 0.5, 1.0):
        rows.append((f"fig9/tpu_load{load}_W", 0.0,
                     f"{energy.energy_proportionality(load, model='tpu'):.0f}"))
    return rows


# --- Fig. 11: Izhikevich neuron scaling -----------------------------------------
def fig11_neuron_scaling() -> List[Row]:
    import sys
    sys.path.insert(0, "examples")
    from neuron_sim import max_neurons_per_core, scaling_curve, simulate
    rows = []
    for n_per_core, total in scaling_curve():
        rows.append((f"fig11/neurons_per_core_{n_per_core}", 0.0,
                     f"{total:.0f}"))
    # a real (small) simulation run: N neurons, 10% connectivity
    t0 = time.perf_counter()
    res = simulate(n_neurons=256, steps=100, seed=0)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("fig11/sim256_spikes", us, f"{res['total_spikes']}"))
    rows.append(("fig11/sim256_rate_hz", 0.0, f"{res['rate_hz']:.1f}"))
    # the paper's hard limit: table memory kills scaling at ~100k neurons
    rows.append(("fig11/max_neurons_64kB_at_10pct", 0.0,
                 f"{max_neurons_per_core(100_000)}"))
    return rows
