"""Swallow §III-A + §X-B: nodes as remote data storage / shared-memory
emulation over distributed memory.

Two strategies, exactly as the paper frames them:
  * ``SingleController`` — one node owns the whole store; every access is
    a message to it (simple, a contention point).
  * ``StripedStore`` — address space striped ``address % n`` over n
    per-node controllers (the paper's "more elegant strategy").

On the mesh this is a real distributed object store: a fixed-size fp32
slab sharded over every device; reads/writes are gather/scatter
collectives issued per batch of addresses.  The same striping rule is
what the LM stack uses for vocab-sharded embeddings and expert tables —
``striped_owner`` is the single source of truth for the mapping.  The
paged-KV serving engine reuses it too: ``repro.serving.paged_kv`` stripes
KV pages over the mesh with exactly this rule (docs/SERVING.md), so the
cache traffic follows the paper's (n-1)/n remote-fraction model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import current_env


def striped_owner(address, n_nodes: int):
    """address % n — the paper's distribution rule."""
    return address % n_nodes


def stripe_slab_index(address, n_nodes: int, size: int):
    """Slab (physical) row of ``address`` under the stripe layout.

    Word/page ``a`` lives on node ``a % n`` at local offset ``a // n``;
    laying node stripes contiguous (node ``d`` owns rows
    ``[d*size/n, (d+1)*size/n)``) makes a ``NamedSharding`` over the
    leading axis place each stripe physically on its owner device —
    host-side ``striped_owner`` accounting and device placement agree.
    Identity when ``n_nodes == 1``; ``stripe_slab_index(0, ...) == 0``
    always (the serving engine's null page stays row 0 on node 0).
    Requires ``size % n_nodes == 0``.
    """
    node = address % n_nodes
    local = address // n_nodes
    return node * (size // n_nodes) + local


@dataclass
class StripedStore:
    """address space striped over devices along one mesh axis."""
    size: int                   # total words
    axis: str = "model"

    def __post_init__(self):
        env = current_env()
        self.env = env
        self.n = env.mesh.shape[self.axis] if env is not None else 1
        assert self.size % max(self.n, 1) == 0
        spec = P(self.axis) if env is not None else P()
        if env is not None:
            self.slab = jax.device_put(
                jnp.zeros((self.size,), jnp.float32),
                NamedSharding(env.mesh, spec))
        else:
            self.slab = jnp.zeros((self.size,), jnp.float32)

    # Stripe layout: word w lives on node w % n at local offset w // n.
    # jnp layout trick: reshape (n, size/n) puts node stripes contiguous.
    def _to_slab_index(self, addr):
        return stripe_slab_index(addr, self.n, self.size)

    def read(self, addresses):
        """Gather a batch of words (collective when owners are remote)."""
        return self.slab[self._to_slab_index(addresses)]

    def write(self, addresses, values):
        out = self.slab.at[self._to_slab_index(addresses)].set(values)
        if self.env is not None:
            # .at[].set rebinds the slab through a scatter whose output
            # sharding XLA may resolve to replicated — re-pin the stripe
            # so a write never silently decays the placement
            out = jax.device_put(
                out, NamedSharding(self.env.mesh, P(self.axis)))
        self.slab = out
        return self.slab

    def traffic_model(self, n_accesses: int,
                      n_nodes: Optional[int] = None) -> dict:
        """Expected fraction of remote accesses (paper: (n-1)/n of reads
        leave the node under uniform addressing)."""
        n = n_nodes if n_nodes is not None else self.n
        remote = (n - 1) / max(n, 1)
        return {"remote_fraction": remote,
                "expected_remote_words": n_accesses * remote,
                "contention_points": 0}


@dataclass
class SingleController:
    """One owner node: every access is remote for everyone else."""
    size: int

    def __post_init__(self):
        self.slab = jnp.zeros((self.size,), jnp.float32)

    def read(self, addresses):
        return self.slab[addresses]

    def write(self, addresses, values):
        self.slab = self.slab.at[addresses].set(values)
        return self.slab

    def traffic_model(self, n_accesses: int, n_nodes: int) -> dict:
        remote = (n_nodes - 1) / max(n_nodes, 1)
        return {"remote_fraction": remote,
                "expected_remote_words": n_accesses * remote,
                "contention_points": 1}


def memory_per_task(n_procs: int, tasks: int,
                    node_kb: float = 64.0) -> float:
    """Fig. 3: memory available per task (kB) when ``tasks`` tasks share
    ``n_procs`` nodes (idle nodes become storage)."""
    return n_procs * node_kb / max(tasks, 1)
