"""Explicit sequence-parallel collectives (Megatron-SP via shard_map).

GSPMD's AllReduce->ReduceScatter rewrite is a backend optimization pass —
the CPU pipeline we dry-run on doesn't apply it, and at 1000-node scale
one does not want to *hope* the compiler halves the dominant wire term.
These wrappers make the two Megatron-SP collectives explicit program
text (the Swallow rule: every byte on the wire is visible):

  gather_seq(x)        (B, S/tp, D) -> (B, S, D)      all-gather
                       backward: psum_scatter          reduce-scatter
  row_parallel(x, w)   partial dot -> (B, S/tp, N)     reduce-scatter
                       backward: all-gather

shard_map autodiff transposes all_gather <-> psum_scatter exactly, so the
backward pass gets the optimal pattern too (this is what eliminated the
fp32 (B,S,D) all-reduces the HLO attribution found — see EXPERIMENTS.md).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import current_env

from repro.parallel.sharding import compat_shard_map as _shard_map


def _axes_tuple(a):
    if a is None:
        return ()
    return (a,) if isinstance(a, str) else tuple(a)


def _sp_axes(env):
    return _axes_tuple(env.resolve("seq_sp")) if env is not None else ()


def _applicable(x, env):
    if env is None or x.ndim != 3 or x.shape[1] <= 1:
        return ()
    axes = _sp_axes(env)
    n = 1
    for a in axes:
        n *= env.mesh.shape[a]
    if n <= 1 or x.shape[1] % n:
        return ()
    return axes


def gather_seq(x):
    """(B, S, D) seq-sharded -> full sequence, replicated over "model".

    Backward is a reduce-scatter of the cotangent.  No-op without a mesh
    (or for decode-length sequences).
    """
    env = current_env()
    axes = _applicable(x, env)
    if not axes:
        return x

    def body(x_l):
        for ax in axes:
            x_l = jax.lax.all_gather(x_l, ax, axis=1, tiled=True)
        return x_l

    return _shard_map(
        body, mesh=env.mesh,
        in_specs=(env.spec("batch", "seq_sp", None),),
        out_specs=env.spec("batch", None, None),
        check_vma=False)(x)


def column_parallel(x, ws, out_dtype=None):
    """Fused column-parallel matmuls: one AG of the seq-sharded input, N
    local dots against column-sharded weights.

    The fusion matters for the backward pass: the transpose computes all
    weight-gradient contractions *inside* the shard_map body and emits a
    single reduce-scatter for the input cotangent — no partial-sum
    all-reduces escape to GSPMD (the failure mode HLO attribution found).

    x (B, S/tp, D); ws list of (D, N_i) sharded on N_i.
    Returns list of (B, S, N_i/tp-sharded) activations.
    """
    env = current_env()
    out_dtype = out_dtype or x.dtype
    axes = _applicable(x, env)
    if not axes:
        return [jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(out_dtype)
            for w in ws]

    def body(x_l, *ws_l):
        for ax in axes:
            x_l = jax.lax.all_gather(x_l, ax, axis=1, tiled=True)
        return tuple(
            jax.lax.dot_general(x_l, w_l, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32
                                ).astype(out_dtype)
            for w_l in ws_l)

    outs = _shard_map(
        body, mesh=env.mesh,
        in_specs=(env.spec("batch", "seq_sp", None),)
        + tuple(env.spec(None, "tp") for _ in ws),
        out_specs=tuple(env.spec("batch", None, "tp") for _ in ws),
        check_vma=False)(x, *ws)
    return list(outs)


def row_parallel(x, w, out_dtype=None):
    """Row-parallel matmul with explicit reduce-scatter output.

    x (B, S, K) sharded on K over "model"; w (K, N) sharded on K.
    Returns (B, S, N) sequence-sharded over "model".  Falls back to a
    plain fp32-accum matmul (with an all-psum for decode) off-mesh.
    """
    env = current_env()
    out_dtype = out_dtype or x.dtype
    axes = _applicable(x, env)
    if not axes:
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return y.astype(out_dtype)

    def body(x_l, w_l):
        y = jax.lax.dot_general(x_l, w_l, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = y.astype(out_dtype)   # reduce on the wire in activation dtype
        for ax in axes:
            y = jax.lax.psum_scatter(y, ax, scatter_dimension=1, tiled=True)
        return y

    return _shard_map(
        body, mesh=env.mesh,
        in_specs=(env.spec("batch", None, "tp"), env.spec("tp", None)),
        out_specs=env.spec("batch", "seq_sp", None),
        check_vma=False)(x, w)
