"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels lower natively (interpret=False); on the CPU container
they run under interpret mode, which executes the kernel body with jnp
semantics — bit-for-bit the same tiling logic, validated against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import (decode_attention as _dec, flash_attention as _fa,
                           moe_gemm as _mg, rglru_scan as _rg,
                           rmsnorm as _rn, rwkv6_scan as _rw)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "softcap", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None, block_q=512, block_kv=1024):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, softcap=softcap,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "block_t"))
def decode_attention(q, k, v, pos, *, scale=None, softcap=None,
                     block_t=512):
    return _dec.decode_attention(q, k, v, pos, scale=scale, softcap=softcap,
                                 block_t=block_t, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "block_t",
                                             "partials"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, pos, *,
                           scale=None, softcap=None, block_t=None,
                           page_mask=None, partials=False):
    return _dec.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                       pos, scale=scale, softcap=softcap,
                                       block_t=block_t, page_mask=page_mask,
                                       partials=partials,
                                       interpret=_interpret())


@jax.jit
def rglru_scan(a, b, h0):
    return _rg.rglru_scan(a, b, h0, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, lw, u, S0, *, chunk=32):
    return _rw.rwkv6_scan(r, k, v, lw, u, S0, chunk=chunk,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "block_d"))
def moe_gemm(x, w, *, block_c=128, block_f=512, block_d=512):
    return _mg.moe_gemm(x, w, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=256):
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interpret())
