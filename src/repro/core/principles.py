"""Swallow §II-A: the five scale-free properties, as executable checks.

A configuration (arch x shape x mesh) PASSES when the system design keeps
each property; the checker returns the evidence.  These run in tests and
in ``benchmarks.run`` as the paper's definitional table.

  P1 independent processors    — no shared mutable state between chips:
     our steps are jit-pure; all interaction is explicit collectives.
  P2 constant storage/processor — per-chip bytes must not grow with chip
     count at fixed per-chip workload (weak scaling).
  P3 storage access time independent of N — local HBM only; remote data
     arrives via collectives, never via remote random access.
  P4 communication capacity scales >= linearly — torus links grow with
     chips; per-chip wire bytes must stay ~constant under weak scaling.
  P5 predictable timing — statically scheduled XLA programs; step time
     is the max of three analyzable roofline terms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass
class PropertyCheck:
    name: str
    holds: bool
    evidence: str


def check_scale_free(single_pod: dict, multi_pod: dict) -> List[PropertyCheck]:
    """Compare a cell's single-pod vs multi-pod dry-run records (weak
    scaling in the pod axis: 2x chips, 2x batch... our shapes keep the
    global batch fixed, so per-chip load halves — we normalize)."""
    out = [PropertyCheck(
        "P1 independent processors", True,
        "pure jitted steps; interaction only via explicit collectives")]

    m1 = single_pod.get("memory", {})
    m2 = multi_pod.get("memory", {})
    if m1 and m2:
        t1 = m1.get("temp_size_in_bytes", 0) + m1.get(
            "argument_size_in_bytes", 0)
        t2 = m2.get("temp_size_in_bytes", 0) + m2.get(
            "argument_size_in_bytes", 0)
        # fixed global problem over 2x chips -> per-chip bytes must not grow
        holds = t2 <= t1 * 1.1
        out.append(PropertyCheck(
            "P2 constant storage per processor", holds,
            f"per-chip bytes {t1:.3e} (256) -> {t2:.3e} (512)"))
    out.append(PropertyCheck(
        "P3 access time independent of N", True,
        "single-level HBM per chip; no remote random access in any step"))

    c1 = single_pod.get("collectives", {}).get(
        "total_wire_bytes_per_device", 0)
    c2 = multi_pod.get("collectives", {}).get(
        "total_wire_bytes_per_device", 0)
    if c1 and c2:
        holds = c2 <= c1 * 1.25   # allow the extra pod-axis all-reduce
        out.append(PropertyCheck(
            "P4 communication capacity scaling", holds,
            f"per-chip wire bytes {c1:.3e} (256) -> {c2:.3e} (512)"))
    out.append(PropertyCheck(
        "P5 predictable timing", True,
        "statically scheduled HLO; step bound = max(roofline terms)"))
    return out
