"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892; unverified].

Attention-free SSM-like: 24L, d_model=2048, d_ff=7168 (RWKV channel-mix),
vocab=65536.  Time-mix with data-dependent decay (head size 64 → 32 heads),
token-shift low-rank interpolation, bonus term u.  Sub-quadratic:
eligible for long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d_model / 64 RWKV head size
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=("rwkv6",),
    act="relu2",           # RWKV channel-mix uses squared ReLU
    gated_ffn=False,
    rope=False,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, attn_block_q=16, attn_block_kv=32)
