"""End-to-end driver: train the ~100M-parameter LM for a few hundred steps
with checkpointing, restart safety and metrics logging.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
(CPU: ~100M params; use --steps 20 for a quick pass.  Interrupt and
re-run with the same --ckpt-dir to verify restart.)
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.runtime import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    ap.add_argument("--metrics", default="results/train_lm_metrics.json")
    args = ap.parse_args()

    cfg = get_config("tiny-100m")
    print(f"training {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")
    shape = ShapeConfig("train_lm", args.seq, args.batch, "train")
    job = train_loop.TrainJobConfig(
        steps=args.steps, log_every=10, ckpt_every=50,
        ckpt_dir=args.ckpt_dir, peak_lr=6e-4, warmup=20,
        metrics_path=args.metrics)
    out = train_loop.run(cfg, shape, job=job)
    hist = out["history"]
    print(f"done in {out['wall_s']:.0f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
