"""Swallow §V-A: the 2.5-D "lattice" topology and dimension-ordered routing.

The XS1-L2A package exposes 4 external links but burns the internal ones
on the core<->core connection, so a grid of packages becomes a two-layer
*lattice*: one layer of cores routes vertically, the other horizontally,
with the only layer crossing inside a package (Fig. 7).  DOR with
vertical priority needs at most TWO layer transitions per route — we
implement the generator + router and property-test exactly that claim,
plus full connectivity.

``map_to_torus`` then re-derives the lesson for TPU: the lattice's
"dimension per layer" becomes "collective phase per mesh axis" — our 2-D
all-reduce decomposition (reduce-scatter along "data", then along "pod",
then all-gather back) is dimension-ordered routing applied to
collectives (see parallel/lattice.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Tuple

Node = Tuple[int, int, int]   # (layer, row, col); layer 0 = vertical router


@dataclass(frozen=True)
class Lattice:
    rows: int
    cols: int

    def nodes(self) -> Iterator[Node]:
        for l in (0, 1):
            for r in range(self.rows):
                for c in range(self.cols):
                    yield (l, r, c)

    def neighbors(self, n: Node) -> List[Node]:
        l, r, c = n
        out = [(1 - l, r, c)]                       # intra-package crossing
        if l == 0:                                   # vertical layer
            if r > 0:
                out.append((0, r - 1, c))
            if r < self.rows - 1:
                out.append((0, r + 1, c))
        else:                                        # horizontal layer
            if c > 0:
                out.append((1, r, c - 1))
            if c < self.cols - 1:
                out.append((1, r, c + 1))
        return out

    def route(self, src: Node, dst: Node) -> List[Node]:
        """Dimension-ordered routing, vertical dimension first (§V-A)."""
        path = [src]
        cur = src
        # 1. vertical moves need the vertical layer
        if cur[1] != dst[1]:
            if cur[0] != 0:
                cur = (0, cur[1], cur[2])
                path.append(cur)
            step = 1 if dst[1] > cur[1] else -1
            while cur[1] != dst[1]:
                cur = (0, cur[1] + step, cur[2])
                path.append(cur)
        # 2. horizontal moves need the horizontal layer
        if cur[2] != dst[2]:
            if cur[0] != 1:
                cur = (1, cur[1], cur[2])
                path.append(cur)
            step = 1 if dst[2] > cur[2] else -1
            while cur[2] != dst[2]:
                cur = (1, cur[1], cur[2] + step)
                path.append(cur)
        # 3. final layer fix-up (at most one more transition)
        if cur[0] != dst[0]:
            cur = (dst[0], cur[1], cur[2])
            path.append(cur)
        return path

    @staticmethod
    def layer_transitions(path: List[Node]) -> int:
        return sum(1 for a, b in zip(path, path[1:]) if a[0] != b[0])

    def hops(self, src: Node, dst: Node) -> int:
        return len(self.route(src, dst)) - 1


def average_hops(lat: Lattice, sample: int = 0) -> float:
    nodes = list(lat.nodes())
    tot = n = 0
    for i, s in enumerate(nodes):
        for d in nodes[i + 1:]:
            tot += lat.hops(s, d)
            n += 1
    return tot / max(n, 1)


def map_to_torus(mesh_shape: Dict[str, int]) -> Dict[str, float]:
    """TPU-torus analogue figures for a mesh: per-axis ring hop counts for
    the collectives our framework emits (ring AG/RS = size-1 hops)."""
    out = {}
    for axis, size in mesh_shape.items():
        out[axis] = {
            "ring_steps": max(size - 1, 0),
            "avg_p2p_hops_torus": size / 4 if size > 1 else 0,  # bidirectional
        }
    return out
