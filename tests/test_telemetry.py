"""Telemetry-plane tests: the streaming percentile digest, the unified
metrics registry, the step-clock flight recorder, and the engine-level
guarantees the observability PR rests on — tracing never changes the
emitted tokens, every opened span closes, the Chrome export validates,
``reset_metrics`` really zeroes the registry, and invariant violations
dump the flight recorder before raising (docs/OBSERVABILITY.md,
docs/FAULT_TOLERANCE.md).
"""
import glob
import json
import os

import numpy as np
import pytest

from conftest import dense_oracle, get_tiny_model, make_engine, \
    seeded_prompts

from repro.serving.telemetry import (HistogramDigest, MetricsRegistry,
                                     StepTracer, counter_attr,
                                     format_model_error,
                                     rollup_dispatch_events,
                                     validate_chrome_trace)


# --- HistogramDigest -------------------------------------------------------
def test_digest_exact_regime_matches_numpy_percentile():
    rng = np.random.default_rng(0)
    vals = rng.exponential(5.0, size=500)
    d = HistogramDigest.of(vals)
    assert d.exact
    for q in (0, 25, 50, 90, 95, 99, 100):
        assert d.percentile(q) == float(np.percentile(vals, q))
    assert d.count == 500
    assert d.mean == pytest.approx(float(np.mean(vals)))
    assert d.vmin == float(np.min(vals))
    assert d.vmax == float(np.max(vals))


def test_digest_spill_stays_within_relative_error():
    rng = np.random.default_rng(1)
    vals = rng.lognormal(0.0, 2.0, size=20_000)
    d = HistogramDigest.of(vals, exact_max=1024, rel_err=0.01)
    assert not d.exact          # spilled into log buckets
    for q in (50, 95, 99):
        true = float(np.percentile(vals, q))
        # DDSketch guarantee: the representative is within rel_err of
        # the true sample; nearest-rank vs interpolation adds at most
        # one bucket of slack on a 20k sample
        assert d.percentile(q) == pytest.approx(true, rel=0.03)
    assert d.count == 20_000


def test_digest_empty_and_reset():
    d = HistogramDigest()
    assert d.percentile(99) == 0.0 and d.mean == 0.0 and d.count == 0
    d.observe_many([1.0, 2.0, 3.0])
    assert d.count == 3
    d.reset()
    assert d.count == 0 and d.percentile(50) == 0.0 and d.exact


def test_digest_handles_nonpositive_values_after_spill():
    d = HistogramDigest(exact_max=4)
    d.observe_many([0.0, 0.0, 0.0, 5.0, 5.0, 5.0, 5.0])  # forces spill
    assert not d.exact
    assert d.percentile(10) == 0.0          # underflow bucket
    assert d.percentile(99) == pytest.approx(5.0, rel=0.03)


def test_digest_snapshot_schema():
    snap = HistogramDigest.of([1.0, 2.0, 4.0]).snapshot()
    assert set(snap) == {"count", "mean", "min", "max",
                         "p50", "p95", "p99"}
    assert snap["count"] == 3 and snap["min"] == 1.0 and snap["max"] == 4.0


# --- MetricsRegistry -------------------------------------------------------
def test_registry_counters_gauges_hists_snapshot_reset():
    r = MetricsRegistry()
    r.inc("steps")
    r.inc("steps", 4)
    r.set_counter("tokens", 12)
    r.set_gauge("load", 0.5)
    r.register_gauge("pool", lambda: 7)
    r.observe("lat", 3.0)
    r.observe("lat", 9.0)
    snap = r.snapshot()
    assert snap["counters"] == {"steps": 5, "tokens": 12}
    assert snap["gauges"] == {"load": 0.5, "pool": 7}
    assert snap["histograms"]["lat"]["count"] == 2
    assert r.percentile("lat", 50) == pytest.approx(6.0)
    assert r.percentile("missing", 99, default=-1.0) == -1.0

    r.reset()
    snap = r.snapshot()
    # keys survive a reset (dashboards keep their columns); stored
    # values zero; gauge CALLABLES are wiring, not state — untouched
    assert snap["counters"] == {"steps": 0, "tokens": 0}
    assert snap["gauges"] == {"load": 0.0, "pool": 7}
    assert snap["histograms"]["lat"]["count"] == 0


def test_counter_attr_descriptor_reads_and_writes_registry():
    class Thing:
        hits = counter_attr()
        renamed = counter_attr("external_name")

        def __init__(self):
            self.registry = MetricsRegistry()
            self.hits = 0
            self.renamed = 0

    t = Thing()
    t.hits += 3
    t.renamed = 9
    assert t.hits == 3 and t.renamed == 9
    assert t.registry.counters == {"hits": 3, "external_name": 9}
    t.registry.reset()
    assert t.hits == 0 and t.renamed == 0


# --- StepTracer ------------------------------------------------------------
def test_tracer_ring_evicts_oldest_and_counts_drops():
    tr = StepTracer(capacity=8)
    for i in range(20):
        with tr.dispatch("scan", i):
            pass
    assert tr.recorded == 20 and tr.dropped == 12 and len(tr.spans) == 8
    # FIFO eviction: the ring holds exactly the newest 8, in order
    assert [s.start_step for s in tr.spans] == list(range(12, 20))


def test_tracer_lifecycle_spans_close_and_never_overlap():
    tr = StepTracer()
    for rid in ("a", "b"):
        tr.request_event(rid, "queued", 0, tenant="t1")
    tr.request_event("a", "prefilling", 2, tenant="t1")
    tr.request_event("a", "running", 3, tenant="t1")
    tr.request_event("b", "prefilling", 4, tenant="t1")
    tr.request_event("a", "finished", 6, tenant="t1")
    assert set(tr.open_spans) == {"b"}      # b still mid-flight
    tr.finalize(7)
    assert not tr.open_spans                # every opened span closed
    lanes = {}
    for s in tr.spans:
        lanes.setdefault((s.group, s.track), []).append(s)
    for spans in lanes.values():
        spans.sort(key=lambda s: s.t0)
        for prev, cur in zip(spans, spans[1:]):
            assert cur.t0 >= prev.t1        # no overlap on a lane
    states = [s.name for s in lanes[("tenant:t1", "a")]]
    assert states == ["queued", "prefilling", "running", "finished"]


def test_tracer_chrome_export_is_schema_valid():
    tr = StepTracer()
    tr.request_event("r0", "queued", 0)
    with tr.dispatch("prefill", 1, predicted_s=1e-3, predicted_j=0.5):
        pass
    tr.request_event("r0", "finished", 2)
    tr.counter_sample(2, [3, 1])
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc) == []
    # round-trips through JSON (what write_chrome ships to Perfetto)
    assert validate_chrome_trace(json.loads(json.dumps(doc))) == []
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert phs == {"X", "M", "C"}


def test_validate_chrome_trace_flags_malformed_docs():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "Q"}]}) != []
    assert validate_chrome_trace(
        {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1,
                          "ts": 0.0, "dur": -1.0, "args": {}}]}) != []


def test_rollup_and_format_model_error():
    tr = StepTracer()
    for step in range(3):
        with tr.dispatch("scan", step, predicted_s=0.5, predicted_j=2.0):
            pass
    report = tr.model_error_report()
    assert set(report) == {"scan"}
    row = report["scan"]
    assert row["count"] == 3
    assert row["predicted_s"] == pytest.approx(1.5)
    assert row["predicted_j"] == pytest.approx(6.0)
    assert row["measured_s"] > 0.0
    assert row["err_ratio"] == pytest.approx(
        row["measured_s"] / row["predicted_s"])
    table = format_model_error(report)
    assert "scan" in table and "meas/pred" in table
    # chrome events feed the same rollup (the offline report tool path)
    via_chrome = rollup_dispatch_events(tr.chrome_trace()["traceEvents"])
    assert via_chrome["scan"]["count"] == 3


def test_flight_dump_contents(tmp_path):
    tr = StepTracer(capacity=4, dump_dir=str(tmp_path))
    for i in range(6):
        with tr.dispatch("scan", i):
            pass
    tr.request_event("r0", "queued", 6)
    reg = MetricsRegistry()
    reg.inc("steps", 6)
    path = tr.flight_dump("test-reason", registry=reg)
    doc = json.load(open(path))
    assert doc["reason"] == "test-reason"
    assert len(doc["spans"]) == 4 and doc["spans_dropped"] == 2
    assert [s["name"] for s in doc["open_spans"]] == ["queued"]
    assert doc["metrics"]["counters"]["steps"] == 6


# --- engine integration ----------------------------------------------------
GEN = 6


def _run_traced(**kw):
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params, trace=True, **kw)
    prompts = seeded_prompts(cfg, 4, 8)
    for i, p in enumerate(prompts):
        eng.submit(p, GEN, rid=f"r{i}")
    fin = eng.run()
    return eng, {r.rid: list(r.tokens) for r in fin}


def test_tracing_does_not_change_tokens():
    cfg, params = get_tiny_model()
    prompts = seeded_prompts(cfg, 4, 8)
    eng_off = make_engine(cfg, params)
    for i, p in enumerate(prompts):
        eng_off.submit(p, GEN, rid=f"r{i}")
    off = {r.rid: list(r.tokens) for r in eng_off.run()}
    eng_on, on = _run_traced()
    assert on == off
    assert on == dense_oracle(cfg, params, prompts, GEN, 32)


def test_engine_trace_reconstructs_lifecycle_and_attribution():
    eng, _ = _run_traced()
    eng.tracer.finalize(eng.sched.step_idx)
    doc = eng.tracer.chrome_trace()
    assert validate_chrome_trace(doc) == []
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    states = {e["name"] for e in spans if e["cat"] in ("request",
                                                       "marker")}
    assert {"queued", "prefilling", "running", "finished"} <= states
    dispatch = [e for e in spans if e["cat"] == "dispatch"]
    assert {e["name"] for e in dispatch} >= {"prefill", "scan"}
    for e in dispatch:
        assert e["args"]["predicted_s"] > 0.0
        assert e["args"]["predicted_j"] > 0.0
        assert e["args"]["measured_s"] >= 0.0
    # per-node occupancy counter track rode along
    assert any(e.get("ph") == "C" for e in doc["traceEvents"])
    report = eng.tracer.model_error_report()
    assert {"prefill", "scan"} <= set(report)


def test_reset_metrics_zeroes_registry_digests_and_tracer():
    eng, _ = _run_traced()
    assert eng.steps_run > 0 and eng.tokens_emitted > 0
    assert eng.tracer.recorded > 0
    assert any(eng.registry.counters.values())
    eng.registry.observe("recovery_steps", 5.0)
    eng.reset_metrics()
    assert all(v == 0 for v in eng.registry.counters.values())
    assert eng.steps_run == 0 and eng.tokens_emitted == 0
    assert eng.tracer.recorded == 0 and not eng.tracer.spans
    assert not eng.tracer.open_spans
    # digests drained too: warmup traffic never pollutes chaos/SLO
    # percentiles (the PR-9 regression this test pins)
    assert eng.registry.hists["recovery_steps"].count == 0
    assert eng.metrics()["recovery_steps_p99"] == 0.0
    # live gauge callables keep reporting pool truth through a reset
    assert eng.registry.gauge("free_pages") > 0


def test_quarantine_invariant_dumps_flight_recorder(tmp_path):
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params, n_nodes=2, trace=True)
    eng.tracer.dump_dir = str(tmp_path)
    eng.submit(seeded_prompts(cfg, 1, 8)[0], GEN, rid="victim")
    eng.step()                                  # prefill: victim holds pages
    held = next(iter(eng.alloc.held["victim"]))
    eng.alloc.quarantined.add(held)             # corrupt: fake a stale page
    with pytest.raises(RuntimeError, match="quarantined"):
        eng._assert_no_quarantined()
    assert eng.quarantined_served == 1
    dumps = glob.glob(os.path.join(str(tmp_path), "flight-*.json"))
    assert len(dumps) == 1
    doc = json.load(open(dumps[0]))
    assert doc["reason"] == "quarantined-served"
    assert doc["spans"]                          # history rode along
    assert doc["metrics"]["counters"]["quarantined_served"] == 1


def test_untraced_engine_pays_no_tracer_and_skips_dump():
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params)
    assert eng.tracer is None
    assert eng._flight_dump("whatever") is None  # no dump, no crash
    # the _span fast path returns the shared null context: predfn (the
    # cost-engine pricing lambda) must never run when tracing is off
    ctx = eng._span("scan", lambda: 1 / 0)
    with ctx:
        pass


def test_registry_snapshot_is_json_serializable():
    eng, _ = _run_traced()
    snap = eng.registry.snapshot()
    rt = json.loads(json.dumps(snap))
    assert rt["counters"]["steps_run"] == eng.steps_run
    assert "pages_in_use" in rt["gauges"]
