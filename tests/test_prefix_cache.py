"""Prefix-sharing subsystem: allocator refcount invariants, radix-tree
match/insert/evict semantics, COW correctness, and the acceptance gate —
greedy tokens bit-identical with the cache on or off (including forced
COW divergence inside a partially filled page, preemption, and LRU
eviction under page pressure)."""
import jax
import numpy as np
import pytest

from conftest import get_tiny_model, make_engine
from repro.serving import (ContinuousBatchScheduler, PageAllocator,
                           PagedEngine, PrefixCache, Request)


# --- allocator: refcount invariants -------------------------------------------
def test_refcount_double_free_rejected():
    a = PageAllocator(n_pages=8, page_size=4, n_nodes=1)
    [p] = a.alloc("r", 1)
    assert a.release_page(p) is True          # refcount 1 -> 0: freed
    with pytest.raises(ValueError):
        a.release_page(p)                     # double free
    with pytest.raises(ValueError):
        a.share(p)                            # unallocated: cannot share
    with pytest.raises(ValueError):
        a.share(0)                            # the null page is never shared


def test_shared_pages_survive_owner_free():
    a = PageAllocator(n_pages=8, page_size=4, n_nodes=1)
    pages = a.alloc("owner", 3)
    a.share(pages[0])                         # a second holder (cache node)
    freed = a.free("owner")
    assert freed == 2                         # the shared page survived
    assert a.refcount_of(pages[0]) == 1
    assert a.pages_in_use == 1
    assert a.release_page(pages[0]) is True   # last reference frees it
    assert a.free_pages == 7


def test_occupancy_counts_shared_pages_once():
    a = PageAllocator(n_pages=9, page_size=4, n_nodes=2)
    pages = a.alloc("r0", 4)
    for p in pages[:2]:
        a.share(p)
    a.alloc("r1", 2, prefix=pages[:2])        # r1 = 2 shared + 2 fresh
    # 6 distinct physical pages despite 8 held references
    assert sum(len(v) for v in a.held.values()) == 8
    assert a.pages_in_use == 6
    assert sum(a.occupancy_by_node()) == 6
    assert a.check_conservation()


def test_conservation_invariant_with_refcounts():
    a = PageAllocator(n_pages=12, page_size=4, n_nodes=3)
    assert a.check_conservation()
    pages = a.alloc("r0", 5)
    a.share(pages[0]); a.share(pages[0]); a.share(pages[3])
    a.alloc("r1", 1, prefix=[pages[0]])
    assert a.check_conservation()
    a.free("r0")
    assert a.check_conservation()
    assert a.refcount_of(pages[0]) == 2       # r1 + the extra share
    a.free("r1")
    assert a.check_conservation()
    a.release_page(pages[0]); a.release_page(pages[3])
    assert a.check_conservation()
    assert a.free_pages == 11 and a.pages_in_use == 0


def test_alloc_prefix_stripes_fresh_pages_after_shared_run():
    a = PageAllocator(n_pages=17, page_size=4, n_nodes=4)
    shared = a.alloc("donor", 2)
    for p in shared:
        a.share(p)
    pages = a.alloc("r", 3, prefix=shared)
    # fresh logical pages 2,3,4 land on nodes 2,3,0 (the address%n rule
    # continues through the shared prefix)
    assert [a.owner(p) for p in pages[2:]] == [2, 3, 0]


# --- radix tree: match / insert / COW / evict ---------------------------------
def _cache(n_pages=32, ps=4, n_nodes=1):
    a = PageAllocator(n_pages=n_pages, page_size=ps, n_nodes=n_nodes)
    return a, PrefixCache(a)


def _seed(a, c, rid, tokens, donate=True):
    """Insert a sequence the way the engine+scheduler would: alloc pages,
    graft, free the owner's references."""
    pages = a.alloc(rid, a.pages_for(len(tokens)))
    c.insert(tokens, pages, donate_partial=donate)
    a.free(rid)
    return pages


def test_radix_insert_match_full_and_partial():
    a, c = _cache()
    toks = tuple(range(100, 110))             # 2 full pages + 2-token tail
    pages = _seed(a, c, "r0", toks)
    assert c.n_nodes == 3                     # partial tail donated too
    assert a.pages_in_use == 3                # tree owns them post-free
    # full-page-aligned prefix of a longer prompt
    assert c.peek(toks + (1, 2, 3)) == 10
    # cap: at least one token must run through the model
    assert c.peek(toks) == 9
    m = c.acquire(toks + (1, 2, 3))
    assert m.length == 10 and len(m.pages) == 2
    assert m.cow_src == pages[2]              # partial tail: COW to extend
    c.release_match(m)
    assert a.check_conservation()


def test_radix_match_diverges_inside_full_page():
    a, c = _cache()
    toks = tuple(range(50, 58))               # exactly 2 full pages
    pages = _seed(a, c, "r0", toks, donate=False)
    probe = toks[:6] + (999, 998, 997)        # diverges at slot 2 of page 1
    assert c.peek(probe) == 6
    m = c.acquire(probe)
    assert m.length == 6
    assert m.pages == [pages[0]] and m.cow_src == pages[1]
    c.release_match(m)


def test_radix_miss_and_no_partial_insert_without_donation():
    a, c = _cache()
    _seed(a, c, "r0", tuple(range(10)), donate=False)
    assert c.n_nodes == 2                     # 8 full tokens only
    assert c.peek((1, 2, 3, 4)) == 0
    m = c.acquire((7, 7, 7, 7, 7))
    assert not m.hit and m.pages == [] and m.cow_src is None


def test_locked_nodes_are_not_evictable():
    a, c = _cache(n_pages=8)
    toks = tuple(range(8))
    _seed(a, c, "r0", toks)
    m = c.acquire(toks + (1, 2))              # locks both pages
    assert c.evict(10) == 0                   # users > 0: nothing evictable
    c.release_match(m)
    assert c.evict(10) == 2 and c.n_nodes == 0
    assert a.check_conservation() and a.pages_in_use == 0


def test_eviction_is_lru_and_leaf_first():
    a, c = _cache(n_pages=32)
    old = tuple(range(200, 208))
    new = tuple(range(300, 308))
    _seed(a, c, "old", old, donate=False)
    _seed(a, c, "new", new, donate=False)
    c.peek(new)                               # peek does NOT touch LRU
    c.acquire(old + (1,)) and None            # touches 'old'
    # release the acquire's references so both branches are evictable
    for node in list(c._nodes.values()):
        while c.users_of(node) > 0:
            a.release_page(node.page)
    freed = c.evict(2)
    assert freed == 2
    # 'old' was touched last: the 'new' branch went first (leaf then root)
    assert c.peek(old + (1,)) == 8 and c.peek(new + (1,)) == 0


def test_reclaim_hook_evicts_cache_before_alloc_fails():
    a, c = _cache(n_pages=6)
    a.reclaim = c.evict
    _seed(a, c, "r0", tuple(range(12)))       # tree owns 3 pages
    assert a.free_pages == 2
    pages = a.alloc("r1", 5)                  # needs eviction to fit
    assert pages is not None
    assert c.stats.evictions >= 1
    assert a.check_conservation()


# --- scheduler: pricing on uncached tokens only -------------------------------
def test_admission_priced_on_uncached_tokens_only():
    a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
    c = PrefixCache(a)
    _seed(a, c, "warm", tuple(range(16)), donate=False)
    costs = []

    def priced(n):
        costs.append(n)
        return float(n)

    s = ContinuousBatchScheduler(a, max_batch=2, prefill_cost_s=priced,
                                 decode_cost_s=1.0, prefill_budget=6.0,
                                 prefix_cache=c)
    # 16 cached of 20 -> uncached 4 <= budget 6; a cold 20-token prompt
    # busts the same budget
    s.submit(Request(rid="hot", prompt_len=20, gen=2,
                     prompt_key=tuple(range(16)) + (901, 902, 903, 904)))
    plan = s.plan_step()
    assert [r.rid for r in plan.admitted] == ["hot"]
    assert plan.admitted[0].cached_tokens == 16
    assert costs[0] == 4                      # priced on uncached only
    s.note_first_token(plan.admitted[0], 1)
    s.submit(Request(rid="cold", prompt_len=20, gen=2,
                     prompt_key=tuple(range(800, 820))))
    plan = s.plan_step()
    assert plan.admitted == []                # 20 uncached > budget 6


def test_shared_pages_survive_preemption_of_owner():
    a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
    c = PrefixCache(a)
    _seed(a, c, "warm", tuple(range(8)), donate=False)
    s = ContinuousBatchScheduler(a, max_batch=2, prefix_cache=c)
    key = tuple(range(8)) + (700, 701)
    s.submit(Request(rid="u", prompt_len=10, gen=4, prompt_key=key))
    plan = s.plan_step()
    req = plan.admitted[0]
    assert req.cached_tokens == 8
    shared = a.held["u"][:2]
    s.note_first_token(req, 1)
    plan2 = type(plan)()
    s._preempt(req, plan2)                    # victim drops its references
    assert all(a.refcount_of(p) == 1 for p in shared)   # tree's survive
    assert c.peek(key) == 8                   # still cached


def test_preempt_before_first_token_releases_cow_reference():
    """Engine-less flows can preempt between admission and first token;
    the temporary COW-source reference from acquire() must be dropped or
    the node leaks as permanently unevictable."""
    a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
    c = PrefixCache(a)
    _seed(a, c, "warm", tuple(range(8)), donate=False)
    s = ContinuousBatchScheduler(a, max_batch=2, prefix_cache=c)
    key = tuple(range(6)) + (700, 701, 702, 703)   # mid-page match -> COW
    s.submit(Request(rid="u", prompt_len=10, gen=4, prompt_key=key))
    plan = s.plan_step()
    req = plan.admitted[0]
    assert req.prefix_match is not None and req.prefix_match.cow_src is not None
    cow = req.prefix_match.cow_src
    assert a.refcount_of(cow) == 2                 # tree + temp COW ref
    s._preempt(req, type(plan)())                  # before note_first_token
    assert a.refcount_of(cow) == 1                 # temp ref released
    assert c.evict(10) >= 1                        # node evictable again


# --- engine acceptance gate: cache on == cache off, bit for bit ---------------
def _run_engine(prompts, gens, *, cache, n_pages, max_batch=3, page_size=4,
                max_len=None, budget=2.0, fused=True):
    cfg, params = get_tiny_model()
    max_len = max_len or max(p.shape[0] + g for p, g in zip(prompts, gens))
    eng = make_engine(cfg, params, max_batch=max_batch, page_size=page_size,
                      n_pages=n_pages, max_len=max_len, fused=fused,
                      prefill_budget=budget, prefix_cache=cache)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(p, g, rid=f"r{i}")
    fin = eng.run()
    return eng, {r.rid: list(r.tokens) for r in fin}


def _shared_prefix_prompts(n, total=14, shared=10, seed=0):
    """n prompts sharing a ``shared``-token prefix that is NOT page
    aligned (page_size=4): divergence lands inside a page -> forced COW."""
    cfg, _ = get_tiny_model()
    base = np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (shared,),
                                         2, cfg.vocab_size), np.int32)
    out = []
    for i in range(n):
        tail = np.asarray(jax.random.randint(jax.random.PRNGKey(seed + 50 + i),
                                             (total - shared,), 2,
                                             cfg.vocab_size), np.int32)
        out.append(np.concatenate([base, tail]))
    return out


def test_engine_tokens_identical_with_forced_cow():
    prompts = _shared_prefix_prompts(4)
    gens = [5, 4, 6, 3]
    eng_off, toks_off = _run_engine(prompts, gens, cache=False, n_pages=48)
    eng_on, toks_on = _run_engine(prompts, gens, cache=True, n_pages=48)
    assert toks_on == toks_off
    m = eng_on.metrics()
    assert m["prefix_hits"] == 3              # all but the first
    assert m["cow_copies"] >= 3               # divergence is mid-page
    assert m["prefill_tokens_cached"] > 0
    assert m["prefill_tokens"] < eng_off.metrics()["prefill_tokens"]
    assert m["bytes_deduped"] > 0
    assert eng_on.alloc.check_conservation()


def test_engine_cache_hits_donated_partial_tail():
    """A follow-up prompt that extends a finished request's sequence
    (prompt + its generated tokens) hits the donated pages, including a
    COW off the partially filled tail page."""
    cfg, params = get_tiny_model()
    S, gen = 9, 5
    p0 = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (S,), 2,
                                       cfg.vocab_size), np.int32)
    eng = PagedEngine(cfg, params, max_batch=2, page_size=4, n_pages=48,
                      max_len=24, prefix_cache=True)
    eng.submit(p0, gen, rid="a")
    fin = eng.run()
    a_tokens = list(fin[0].tokens)
    # prompt + all-but-last generated token are cached (the last token's
    # KV was never written); extend past the donated tail and diverge
    p1 = np.concatenate([p0, np.asarray(a_tokens[:-1], np.int32),
                         np.asarray([5, 7, 11], np.int32)])
    eng.submit(p1, 3, rid="b")
    fin2 = eng.run()
    b_on = {r.rid: list(r.tokens) for r in fin2}["b"]
    m = eng.metrics()
    assert m["prefix_hits"] >= 1
    assert m["prefill_tokens_cached"] >= S + gen - 1
    # oracle: same request, cache off
    eng_off, toks_off = _run_engine([p1], [3], cache=False, n_pages=48,
                                    max_batch=2, max_len=24)
    assert b_on == toks_off["r0"]


def test_engine_tokens_identical_under_preemption_and_eviction():
    """Tight pool: page pressure drives tenant preemption (cache off)
    and LRU cache eviction (cache on, distinct prompts bloat the tree) —
    tokens still match the cache-off run exactly."""
    cfg, _ = get_tiny_model()
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(70 + i),
                                             (12,), 2, cfg.vocab_size),
               np.int32) for i in range(6)]
    gens = [6] * 6
    eng_off, toks_off = _run_engine(prompts, gens, cache=False, n_pages=14,
                                    budget=0.0)
    eng_on, toks_on = _run_engine(prompts, gens, cache=True, n_pages=14,
                                  budget=0.0)
    assert toks_on == toks_off
    m = eng_on.metrics()
    assert m["prefix_evictions"] >= 1
    assert eng_off.metrics()["preemptions"] >= 1
    assert eng_on.alloc.check_conservation()


def test_engine_preempted_request_recomputes_exactly_through_cache():
    """A preempted request re-admitted with the cache ON re-matches its
    own donated/inserted pages and recomputes through the suffix path —
    tokens still bit-identical to the cache-off run (preemptions >= 1 on
    both sides is part of the pin)."""
    prompts = _shared_prefix_prompts(6, total=12, shared=9, seed=7)
    gens = [8] * 6
    eng_off, toks_off = _run_engine(prompts, gens, cache=False, n_pages=14,
                                    budget=0.0)
    eng_on, toks_on = _run_engine(prompts, gens, cache=True, n_pages=14,
                                  budget=0.0)
    assert toks_on == toks_off
    assert eng_on.metrics()["preemptions"] >= 1
    assert eng_off.metrics()["preemptions"] >= 1
    assert eng_on.metrics()["prefix_hits"] >= 1
    assert eng_on.alloc.check_conservation()


def test_engine_cache_off_by_default_and_metrics_gated():
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params, max_batch=2, page_size=4, n_pages=16,
                      max_len=16)
    assert eng.cache is None
    assert "prefix_hit_rate" not in eng.metrics()
