"""Swallow §V applied to the model-dispatch "interconnect": weightless
n-gram speculative decoding for the paged serving engine.

The paper's throughput argument is about the communication-to-computation
ratio: a fixed per-message overhead is amortized by making every message
carry more useful payload.  PR 3 applied that to host<->device syncs
(O(1)/window); this module applies it to *model dispatches per emitted
token* — the remaining per-token fixed cost.  A decode step is one model
pass for one token; speculative decoding turns it into one model pass
for up to K+1 tokens: a draft of K tokens is *proposed for free* (no
model, no weights — pure host-side string matching) and *verified in one
batched dispatch* (:func:`repro.models.lm.verify_window_paged`, the same
``apply_prefill_paged`` arithmetic as the prefix-cache suffix path), so
the accepted prefix plus the verifier's own bonus/correction token all
land from a single pass.

Drafting is prompt-lookup (n-gram) speculation: match the last ``n``
tokens of the sequence's own prompt+output history against an earlier
occurrence in that same history, and propose the tokens that followed
it.  Repetitive text — templated output, code, retrieval-heavy prompts,
or the fixed-point loops greedy decode falls into — drafts almost
perfectly; adversarial text drafts nothing and the engine degrades to
the plain fused-window path.  Either way the *emitted* tokens are
bit-identical to non-speculative greedy decode, because acceptance only
keeps drafts that equal the verifier's greedy argmax and the first
mismatch is replaced by that argmax (pinned by
tests/test_spec_decode.py across prefix-cache hits, preemption and
fused windows).

Pure host-side logic: no jax imports.  The verify dispatch and the
page rollback (:meth:`repro.serving.paged_kv.PageAllocator.truncate_to`)
live in :mod:`repro.serving.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence


def propose_ngram(history: Sequence[int], k: int, *, max_n: int = 3,
                  min_n: int = 1) -> List[int]:
    """Prompt-lookup drafting: find the *earliest* earlier occurrence of
    the history's last ``n`` tokens (longest ``n`` first, ``max_n`` down
    to ``min_n``) and propose up to ``k`` tokens that followed it.
    Earliest — not most recent — because the match nearest the end has
    the least history left after it: on a looping sequence the latest
    occurrence only ever yields a 1-token draft, while the earliest
    yields the whole period.

    Returns [] when nothing matches — the caller falls back to plain
    decode.  O(n * len(history)) per candidate ``n``; histories are
    bounded by the engine's ``max_len``, so this stays microseconds-cheap
    next to a model dispatch.
    """
    L = len(history)
    if k < 1 or L < min_n + 1:
        return []
    hist = [int(t) for t in history]
    for n in range(min(max_n, L - 1), min_n - 1, -1):
        pattern = hist[L - n:]
        for i in range(L - n):
            if hist[i:i + n] == pattern:
                return hist[i + n:i + n + k]
    return []


@dataclass
class SpecStats:
    """Acceptance accounting for the engine's ``accept_rate`` /
    ``dispatches_per_token`` observables."""
    drafted: int = 0       # draft tokens proposed to the verifier
    accepted: int = 0      # draft tokens the verifier kept
    verifies: int = 0      # verification dispatches run
    rollbacks: int = 0     # verifies that released rejected pages

    @property
    def accept_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)


class NGramSpec:
    """Per-engine speculative-decoding policy: draft depth, n-gram
    bounds, and acceptance stats.  Weightless — the proposer never
    touches model state, only the request's token history."""

    def __init__(self, k: int = 8, max_n: int = 3, min_n: int = 1):
        assert k >= 1 and max_n >= min_n >= 1
        self.k = k
        self.max_n = max_n
        self.min_n = min_n
        self.stats = SpecStats()

    def propose(self, prompt: Sequence[int], tokens: Sequence[int],
                k_cap: int) -> List[int]:
        """Draft up to ``min(self.k, k_cap)`` tokens from the sequence's
        own prompt+output history."""
        k = min(self.k, k_cap)
        if k < 1:
            return []
        history = [int(t) for t in prompt] + [int(t) for t in tokens]
        return propose_ngram(history, k, max_n=self.max_n,
                             min_n=self.min_n)

    def accept(self, draft: Sequence[int],
               greedy: Sequence[int]) -> List[int]:
        """Greedy acceptance rule: keep the longest draft prefix that
        matches the verifier's argmax at each position, then append the
        verifier's own token at the first mismatch (or the bonus token
        when everything matched).  The result is therefore *exactly*
        the token sequence non-speculative greedy decode would emit —
        speculation changes dispatch count, never tokens."""
        a = 0
        while a < len(draft) and int(greedy[a]) == int(draft[a]):
            a += 1
        emitted = [int(t) for t in draft[:a]] + [int(greedy[a])]
        self.stats.drafted += len(draft)
        self.stats.accepted += a
        self.stats.verifies += 1
        return emitted
