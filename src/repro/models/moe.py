"""Mixture-of-Experts FFN with explicit shard_map communication.

Baseline strategy: **expert tensor parallelism** ("etp") — experts are
unsharded (works for any expert count: grok has 8 experts, deepseek 256),
the per-expert FFN hidden dim is sharded over the "model" mesh axis, and
tokens never move between data shards.  The residual stream arrives
sequence-sharded over "model" (Megatron-SP), is all-gathered inside the
shard_map region, dispatched locally (sort + fixed capacity), pushed
through a group-scanned grouped-GEMM, and the partial outputs are
reduce-scattered back to the sequence-sharded layout.  This is the
Swallow design rule made literal: every byte communicated is an explicit
collective in the program text.

The alternative "ep" strategy (experts striped over "model" — the paper's
address%n striping applied to the expert table) is selected by overriding
the logical axis rules {"expert": "model", "expert_ff": None}; the local
dispatch math is identical.  Evaluated in the perf hillclimb.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.parallel.sharding import current_env

from repro.parallel.sharding import compat_shard_map as _shard_map


def init(key, cfg, dtype):
    m = cfg.moe
    d, fe = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router_w": nn.dense_init(ks[0], d, m.n_experts, jnp.float32),
        "e_up": _expert_init(ks[1], m.n_experts, d, fe, dtype),
        "e_down": _expert_init(ks[2], m.n_experts, fe, d, dtype,
                               scale=1.0 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.gated_ffn:
        p["e_gate"] = _expert_init(ks[3], m.n_experts, d, fe, dtype)
    return p


def _expert_init(key, e, d_in, d_out, dtype, scale: float = 1.0):
    std = scale * (d_in ** -0.5)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * std
            ).astype(dtype)


# ---------------------------------------------------------------------------
# routing + dispatch (runs per-shard; pure local math)
# ---------------------------------------------------------------------------
def route(cfg, router_w, tokens):
    """tokens (T, D) -> (weights (T,k), ids (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if m.score_func == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(scores, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    f = jnp.zeros((m.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / (ids.size)
    p_mean = probs.mean(0)
    aux = m.n_experts * jnp.sum(f * p_mean)
    return w, ids, aux


def capacity(cfg, n_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def dispatch_indices(ids, n_tokens: int, top_k: int, E: int, C: int):
    """Sort token->expert assignments into fixed-capacity slots.

    Returns slot_tok (E*C,) int32 token row per slot (sentinel n_tokens for
    empty), and slot (T*k,) destination slot per assignment (E*C = dropped).
    """
    flat_e = ids.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(flat_e.size, dtype=jnp.int32) - first[sorted_e]
    slot_of_sorted = jnp.where(pos_in_e < C, sorted_e * C + pos_in_e, E * C)
    slot = jnp.zeros((flat_e.size,), jnp.int32).at[order].set(slot_of_sorted)
    tok_ids = jnp.arange(flat_e.size, dtype=jnp.int32) // top_k
    slot_tok = jnp.full((E * C,), n_tokens, jnp.int32).at[slot].set(
        tok_ids, mode="drop")
    return slot_tok, slot


def _group_count(E: int, C: int, D: int, budget_bytes: int = 1 << 27) -> int:
    """Experts per scan step sized so gathered activations stay ~<=128MB."""
    per_expert = C * D * 4
    eg = max(1, min(E, budget_bytes // max(per_expert, 1)))
    while E % eg:
        eg -= 1
    return E // eg


def local_moe(cfg, tokens, router_w, e_gate, e_up, e_down):
    """Dense-math MoE on local tokens. tokens (T, D) -> (out (T, D), aux).

    e_* weights may be sharded on the ffn dim (expert-TP): the result is
    then a partial sum the caller must psum/reduce-scatter.
    """
    m = cfg.moe
    T, D = tokens.shape
    E = m.n_experts
    C = capacity(cfg, T)
    act = nn.activation(cfg.act)

    w, ids, aux = route(cfg, router_w, tokens)
    slot_tok, slot = dispatch_indices(ids, T, m.top_k, E, C)
    slot_w = jnp.zeros((E * C,), tokens.dtype).at[slot].set(
        w.reshape(-1).astype(tokens.dtype), mode="drop")

    x_pad = jnp.concatenate([tokens, jnp.zeros((1, D), tokens.dtype)], 0)
    n_g = _group_count(E, C, D)
    eg = E // n_g
    slot_tok_g = slot_tok.reshape(n_g, eg * C)
    slot_w_g = slot_w.reshape(n_g, eg * C)

    def group_step(out_acc, inputs):
        gi, st, sw = inputs
        xg = x_pad[st].reshape(eg, C, D)
        wg_up = jax.lax.dynamic_slice_in_dim(e_up, gi * eg, eg, axis=0)
        wg_dn = jax.lax.dynamic_slice_in_dim(e_down, gi * eg, eg, axis=0)
        up = jnp.einsum("ecd,edf->ecf", xg, wg_up,
                        preferred_element_type=jnp.float32)
        if e_gate is not None:
            wg_gt = jax.lax.dynamic_slice_in_dim(e_gate, gi * eg, eg, axis=0)
            gt = jnp.einsum("ecd,edf->ecf", xg, wg_gt,
                            preferred_element_type=jnp.float32)
            h = act(gt) * up
        else:
            h = act(up)
        y = jnp.einsum("ecf,efd->ecd", h.astype(tokens.dtype), wg_dn,
                       preferred_element_type=jnp.float32)
        y = (y.reshape(eg * C, D) * sw[:, None]).astype(jnp.float32)
        out_acc = out_acc.at[st].add(y, mode="drop")
        return out_acc, None

    out0 = jnp.zeros((T + 1, D), jnp.float32)
    out, _ = jax.lax.scan(group_step, out0,
                          (jnp.arange(n_g), slot_tok_g, slot_w_g))
    return out[:T].astype(tokens.dtype), aux


# ---------------------------------------------------------------------------
# sharded entry point
# ---------------------------------------------------------------------------
def apply(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux loss scalar)."""
    env = current_env()
    e_gate = p.get("e_gate")
    if env is None:
        B, S, D = x.shape
        out, aux = local_moe(cfg, x.reshape(B * S, D), p["router_w"],
                             e_gate, p["e_up"], p["e_down"])
        return out.reshape(B, S, D), aux

    mesh = env.mesh
    B, S, D = x.shape
    tp = env.resolve("expert_ff")          # model axis (expert-TP) or None
    ep = env.resolve("expert")             # model axis (EP) or None
    fsdp = env.resolve("fsdp")
    batch = env.resolve("batch")
    model_size = 1
    for a in _axes_tuple(tp) + _axes_tuple(ep):
        model_size *= mesh.shape[a]
    # x arrives FULL-sequence (blocks gather after the pre-norm); the
    # output is reduce-scattered back to the seq-sharded residual layout.
    seq_shard = (S % max(model_size, 1) == 0) and model_size > 1 and S > 1
    seq_axes = (tp or ep) if seq_shard else None

    in_specs = (
        env.spec("batch", "seq_sp" if seq_shard else None, None),   # x
        env.spec("fsdp", None),                                     # router
        env.spec("expert", "fsdp", "expert_ff"),                    # gate
        env.spec("expert", "fsdp", "expert_ff"),                    # up
        env.spec("expert", "expert_ff", "fsdp"),                    # down
    )
    out_specs = (env.spec("batch", "seq_sp" if seq_shard else None, None),
                 env.spec())

    fn = partial(_sharded_moe, cfg=cfg, seq_axes=_axes_tuple(seq_axes),
                 tp_axes=_axes_tuple(tp), ep_axes=_axes_tuple(ep),
                 fsdp_axes=_axes_tuple(fsdp), batch_axes=_axes_tuple(batch))
    gate_arg = e_gate if e_gate is not None else jnp.zeros(
        (0,) + p["e_up"].shape[1:], p["e_up"].dtype)
    out, aux = _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)(
        x, p["router_w"], gate_arg, p["e_up"], p["e_down"])
    return out, aux


def _axes_tuple(a):
    if a is None:
        return ()
    return (a,) if isinstance(a, str) else tuple(a)


def _sharded_moe(x, router_w, e_gate, e_up, e_down, *, cfg, seq_axes,
                 tp_axes, ep_axes, fsdp_axes, batch_axes):
    """shard_map body: explicit AG / RS around the local MoE math."""
    # 1. gather sequence shards so each model shard sees its full tokens
    for ax in seq_axes:
        x = jax.lax.all_gather(x, ax, axis=1, tiled=True)
    # 2. gather weight FSDP shards (nodes-as-storage: fetch remote shards)
    for ax in fsdp_axes:
        router_w = jax.lax.all_gather(router_w, ax, axis=0, tiled=True)
        e_up = jax.lax.all_gather(e_up, ax, axis=1, tiled=True)
        e_down = jax.lax.all_gather(e_down, ax, axis=2, tiled=True)
        if e_gate.shape[0]:
            e_gate = jax.lax.all_gather(e_gate, ax, axis=1, tiled=True)
    gate = e_gate if e_gate.shape[0] else None

    B, S, D = x.shape
    tokens = x.reshape(B * S, D)

    if ep_axes:
        out, aux = _local_moe_ep(cfg, tokens, router_w, gate, e_up, e_down,
                                 ep_axes)
    else:
        out, aux = local_moe(cfg, tokens, router_w, gate, e_up, e_down)

    out = out.reshape(B, S, D)
    # 3. combine partial sums (expert-TP) / complete EP outputs, returning
    #    to the sequence-sharded residual layout
    comb_axes = tp_axes + ep_axes
    if seq_axes:
        for ax in comb_axes:
            out = jax.lax.psum_scatter(out, ax, scatter_dimension=1,
                                       tiled=True)
    elif comb_axes:
        out = jax.lax.psum(out, comb_axes)
    # average aux across data shards
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return out, aux


def _local_moe_ep(cfg, tokens, router_w, e_gate, e_up, e_down, ep_axes):
    """EP variant: each shard holds E_local experts; tokens routed to local
    experts only (others contribute via the later psum over ep axes)."""
    m = cfg.moe
    T, D = tokens.shape
    E_local = e_up.shape[0]
    idx = jax.lax.axis_index(ep_axes[0]) if len(ep_axes) == 1 else \
        _linear_index(ep_axes)
    e_lo = idx * E_local

    w, ids, aux = route(cfg, router_w, tokens)
    # keep only assignments owned by this shard; remap to local expert ids
    local = (ids >= e_lo) & (ids < e_lo + E_local)
    ids_l = jnp.where(local, ids - e_lo, E_local)     # E_local = drop bucket
    w_l = jnp.where(local, w, 0.0)

    C = capacity(cfg, T)  # same global capacity per expert
    slot_tok, slot = dispatch_indices(ids_l, T, m.top_k, E_local + 1, C)
    # slots belonging to the drop bucket are masked via zero weights
    slot_w = jnp.zeros(((E_local + 1) * C,), tokens.dtype).at[slot].set(
        w_l.reshape(-1).astype(tokens.dtype), mode="drop")
    slot_tok = slot_tok[: E_local * C]
    slot_w = slot_w[: E_local * C]

    x_pad = jnp.concatenate([tokens, jnp.zeros((1, D), tokens.dtype)], 0)
    act = nn.activation(cfg.act)
    xg = x_pad[slot_tok].reshape(E_local, C, D)
    up = jnp.einsum("ecd,edf->ecf", xg, e_up,
                    preferred_element_type=jnp.float32)
    if e_gate is not None:
        gt = jnp.einsum("ecd,edf->ecf", xg, e_gate,
                        preferred_element_type=jnp.float32)
        h = act(gt) * up
    else:
        h = act(up)
    y = jnp.einsum("ecf,efd->ecd", h.astype(tokens.dtype), e_down,
                   preferred_element_type=jnp.float32)
    y = (y.reshape(E_local * C, D) * slot_w[:, None]).astype(jnp.float32)
    out = jnp.zeros((T + 1, D), jnp.float32).at[slot_tok].add(y, mode="drop")
    return out[:T].astype(tokens.dtype), aux


def _linear_index(axes):
    idx = 0
    for ax in axes:
        idx = idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    return idx
