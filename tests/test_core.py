"""Swallow core modules: validation against the paper's own numbers plus
property tests (topology routing, striping, scheduler)."""
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (energy, memory_server, network, nos, overlays,
                        ratio, topology)


# --- energy (§VI-VII) -------------------------------------------------------
def test_eqn3_matches_paper():
    # Eqn 3: P = 46 + 0.30 f; paper quotes 193 mW @ 500 MHz, 65 mW @ 71 MHz
    assert abs(energy.swallow_core_power_mw(500) - 196.0) < 1e-6
    assert abs(energy.swallow_core_power_mw(500) - 193.0) < 5.0
    assert abs(energy.swallow_core_power_mw(71) - 67.3) < 3.0


def test_link_energy_table():
    t = energy.SWALLOW_LINK_PJ_PER_BIT
    assert t["on_die"] == 1.63
    # off-board ~50x the on-board energy (paper: "rises by approx 50x")
    assert 40 < t["off_board_ffc"] / t["on_board_h"] < 60


def test_dvfs_saves_over_fs_only():
    # voltage+frequency scaling must beat frequency-only at low f
    p_dvfs = energy.swallow_dvfs_power_mw(71.0)
    p_fs = energy.swallow_core_power_mw(71.0)
    assert p_dvfs < p_fs


def test_step_energy_split():
    e = energy.step_energy(flops_per_chip=1e14, hbm_bytes_per_chip=1e11,
                           ici_bytes_per_chip=1e9, step_seconds=1.0)
    assert abs(sum([e.compute_j, e.hbm_j, e.ici_j, e.static_j])
               - e.total_j) < 1e-9
    assert 0.99 < sum(e.breakdown.values()) < 1.01


# --- ratio (§II-B, Tab. III) -------------------------------------------------
def test_swallow_table_iii():
    r = ratio.swallow_ec()
    assert r.ec == 2.0 and r.EC == 32
    assert r.perf_bound() == 32


def test_cell_ratio_balanced_detection():
    # tiny traffic, big compute -> balanced
    r = ratio.analyze_cell("x", wire_bytes_per_device=1e6,
                           compute_seconds=1.0, n_chips=256,
                           mesh_shape={"data": 16, "model": 16})
    assert r.balanced and r.bound == "compute"
    # huge traffic -> communication bound
    r2 = ratio.analyze_cell("y", wire_bytes_per_device=1e13,
                            compute_seconds=0.1, n_chips=256,
                            mesh_shape={"data": 16, "model": 16})
    assert not r2.balanced


# --- topology (§V-A): the <=2 layer transitions claim ------------------------
@settings(max_examples=60, deadline=None)
@given(rows=st.integers(2, 8), cols=st.integers(2, 8),
       data=st.data())
def test_lattice_routing_properties(rows, cols, data):
    lat = topology.Lattice(rows, cols)
    nodes = list(lat.nodes())
    src = data.draw(st.sampled_from(nodes))
    dst = data.draw(st.sampled_from(nodes))
    path = lat.route(src, dst)
    assert path[0] == src and path[-1] == dst
    # every step is a physical link
    for a, b in zip(path, path[1:]):
        assert b in lat.neighbors(a), (a, b)
    # the paper's claim: at most two layer transitions... plus possibly a
    # final transition when src and dst layers both force crossings
    assert topology.Lattice.layer_transitions(path) <= 3
    # dimension-ordered: vertical moves never follow horizontal moves
    seen_h = False
    for a, b in zip(path, path[1:]):
        if a[0] == b[0] == 1 and a[2] != b[2]:
            seen_h = True
        if a[0] == b[0] == 0 and a[1] != b[1]:
            assert not seen_h


def test_lattice_two_transitions_for_core_routes():
    # the paper's exact case: two nodes on the horizontal layer without a
    # shared vertical index need exactly two transitions
    lat = topology.Lattice(4, 4)
    path = lat.route((1, 0, 0), (1, 3, 3))
    assert topology.Lattice.layer_transitions(path) == 2


def test_lattice_full_connectivity():
    lat = topology.Lattice(3, 3)
    nodes = list(lat.nodes())
    for s in nodes:
        for d in nodes:
            p = lat.route(s, d)
            assert p[0] == s and p[-1] == d


# --- network (§V-B/C) ---------------------------------------------------------
def test_link_rates_match_paper():
    # paper: 500 Mbit/s per internal link at Ts=2, Tt=1, 500 MHz
    assert abs(network.link_rate_bps() - 500e6) / 500e6 < 0.01
    # packetized ~435 Mbit/s effective ("depending on packet size")
    r = network.packet_rate_bps(32)
    assert 420e6 < r < 460e6


def test_circuit_beats_packet_small_messages():
    t_c = network.ring_collective_time(1e4, 16, mode="circuit")
    t_p = network.ring_collective_time(1e4, 16, mode="packet")
    assert t_p > t_c
    # large messages converge
    t_c = network.ring_collective_time(1e9, 16, mode="circuit")
    t_p = network.ring_collective_time(1e9, 16, mode="packet")
    assert (t_p - t_c) / t_c < 0.05


# --- memory server (§III-A / §X-B) --------------------------------------------
@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 64), addr=st.integers(0, 10 ** 6))
def test_striping_rule(n, addr):
    assert memory_server.striped_owner(addr, n) == addr % n


def test_striped_store_roundtrip():
    import jax.numpy as jnp
    st_ = memory_server.StripedStore(size=64)
    addrs = jnp.array([0, 5, 17, 63])
    vals = jnp.array([1.0, 2.0, 3.0, 4.0])
    st_.write(addrs, vals)
    got = st_.read(addrs)
    assert jnp.allclose(got, vals)
    assert float(st_.read(jnp.array([1]))[0]) == 0.0


def test_memory_per_task_fig3():
    # Fig. 3: fixed tasks + growing procs -> exponential memory per task;
    # tasks == procs -> constant 64 kB
    assert memory_server.memory_per_task(1024, 1) == 1024 * 64
    assert memory_server.memory_per_task(1024, 1024) == 64
    assert memory_server.memory_per_task(2048, 1024) == 128


# --- overlays (§III-B) ---------------------------------------------------------
def test_overlay_map_fig4():
    m = overlays.overlay_map()
    assert m["n_overlays"] == 2
    assert m["resident_kwords"] == 12   # paper: 16k -> 12k words


# --- nOS (§VIII) ---------------------------------------------------------------
def test_nos_scheduler():
    s = nos.NOS(data_rows=16)
    assert s.submit(nos.Job("a", rows_needed=8))
    assert s.submit(nos.Job("b", rows_needed=8))
    assert not s.submit(nos.Job("c", rows_needed=4))   # queued
    assert s.jobs["c"].state == "pending"
    assert s.utilisation() == 1.0
    s.finish("a")
    assert s.jobs["c"].state == "running"
    assert s.utilisation() == 0.75


def test_nos_failure_eviction():
    s = nos.NOS(data_rows=8)
    s.submit(nos.Job("a", rows_needed=4))
    evicted = s.fail_rows([0, 1])
    assert "a" in evicted
    # rows 0,1 quarantined; job re-placed on remaining rows
    assert s.jobs["a"].state == "running"
    assert not (set(s.jobs["a"].rows) & {0, 1})


def test_nos_restore_rows_inverts_failure():
    s = nos.NOS(data_rows=8)
    s.submit(nos.Job("a", rows_needed=4))
    s.submit(nos.Job("b", rows_needed=4))
    s.fail_rows([0, 1, 2, 3])
    # half the pod is dark: only one job fits the surviving rows
    states = sorted(j.state for j in s.jobs.values())
    assert states == ["pending", "running"]
    placed = s.restore_rows([0, 1, 2, 3])
    # recovery re-admits the stranded job onto the recovered capacity
    assert len(placed) == 1
    assert all(j.state == "running" for j in s.jobs.values())
    assert s._quarantined == set()
    used = [r for j in s.jobs.values() for r in j.rows]
    assert len(used) == len(set(used)) == 8


def test_nos_restore_rows_ignores_healthy_rows():
    s = nos.NOS(data_rows=8)
    s.submit(nos.Job("a", rows_needed=4))       # holds rows 0-3
    # restoring rows a running job holds must not double-free them
    assert s.restore_rows([0, 1]) == []
    assert sorted(s._free) == [4, 5, 6, 7]
    assert s.jobs["a"].state == "running"
    s.fail_rows([5])                            # idle row: nothing evicted
    assert s.restore_rows([5, 6, 7]) == []      # 6,7 never quarantined
    assert 5 in s._free and s._quarantined == set()
    assert sorted(s._free) == [4, 5, 6, 7]
    assert s.jobs["a"].rows == (0, 1, 2, 3)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(2, 32),
       sizes=st.lists(st.integers(1, 8), min_size=1, max_size=10))
def test_nos_never_overlaps(rows, sizes):
    s = nos.NOS(data_rows=rows)
    for i, n in enumerate(sizes):
        s.submit(nos.Job(f"j{i}", rows_needed=n))
    used = []
    for j in s.jobs.values():
        if j.state == "running":
            used.extend(j.rows)
    assert len(used) == len(set(used))          # no double allocation
    assert all(0 <= r < rows for r in used)
