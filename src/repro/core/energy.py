"""Swallow §VI-VII: energy transparency & proportionality, at both scales.

Paper ground truth (reproduced for validation + benchmarks):
  Eqn. 3   P/core = (46 + 0.30 f) mW       (f in MHz; static 46 mW)
  Tab. II  per-bit link energies: on-die 1.63 pJ, on-board ~101-106 pJ,
           off-board 30 cm FFC 5440 pJ
  Fig. 10  DVFS: P = C V^2 f with Vmin(71 MHz) = 0.6 V, Vmin(500) = 0.95 V
  §VII-A   480 cores: 193 mW/core active, 134 W system, ~26% conversion
           losses, 30% compute, 40% static/dynamic waste, 4% network

TPU adaptation: the same three-way split (static + dynamic-compute +
communication) is modelled per chip with public v5e-class constants, and
``step_energy`` prices a dry-run cell from its roofline counters — the
paper's "program that can measure its own power" becomes a step function
that can *account* its own energy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# --- paper constants --------------------------------------------------------
SWALLOW_STATIC_MW = 46.0
SWALLOW_DYN_MW_PER_MHZ = 0.30
SWALLOW_ACTIVE_MW_500 = 193.0
SWALLOW_IDLE_MW_500 = 113.0  # 500 MHz all-idle (Fig. 9)
SWALLOW_LINK_PJ_PER_BIT = {
    "on_die": 1.63, "on_board_v": 106.0, "on_board_h": 101.0,
    "off_board_ffc": 5440.0}
SWALLOW_VMIN = {71.0: 0.60, 500.0: 0.95}


def swallow_core_power_mw(f_mhz: float) -> float:
    """Eqn. 3. Validates against 193 mW @ 500 MHz (within ~1 mW)."""
    return SWALLOW_STATIC_MW + SWALLOW_DYN_MW_PER_MHZ * f_mhz


def swallow_vdd(f_mhz: float) -> float:
    """Linear Vmin(f) interpolation between the paper's measured points."""
    f0, f1 = 71.0, 500.0
    v0, v1 = SWALLOW_VMIN[f0], SWALLOW_VMIN[f1]
    t = (f_mhz - f0) / (f1 - f0)
    return v0 + t * (v1 - v0)


def swallow_dvfs_power_mw(f_mhz: float) -> float:
    """Fig. 10: P = CV^2 f, normalized to Eqn. 3 dynamic power at 500 MHz
    (voltage scaling stacked on frequency scaling)."""
    v = swallow_vdd(f_mhz)
    v500 = SWALLOW_VMIN[500.0]
    dyn500 = SWALLOW_DYN_MW_PER_MHZ * 500.0
    dyn = dyn500 * (v / v500) ** 2 * (f_mhz / 500.0)
    return SWALLOW_STATIC_MW * (v / v500) ** 2 + dyn


# --- TPU v5e-class analytical model -----------------------------------------
# Public-ballpark constants; what matters for the methodology is the split.
TPU_TDP_W = 200.0                  # chip + HBM envelope
TPU_STATIC_W = 60.0                # idle/static share
TPU_PJ_PER_FLOP_BF16 = 0.55e-12 * 1e12  # ~0.55 pJ/flop dynamic -> J/flop
TPU_PJ_PER_FLOP = 0.55e-12
TPU_HBM_PJ_PER_BYTE = 6.0e-12      # HBM2e access energy
TPU_ICI_PJ_PER_BYTE = 10.0e-12     # intra-pod link
TPU_DCN_PJ_PER_BYTE = 60.0e-12     # pod-to-pod (optical + NIC)


@dataclass
class StepEnergy:
    compute_j: float
    hbm_j: float
    ici_j: float
    static_j: float
    total_j: float
    w_per_chip: float
    breakdown: Dict[str, float]


def step_energy(*, flops_per_chip: float, hbm_bytes_per_chip: float,
                ici_bytes_per_chip: float, step_seconds: float,
                dcn_bytes_per_chip: float = 0.0) -> StepEnergy:
    """Energy of one step on one chip (the Fig. 8 split, TPU constants)."""
    compute = flops_per_chip * TPU_PJ_PER_FLOP
    hbm = hbm_bytes_per_chip * TPU_HBM_PJ_PER_BYTE
    ici = ici_bytes_per_chip * TPU_ICI_PJ_PER_BYTE \
        + dcn_bytes_per_chip * TPU_DCN_PJ_PER_BYTE
    static = TPU_STATIC_W * step_seconds
    total = compute + hbm + ici + static
    return StepEnergy(
        compute_j=compute, hbm_j=hbm, ici_j=ici, static_j=static,
        total_j=total, w_per_chip=total / max(step_seconds, 1e-12),
        breakdown={
            "compute_frac": compute / total, "hbm_frac": hbm / total,
            "network_frac": ici / total, "static_frac": static / total})


def energy_proportionality(load: float, *, f_max_mhz: float = 500.0,
                           model: str = "swallow") -> float:
    """Power at fractional load under frequency scaling (Fig. 9 analogue).

    load in [0,1] maps linearly to f in [71, 500] MHz for the Swallow
    model; the TPU model scales the dynamic share linearly with load.
    """
    if model == "swallow":
        f = 71.0 + load * (f_max_mhz - 71.0)
        return swallow_core_power_mw(f)
    return TPU_STATIC_W + (TPU_TDP_W - TPU_STATIC_W) * load
