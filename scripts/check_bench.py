#!/usr/bin/env python
"""Perf-smoke CI gate: validate the machine-readable BENCH_*.json files
that ``benchmarks/run.py --json`` emits.

* ``BENCH_micro.json`` (swallow.bench.micro/v1): non-empty ``rows`` of
  {name, us_per_call, derived} with finite positive timings, including
  the serve rows the fused-decode PR pinned.
* ``BENCH_serve.json`` (swallow.bench.serve/v1): fused + perstep stat
  blocks, ``tokens_match`` must be true (fused windows are a perf
  transform, not a sampler change), syncs-per-token must drop, and
  ``speedup_decode`` must clear ``PERF_SMOKE_MIN_SPEEDUP`` (default 1.0
  — the 1.5x acceptance bar is checked on dedicated hosts, CI runners
  only guard against regressions to parity).
* ``BENCH_prefix.json`` (swallow.bench.prefix/v1): prefix-cache on/off
  stat blocks on the shared-prefix trace.  ``tokens_match`` must be
  true (sharing is a placement transform), ``on.hit_rate`` must be
  positive, and ``prefill_token_reduction`` must clear
  ``PERF_SMOKE_MIN_PREFIX_REDUCTION`` (default 2.0 — the reduction is a
  token *count* ratio, deterministic on any host).
* ``BENCH_spec.json`` (swallow.bench.spec/v2): speculative-decoding
  on/off stat blocks on the repetitive single-stream trace, including
  the wall-clock honesty split (``wall_s`` = ``scan_s`` +
  ``draft_verify_s`` + ``host_s``).  ``tokens_match`` must be true
  (speculation is a dispatch transform, not a sampler change),
  ``on.accept_rate`` must be positive, ``on.dispatches_per_token``
  must stay under ``PERF_SMOKE_MAX_SPEC_DISPATCHES`` (default 0.7),
  ``dispatch_reduction`` must clear ``PERF_SMOKE_MIN_SPEC_REDUCTION``
  (default 1.4) — both are model-pass *count* ratios, deterministic on
  any host — and ``spec_speedup`` (on.tok_per_s / off.tok_per_s, the
  wall-clock verdict) must clear ``PERF_SMOKE_SPEC_SPEEDUP_MIN``
  (default 1.0: speculation must never lose to the plain scan it
  replaces).

* ``BENCH_slo.json`` (swallow.bench.slo/v1): chunked-prefill vs
  monolithic stat blocks on the overload trace (diurnal interactive +
  Pareto batch + surge), each with per-SLO-class TTFT percentile
  digests.  ``tokens_match`` must be true (chunking is a KV-composition
  transform, not a sampler change), ``p99_ttft_ratio`` (the interactive
  class's p99 TTFT on the deterministic engine-step clock,
  chunked/monolithic) must stay under ``PERF_SMOKE_MAX_P99_TTFT_RATIO``
  (default 1.0: slicing prefills must never make the interactive tail
  WORSE), and ``goodput_ratio`` (deadline-met tokens,
  chunked/monolithic) must clear ``PERF_SMOKE_MIN_GOODPUT_RATIO``
  (default 1.0: the latency win must not be bought with thrown-away
  throughput).  All three are deterministic on any host.

* ``BENCH_chaos.json`` (swallow.bench.chaos/v1): fault-free vs chaos
  stat blocks on the fault-injection trace (a seeded FaultPlan of node
  failures + transient rejections + a straggler against the striped
  page pool).  ``tokens_match`` must be true (every request the chaos
  run finishes is bit-identical to the fault-free run — recovery is
  exact greedy recompute, not resampling), ``chaos.node_failures``
  must be >= 2 both planned and detected, ``quarantined_served`` must
  be 0 (no dispatch ever read a dead stripe), recovery percentiles
  must be finite, and ``goodput_retained`` (deadline-met tokens,
  chaos/fault-free) must clear ``PERF_SMOKE_MIN_GOODPUT_RETAINED``
  (default 0.25 — degradation must be graceful; the whole chain is on
  the deterministic step clock, so the value is host-independent).

* ``BENCH_obs.json`` (swallow.bench.obs/v1): flight-recorder off vs on
  stat blocks on the overload trace.  ``tokens_match`` must be true
  (the tracer only *reads* the deterministic step clock — observing a
  run must never change it), ``overhead_ratio`` (min traced wall / min
  untraced wall) must stay under ``PERF_SMOKE_MAX_OBS_OVERHEAD``
  (default 1.05 — a flight recorder that taxes serving >5% would never
  stay armed in production), the embedded ``trace_events`` excerpt
  must validate against the Chrome trace-event schema
  (``repro.serving.telemetry.validate_chrome_trace`` — the same
  document ``--trace-out`` ships to Perfetto), at least one dispatch
  span must carry the full attribution triple
  (predicted_s/predicted_j/measured_s), and the ``model_error`` rollup
  must be finite.

* ``BENCH_tp.json`` (swallow.bench.tp/v1): the pinned prefix-sharing
  workload replayed at every serving layout — the 1x1 single-device
  baseline plus striped (data, model) meshes, each in a forced-device
  subprocess.  ``tokens_match`` must be true per layout and overall
  (striping the page pools is a placement transform — greedy tokens are
  bit-identical across meshes), every striped layout must price
  interconnect traffic (``predicted_comms_s`` / ``comms_bytes`` > 0 —
  the §V link model applied per dispatch window), and the measured
  remote page fraction must track the predicted (n-1)/n stripe model:
  ``remote_frac_ratio`` within ``PERF_SMOKE_MAX_TP_MODEL_ERROR``
  (default 0.25) of 1.0.  All gated values ride the deterministic step
  clock and allocator state, so they are host-independent.

Run from the repo root:
    python benchmarks/run.py --only micro --json
    python scripts/check_bench.py BENCH_micro.json BENCH_serve.json \
        BENCH_prefix.json BENCH_spec.json BENCH_slo.json \
        BENCH_chaos.json BENCH_obs.json BENCH_tp.json
"""
from __future__ import annotations

import json
import math
import os
import sys

# telemetry is pure host-side (stdlib + numpy) — importable without jax
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

REQUIRED_SERVE_KEYS = ("tokens", "steps", "windows", "decode_tok_per_s",
                       "tok_per_s", "h2d_syncs", "d2h_syncs",
                       "syncs_per_token", "preemptions")
REQUIRED_MICRO_ROWS = ("micro/serve_fused_window_", "micro/serve_perstep_",
                       "micro/paged_attn_kernel_")


def _finite_pos(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x >= 0


def check_micro(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.micro/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    rows = doc.get("rows", [])
    if not rows:
        errs.append("rows is empty")
    for r in rows:
        if set(r) != {"name", "us_per_call", "derived"}:
            errs.append(f"bad row keys: {sorted(r)}")
            break
        if not _finite_pos(r["us_per_call"]):
            errs.append(f"{r['name']}: non-finite us_per_call "
                        f"{r['us_per_call']!r}")
    names = [r.get("name", "") for r in rows]
    for prefix in REQUIRED_MICRO_ROWS:
        if not any(n.startswith(prefix) for n in names):
            errs.append(f"missing required micro row {prefix}*")
    return errs


def check_serve(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.serve/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    for mode in ("fused", "perstep"):
        blk = doc.get(mode)
        if not isinstance(blk, dict):
            errs.append(f"missing {mode} block")
            continue
        for key in REQUIRED_SERVE_KEYS:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{mode}.{key}: non-finite {blk.get(key)!r}")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: fused windows changed "
                    "the emitted tokens")
    if not errs:
        if doc["fused"]["syncs_per_token"] \
                > doc["perstep"]["syncs_per_token"]:
            errs.append(
                "fused syncs_per_token "
                f"({doc['fused']['syncs_per_token']:.3f}) did not drop "
                f"below per-step ({doc['perstep']['syncs_per_token']:.3f})")
        min_speedup = float(os.environ.get("PERF_SMOKE_MIN_SPEEDUP", "1.0"))
        speedup = doc.get("speedup_decode")
        if not _finite_pos(speedup):
            errs.append(f"speedup_decode: non-finite {speedup!r}")
        elif speedup < min_speedup:
            errs.append(f"speedup_decode {speedup:.3f} "
                        f"< required {min_speedup}")
    return errs


REQUIRED_PREFIX_ON_KEYS = ("tokens", "steps", "prefill_tokens",
                           "tok_per_s", "ttft_steps_mean", "hit_rate",
                           "prefill_tokens_cached", "cow_copies",
                           "shared_pages", "bytes_deduped")
REQUIRED_PREFIX_OFF_KEYS = ("tokens", "steps", "prefill_tokens",
                            "tok_per_s", "ttft_steps_mean")


def check_prefix(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.prefix/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    for mode, keys in (("on", REQUIRED_PREFIX_ON_KEYS),
                       ("off", REQUIRED_PREFIX_OFF_KEYS)):
        blk = doc.get(mode)
        if not isinstance(blk, dict):
            errs.append(f"missing {mode} block")
            continue
        for key in keys:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{mode}.{key}: non-finite {blk.get(key)!r}")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: prefix sharing changed "
                    "the emitted tokens")
    if not errs:
        if doc["on"]["hit_rate"] <= 0.0:
            errs.append("on.hit_rate is 0: the shared-prefix trace "
                        "never hit the cache")
        min_red = float(os.environ.get("PERF_SMOKE_MIN_PREFIX_REDUCTION",
                                       "2.0"))
        red = doc.get("prefill_token_reduction")
        if not _finite_pos(red):
            errs.append(f"prefill_token_reduction: non-finite {red!r}")
        elif red < min_red:
            errs.append(f"prefill_token_reduction {red:.3f} "
                        f"< required {min_red}")
    return errs


REQUIRED_SPEC_ON_KEYS = ("tokens", "steps", "model_passes",
                         "dispatches_per_token", "accept_rate",
                         "spec_drafted", "spec_accepted", "spec_verifies",
                         "spec_k_mean", "tok_per_s", "wall_s", "scan_s",
                         "draft_verify_s", "host_s")
REQUIRED_SPEC_OFF_KEYS = ("tokens", "steps", "model_passes",
                          "dispatches_per_token", "tok_per_s", "wall_s",
                          "scan_s", "draft_verify_s", "host_s")


def check_spec(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.spec/v2":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    for mode, keys in (("on", REQUIRED_SPEC_ON_KEYS),
                       ("off", REQUIRED_SPEC_OFF_KEYS)):
        blk = doc.get(mode)
        if not isinstance(blk, dict):
            errs.append(f"missing {mode} block")
            continue
        for key in keys:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{mode}.{key}: non-finite {blk.get(key)!r}")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: speculative decoding "
                    "changed the emitted tokens")
    if not errs:
        if doc["on"]["accept_rate"] <= 0.0:
            errs.append("on.accept_rate is 0: the repetitive trace never "
                        "accepted a draft")
        max_dpt = float(os.environ.get("PERF_SMOKE_MAX_SPEC_DISPATCHES",
                                       "0.7"))
        dpt = doc["on"]["dispatches_per_token"]
        if dpt >= max_dpt:
            errs.append(f"on.dispatches_per_token {dpt:.3f} "
                        f">= allowed {max_dpt}")
        min_red = float(os.environ.get("PERF_SMOKE_MIN_SPEC_REDUCTION",
                                       "1.4"))
        red = doc.get("dispatch_reduction")
        if not _finite_pos(red):
            errs.append(f"dispatch_reduction: non-finite {red!r}")
        elif red < min_red:
            errs.append(f"dispatch_reduction {red:.3f} "
                        f"< required {min_red}")
        # the wall-clock verdict: fewer dispatches must actually buy
        # wall time, or speculation is a pessimization on this host
        min_speedup = float(os.environ.get("PERF_SMOKE_SPEC_SPEEDUP_MIN",
                                           "1.0"))
        speedup = doc.get("spec_speedup")
        if not _finite_pos(speedup):
            errs.append(f"spec_speedup: non-finite {speedup!r}")
        elif speedup < min_speedup:
            errs.append(f"spec_speedup {speedup:.3f} "
                        f"< required {min_speedup}: speculation lost "
                        "wall-clock to the plain scan")
    return errs


REQUIRED_SLO_KEYS = ("tokens", "steps", "tok_per_s", "prefill_tokens",
                     "goodput_tokens")
REQUIRED_SLO_CLASS_KEYS = ("requests", "ttft_steps_p50", "ttft_steps_p95",
                           "ttft_steps_p99", "slo_met_frac",
                           "goodput_tokens", "tokens")


def check_slo(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.slo/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    for mode in ("chunked", "monolithic"):
        blk = doc.get(mode)
        if not isinstance(blk, dict):
            errs.append(f"missing {mode} block")
            continue
        for key in REQUIRED_SLO_KEYS:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{mode}.{key}: non-finite {blk.get(key)!r}")
        classes = blk.get("slo")
        if not isinstance(classes, dict) or not classes:
            errs.append(f"{mode}.slo: missing per-class digest")
            continue
        if "interactive" not in classes:
            errs.append(f"{mode}.slo: no interactive class (the gated "
                        "ratio needs one)")
        for cls, digest in classes.items():
            for key in REQUIRED_SLO_CLASS_KEYS:
                if not _finite_pos(digest.get(key)):
                    errs.append(f"{mode}.slo.{cls}.{key}: non-finite "
                                f"{digest.get(key)!r}")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: chunked prefill changed "
                    "the emitted tokens")
    if not errs:
        max_ratio = float(os.environ.get("PERF_SMOKE_MAX_P99_TTFT_RATIO",
                                         "1.0"))
        ratio = doc.get("p99_ttft_ratio")
        if not _finite_pos(ratio):
            errs.append(f"p99_ttft_ratio: non-finite {ratio!r}")
        elif ratio > max_ratio:
            errs.append(f"p99_ttft_ratio {ratio:.3f} > allowed "
                        f"{max_ratio}: chunked prefill made the "
                        "interactive p99 TTFT worse")
        min_good = float(os.environ.get("PERF_SMOKE_MIN_GOODPUT_RATIO",
                                        "1.0"))
        good = doc.get("goodput_ratio")
        if not _finite_pos(good):
            errs.append(f"goodput_ratio: non-finite {good!r}")
        elif good < min_good:
            errs.append(f"goodput_ratio {good:.3f} < required "
                        f"{min_good}: chunking bought latency with "
                        "thrown-away throughput")
    return errs


REQUIRED_CHAOS_KEYS = ("tokens", "steps", "tok_per_s",
                       "requests_finished", "goodput_tokens")
REQUIRED_CHAOS_FAULT_KEYS = ("node_failures", "node_joins",
                             "pages_quarantined", "requests_recovered",
                             "requests_shed", "tokens_recomputed",
                             "transient_rejections", "quarantined_served",
                             "recovery_steps_p50", "recovery_steps_p99")


def check_chaos(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.chaos/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    for mode in ("fault_free", "chaos"):
        blk = doc.get(mode)
        if not isinstance(blk, dict):
            errs.append(f"missing {mode} block")
            continue
        for key in REQUIRED_CHAOS_KEYS:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{mode}.{key}: non-finite {blk.get(key)!r}")
    chaos = doc.get("chaos")
    if isinstance(chaos, dict):
        for key in REQUIRED_CHAOS_FAULT_KEYS:
            if not _finite_pos(chaos.get(key)):
                errs.append(f"chaos.{key}: non-finite {chaos.get(key)!r}")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: fault recovery changed "
                    "a survivor's emitted tokens")
    if not errs:
        if doc.get("planned_failures", 0) < 2:
            errs.append(f"planned_failures "
                        f"{doc.get('planned_failures')!r} < 2: the "
                        "chaos schedule must inject >= 2 node failures")
        if chaos["node_failures"] < 2:
            errs.append(f"chaos.node_failures {chaos['node_failures']} "
                        "< 2: the watchdog missed injected failures")
        if chaos["quarantined_served"] != 0:
            errs.append(f"chaos.quarantined_served "
                        f"{chaos['quarantined_served']} != 0: a dispatch "
                        "read a quarantined page")
        min_good = float(os.environ.get("PERF_SMOKE_MIN_GOODPUT_RETAINED",
                                        "0.25"))
        good = doc.get("goodput_retained")
        if not _finite_pos(good):
            errs.append(f"goodput_retained: non-finite {good!r}")
        elif good < min_good:
            errs.append(f"goodput_retained {good:.3f} < required "
                        f"{min_good}: recovery did not degrade "
                        "gracefully")
    return errs


REQUIRED_TP_KEYS = ("predicted_s", "measured_s", "predicted_comms_s",
                    "comms_bytes", "measured_remote_frac", "steps",
                    "cow_copies", "preemptions")


def check_tp(doc: dict) -> list:
    errs = []
    if doc.get("schema") != "swallow.bench.tp/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    layouts = doc.get("layouts")
    if not isinstance(layouts, list) or len(layouts) < 2:
        errs.append("layouts: need the 1x1 baseline plus at least one "
                    "striped mesh")
        return errs
    for blk in layouts:
        tag = blk.get("layout", "?")
        for key in REQUIRED_TP_KEYS:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{tag}.{key}: non-finite {blk.get(key)!r}")
        if blk.get("tokens_match") is not True:
            errs.append(f"{tag}: tokens_match is not true — sharding the "
                        "page pools changed the emitted tokens")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: some layout diverged from "
                    "the 1x1 baseline")
    if not any(blk.get("model", 1) > 1 for blk in layouts):
        errs.append("no striped layout (model > 1) in the sweep")
    if not errs:
        # the §V stripe model: measured remote page fraction vs the
        # predicted (n-1)/n, gated as a ratio around 1.0
        max_err = float(os.environ.get("PERF_SMOKE_MAX_TP_MODEL_ERROR",
                                       "0.25"))
        for blk in layouts:
            if blk.get("model", 1) <= 1:
                continue
            tag = blk["layout"]
            ratio = blk.get("remote_frac_ratio")
            if not _finite_pos(ratio):
                errs.append(f"{tag}.remote_frac_ratio: non-finite "
                            f"{ratio!r}")
            elif abs(ratio - 1.0) > max_err:
                errs.append(f"{tag}.remote_frac_ratio {ratio:.3f} "
                            f"deviates from the (n-1)/n stripe model by "
                            f"more than {max_err}")
            if blk.get("predicted_comms_s", 0.0) <= 0.0:
                errs.append(f"{tag}.predicted_comms_s is 0: the striped "
                            "run priced no interconnect traffic")
            if blk.get("comms_bytes", 0.0) <= 0.0:
                errs.append(f"{tag}.comms_bytes is 0: the striped run "
                            "priced no wire bytes")
    return errs


REQUIRED_OBS_KEYS = ("tokens", "steps", "tok_per_s", "wall_s")


def check_obs(doc: dict) -> list:
    from repro.serving.telemetry import validate_chrome_trace

    errs = []
    if doc.get("schema") != "swallow.bench.obs/v1":
        errs.append(f"bad schema: {doc.get('schema')!r}")
    for mode in ("off", "on"):
        blk = doc.get(mode)
        if not isinstance(blk, dict):
            errs.append(f"missing {mode} block")
            continue
        for key in REQUIRED_OBS_KEYS:
            if not _finite_pos(blk.get(key)):
                errs.append(f"{mode}.{key}: non-finite {blk.get(key)!r}")
    if doc.get("tokens_match") is not True:
        errs.append("tokens_match is not true: arming the flight "
                    "recorder changed the emitted tokens")
    events = doc.get("trace_events")
    if not isinstance(events, list) or not events:
        errs.append("trace_events: missing or empty")
    else:
        for e in validate_chrome_trace({"traceEvents": events}):
            errs.append(f"trace_events: {e}")
        dispatch = [e for e in events
                    if e.get("ph") == "X" and e.get("cat") == "dispatch"]
        if not dispatch:
            errs.append("trace_events: no dispatch spans in the excerpt")
        elif not any({"predicted_s", "predicted_j", "measured_s"}
                     <= set(e.get("args", {})) for e in dispatch):
            errs.append("trace_events: no dispatch span carries the "
                        "predicted_s/predicted_j/measured_s attribution "
                        "triple")
    report = doc.get("model_error")
    if not isinstance(report, dict) or not report:
        errs.append("model_error: missing or empty rollup")
    else:
        for phase, r in report.items():
            for key in ("count", "predicted_s", "measured_s",
                        "predicted_j"):
                if not _finite_pos(r.get(key)):
                    errs.append(f"model_error.{phase}.{key}: non-finite "
                                f"{r.get(key)!r}")
    if not errs:
        if doc["on"].get("spans_recorded", 0) <= 0:
            errs.append("on.spans_recorded is 0: the traced run "
                        "recorded nothing")
        max_over = float(os.environ.get("PERF_SMOKE_MAX_OBS_OVERHEAD",
                                        "1.05"))
        over = doc.get("overhead_ratio")
        if not _finite_pos(over):
            errs.append(f"overhead_ratio: non-finite {over!r}")
        elif over > max_over:
            errs.append(f"overhead_ratio {over:.3f} > allowed "
                        f"{max_over}: the flight recorder taxes "
                        "serving too much to stay armed")
    return errs


def main() -> None:
    paths = sys.argv[1:] or ["BENCH_micro.json", "BENCH_serve.json",
                             "BENCH_prefix.json", "BENCH_spec.json",
                             "BENCH_slo.json", "BENCH_chaos.json",
                             "BENCH_obs.json", "BENCH_tp.json"]
    failures = []
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"{path}: unreadable ({e})")
            continue
        schema = doc.get("schema", "")
        if "micro" in schema or "micro" in os.path.basename(path):
            errs = check_micro(doc)
        elif "prefix" in schema or "prefix" in os.path.basename(path):
            errs = check_prefix(doc)
        elif "spec" in schema or "spec" in os.path.basename(path):
            errs = check_spec(doc)
        elif "slo" in schema or "slo" in os.path.basename(path):
            errs = check_slo(doc)
        elif "chaos" in schema or "chaos" in os.path.basename(path):
            errs = check_chaos(doc)
        elif "obs" in schema or "obs" in os.path.basename(path):
            errs = check_obs(doc)
        elif "tp" in schema \
                or os.path.basename(path).startswith("BENCH_tp"):
            errs = check_tp(doc)
        else:
            errs = check_serve(doc)
        for e in errs:
            failures.append(f"{path}: {e}")
        if not errs:
            print(f"[bench] {path}: ok ({schema})")
    if failures:
        print(f"\n{len(failures)} bench check(s) FAILED:", file=sys.stderr)
        for f in failures:
            print(" -", f, file=sys.stderr)
        sys.exit(1)
    print("all bench checks passed")


if __name__ == "__main__":
    main()
