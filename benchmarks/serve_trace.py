"""Bursty multi-tenant Poisson trace through the paged serving engine.

The serving-side companion of cost_sweep.py: where that benchmark replays
whole *jobs* through the cost-aware nOS, this one replays individual
*requests* through :mod:`repro.serving` — the paged-KV continuous-batching
engine — and emits a throughput / TTFT / page-occupancy table per tenant,
plus the nOS fleet serving view (pages, energy, queue latency).

Arrivals are Poisson per tenant in units of engine steps (the engine
step is the farmer's clock), with a burst tenant that dumps its whole
load at once — the mixed pattern that makes continuous batching and
page-pressure preemption visible.

Run:  PYTHONPATH=src python benchmarks/serve_trace.py [--quick]
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

sys.path.insert(0, "src")


@dataclass(frozen=True)
class Tenant:
    name: str
    n_requests: int
    rate: float          # mean arrivals per engine step (Poisson); 0 = burst
    prompt_len: int
    gen: int
    at_step: int = 0     # burst tenants: every request arrives here


def default_tenants(quick: bool = False) -> List[Tenant]:
    if quick:
        return [Tenant("chat", 6, 0.5, 12, 6),
                Tenant("burst", 4, 0.0, 8, 4, at_step=5)]
    return [
        Tenant("chat", 12, 0.4, 16, 8),          # steady interactive load
        Tenant("batch", 8, 0.15, 32, 16),        # long-prompt background
        Tenant("burst", 8, 0.0, 12, 6, at_step=10),  # arrives all at once
    ]


def arrivals_for(t: Tenant, rng: np.random.Generator):
    """(step, tenant) arrival list — Poisson gaps, or one burst."""
    if t.rate <= 0.0:
        return [(t.at_step, t)] * t.n_requests
    gaps = rng.exponential(1.0 / t.rate, size=t.n_requests)
    steps = np.floor(np.cumsum(gaps)).astype(int)
    return [(int(s), t) for s in steps]


def replay(tenants: Optional[List[Tenant]] = None, *, seed: int = 0,
           max_batch: int = 4, page_size: int = 8, n_pages: int = 0,
           arch: str = "tiny-100m", link_mode: str = "circuit",
           prefill_budget: float = 2.0, fused: bool = True,
           max_window: int = 8, warmup: bool = False, params=None):
    """Drive the engine window by window, injecting arrivals between
    dispatches.  With ``fused`` the engine decodes multi-token windows,
    capped to the next pending arrival so the trace's admission clock
    stays faithful; ``fused=False`` is the legacy per-step loop.

    Returns (engine, per-tenant rows, totals).
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm
    from repro.serving import PagedEngine

    tenants = tenants if tenants is not None else default_tenants()
    rng = np.random.default_rng(seed)
    pending = sorted([a for t in tenants for a in arrivals_for(t, rng)],
                     key=lambda a: a[0])
    max_len = max(t.prompt_len + t.gen for t in tenants)
    if not n_pages:
        # ~75% of worst-case demand: page pressure without thrash
        worst = max_batch * (-(-max_len // page_size))
        n_pages = max(int(worst * 0.75), 2) + 1

    cfg = get_tiny_config(arch)
    if params is None:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedEngine(cfg, params, max_batch=max_batch,
                      page_size=page_size, n_pages=n_pages,
                      max_len=max_len, link_mode=link_mode,
                      prefill_budget=prefill_budget, fused=fused,
                      max_window=max_window)
    if warmup:
        # compile every window bucket + a prefill per DISTINCT prompt
        # shape in the trace (prefill retraces per length) outside the
        # timed region
        eng.warmup_windows()
        for i, plen in enumerate(sorted({t.prompt_len for t in tenants})):
            warm = jax.random.randint(jax.random.PRNGKey(10_000 + i),
                                      (plen,), 2, cfg.vocab_size)
            eng.submit(np.asarray(warm), min(2, max_len - plen),
                       rid=f"warmup{i}")
        eng.run()
        eng.reset_metrics()
        eng.sched.step_idx = 0

    occupancy = []
    rid = 0
    while pending or eng.sched.waiting or eng.sched.running:
        while pending and pending[0][0] <= eng.sched.step_idx:
            _, t = pending.pop(0)
            prompt = jax.random.randint(jax.random.PRNGKey(rid),
                                        (t.prompt_len,), 2, cfg.vocab_size)
            eng.submit(np.asarray(prompt), t.gen, tenant=t.name,
                       rid=f"{t.name}/{rid}")
            rid += 1
        before = eng.steps_run
        if eng.sched.waiting or eng.sched.running:
            # never decode past the next arrival: windows respect the
            # trace's clock, not just the scheduler's safe horizon
            cap = pending[0][0] - eng.sched.step_idx if pending else None
            eng.step(max_window=cap)
        else:
            eng.sched.step_idx += 1   # idle gap before the next arrival
        # one sample per *scheduler* step (a fused window covers several)
        # so fused and per-step occupancy means weight phases identically
        occupancy += [eng.alloc.pages_in_use] * max(eng.steps_run - before,
                                                    1)

    rows = []
    for t in tenants:
        fin = [r for r in eng.sched.finished if r.tenant == t.name]
        ttft = [r.first_token_step - r.arrived_step for r in fin]
        rows.append(dict(
            tenant=t.name, requests=len(fin),
            tokens=sum(len(r.tokens) for r in fin),
            ttft_mean=float(np.mean(ttft)) if ttft else 0.0,
            ttft_p95=float(np.percentile(ttft, 95)) if ttft else 0.0,
            preemptions=sum(r.preemptions for r in fin)))
    m = eng.metrics()
    totals = dict(
        steps=eng.steps_run, windows=m["windows"], tokens=m["tokens_out"],
        tokens_finished=m["tokens_finished"],
        tok_per_s=m["tok_per_s"], decode_tok_per_s=m["decode_tok_per_s"],
        h2d_syncs=m["h2d_syncs"], d2h_syncs=m["d2h_syncs"],
        syncs_per_token=m["syncs_per_token"],
        occupancy_mean=float(np.mean(occupancy)) / max(n_pages - 1, 1),
        occupancy_peak=m["peak_pages"] / max(n_pages - 1, 1),
        preemptions=m["preemptions"], n_pages=n_pages,
        page_size=page_size)
    return eng, rows, totals


def bench_tenants() -> List[Tenant]:
    """Decode-heavy pinned trace for BENCH_serve.json: one burst of
    long-gen requests at batch pressure, so fused windows actually reach
    ``max_window``.  (The docs quick trace is arrival-dominated — its
    windows are capped near K=1 by the admission clock, which makes it a
    TTFT exemplar, not a decode-throughput one.)"""
    return [Tenant("decode", 8, 0.0, 16, 24, at_step=0)]


def bench_fused_comparison(*, quick: bool = True, seed: int = 0,
                           max_batch: int = 4, page_size: int = 8,
                           max_window: int = 8, arch: str = "tiny-100m"):
    """Replay the pinned decode-burst trace twice — fused windows vs
    legacy per-step — with shared params, warmed-up compiles and an
    uncontended pool (speedup A/B, not a preemption stressor), asserting
    token identity per request.

    Returns the BENCH_serve.json payload (see scripts/check_bench.py).
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm

    tenants = bench_tenants()
    if not quick:
        tenants = [Tenant("decode", 16, 0.0, 32, 48, at_step=0)]
    max_len = max(t.prompt_len + t.gen for t in tenants)
    n_pages = max_batch * (-(-max_len // page_size)) + 1
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    toks = {}
    for mode, fused in (("fused", True), ("perstep", False)):
        eng, rows, totals = replay(tenants, seed=seed,
                                   max_batch=max_batch,
                                   page_size=page_size, n_pages=n_pages,
                                   fused=fused,
                                   max_window=max_window, warmup=True,
                                   params=params, arch=arch)
        toks[mode] = {r.rid: list(r.tokens) for r in eng.sched.finished}
        out[mode] = dict(
            tokens=totals["tokens"], steps=totals["steps"],
            windows=totals["windows"],
            decode_tok_per_s=totals["decode_tok_per_s"],
            tok_per_s=totals["tok_per_s"],
            h2d_syncs=totals["h2d_syncs"], d2h_syncs=totals["d2h_syncs"],
            syncs_per_token=totals["syncs_per_token"],
            preemptions=totals["preemptions"])
    return {
        "schema": "swallow.bench.serve/v1",
        "arch": arch, "batch": max_batch, "page_size": page_size,
        "max_window": max_window, "trace": "decode-burst",
        "quick": quick, "seed": seed,
        "fused": out["fused"], "perstep": out["perstep"],
        "tokens_match": toks["fused"] == toks["perstep"],
        "speedup_decode": out["fused"]["decode_tok_per_s"]
        / max(out["perstep"]["decode_tok_per_s"], 1e-9),
        "sync_reduction": out["perstep"]["syncs_per_token"]
        / max(out["fused"]["syncs_per_token"], 1e-9),
    }


def format_table(rows, totals) -> str:
    out = [f"# paged serve trace — {len(rows)} tenants, "
           f"{totals['n_pages']} pages x {totals['page_size']} tokens",
           f"{'tenant':<10} {'reqs':>5} {'tokens':>7} {'ttft_mean':>10} "
           f"{'ttft_p95':>9} {'preempt':>8}"]
    for r in rows:
        out.append(f"{r['tenant']:<10} {r['requests']:>5} {r['tokens']:>7} "
                   f"{r['ttft_mean']:>10.1f} {r['ttft_p95']:>9.1f} "
                   f"{r['preemptions']:>8}")
    t = totals
    out.append(f"{t['steps']} engine steps in {t['windows']} device "
               f"dispatches, {t['tokens']} tokens "
               f"({t['tok_per_s']:.0f} tok/s wall, "
               f"{t['decode_tok_per_s']:.0f} decode tok/s); "
               f"host<->device syncs {t['h2d_syncs']} h2d + "
               f"{t['d2h_syncs']} d2h ({t['syncs_per_token']:.2f}/token); "
               f"page occupancy "
               f"mean {t['occupancy_mean'] * 100:.0f}% / peak "
               f"{t['occupancy_peak'] * 100:.0f}%; "
               f"{t['preemptions']} preemptions")
    return "\n".join(out)


def fleet_view(eng) -> str:
    """Per-tenant gauges through the nOS serving surface."""
    from repro.core import nos as nos_mod
    pod = nos_mod.NOS(data_rows=4, model_cols=1)
    est = eng.decode_estimate      # engine-priced step time & energy
    j_per_token = est.energy.total_j / max(eng.max_batch, 1)
    tenants = sorted({r.tenant for r in eng.sched.finished})
    for name in tenants:
        fin = [r for r in eng.sched.finished if r.tenant == name]
        ttft = [r.first_token_step - r.arrived_step for r in fin]
        tokens = sum(len(r.tokens) for r in fin)
        pod.submit(nos_mod.Job(name, rows_needed=1))
        pod.update_serving(
            name,
            pages_held=max((eng.alloc.pages_for(r.prompt_len + r.gen)
                            for r in fin), default=0),
            tokens_out=tokens,
            queue_latency_s=(float(np.mean(ttft)) if ttft else 0.0)
            * est.step_time_s,
            preemptions=sum(r.preemptions for r in fin),
            energy_j=tokens * j_per_token)
    return pod.serving_table()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small trace for CI / docs examples")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=0)
    ap.add_argument("--link-mode", default="circuit",
                    choices=["circuit", "packet"])
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused multi-token decode windows "
                         "(--no-fused = legacy per-step loop)")
    ap.add_argument("--window", type=int, default=8,
                    help="max fused window (tokens per device dispatch)")
    args = ap.parse_args()
    eng, rows, totals = replay(default_tenants(args.quick), seed=args.seed,
                               max_batch=args.batch,
                               page_size=args.page_size, n_pages=args.pages,
                               link_mode=args.link_mode, fused=args.fused,
                               max_window=args.window)
    print(format_table(rows, totals))
    print("[nOS] fleet serving view:")
    print(fleet_view(eng))


if __name__ == "__main__":
    main()
