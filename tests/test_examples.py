"""Smoke tests for examples/ — each example must run end to end (they
carry their own internal assertions, e.g. shared_memory.py checks store
semantics and cache-on/off token identity)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_example(name: str, timeout: int = 300):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout)


def test_shared_memory_example_runs():
    proc = _run_example("shared_memory.py")
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = proc.stdout
    assert "semantics check OK" in out
    assert "tokens identical with cache on/off: True" in out
    assert "hit rate" in out
