"""Config dataclasses for swallow-jax model architectures.

Every assigned architecture is expressed as a ``ModelConfig``.  Layer
heterogeneity (gemma2 local/global alternation, recurrentgemma RG-LRU:attn 2:1,
deepseek first-k-dense-then-MoE) is expressed with a cyclic ``layer_pattern``
plus ``first_k_dense`` so the model can ``lax.scan`` over homogeneous groups.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds usable in ``layer_pattern``.
ATTN = "attn"          # global self attention (GQA)
LOCAL = "local"        # sliding-window self attention
MLA = "mla"            # multi-head latent attention (deepseek)
RGLRU = "rglru"        # Griffin RG-LRU recurrent block
RWKV6 = "rwkv6"        # RWKV-6 time-mix block
LAYER_KINDS = (ATTN, LOCAL, MLA, RGLRU, RWKV6)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int               # per-expert FFN hidden
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # deepseek-style aux-loss-free bias routing; we implement standard
    # softmax-top-k with an optional load-balance aux loss.
    aux_loss_coef: float = 0.001
    score_func: str = "softmax"    # softmax | sigmoid (deepseek-v3 uses sigmoid)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # -- layer composition ---------------------------------------------------
    layer_pattern: Tuple[str, ...] = (ATTN,)   # cycled across layers
    first_k_dense: int = 0         # leading layers forced dense-FFN (deepseek)

    # -- attention details ---------------------------------------------------
    causal: bool = True            # False => encoder-only (hubert)
    qk_norm: bool = False
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    logit_softcap: Optional[float] = None    # gemma2: 30.0
    rope_theta: float = 10_000.0
    sliding_window: int = 4096     # for LOCAL layers
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    attn_logit_scale: Optional[float] = None  # override 1/sqrt(head_dim)
    rope: bool = True              # False => no positional rotation (hubert stub)
    post_norm: bool = False        # gemma2: extra norm after each sublayer

    # -- FFN -------------------------------------------------------------
    act: str = "silu"              # silu | gelu
    gated_ffn: bool = True         # GLU-style (SwiGLU / GeGLU); False => plain MLP

    # -- optional sub-configs --------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None

    # -- recurrent blocks --------------------------------------------------
    lru_width: Optional[int] = None  # RG-LRU recurrence width (default d_model)
    conv1d_width: int = 4            # temporal conv in the RG-LRU block

    # -- embeddings / head -----------------------------------------------
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma-style sqrt(d_model) embedding scale
    norm_eps: float = 1e-6
    # vlm/audio backbones take precomputed embeddings instead of token ids.
    embed_inputs: bool = True      # False => inputs are (B, S, d_model) floats
    mtp_depth: int = 0             # deepseek multi-token-prediction modules

    # -- numerics / memory policy ------------------------------------------
    param_dtype: str = "float32"   # deepseek/grok: bfloat16
    activation_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # deepseek: int8 (block-quantized)
    remat: bool = True

    # -- implementation switch (ref | blocked | pallas) -----------------------
    impl: str = "blocked"
    attn_block_q: int = 512        # flash blocking (blocked/pallas impls)
    attn_block_kv: int = 1024
    scan_layers: bool = True       # lax.scan over layer groups

    def __post_init__(self):
        for k in self.layer_pattern:
            if k not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {k!r}")
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        # n_layers need not be divisible by the pattern period: the model
        # scans over full cycles and unrolls the remainder (recurrentgemma:
        # 26 layers over a (rglru, rglru, local) period-3 pattern).

    # -- derived -------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Fully unrolled per-layer kind list (length n_layers)."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def uses_kind(self, kind: str) -> bool:
        return kind in self.layer_pattern

    @property
    def sub_quadratic(self) -> bool:
        """True iff no layer does *global* attention (long_500k eligibility)."""
        return all(k in (LOCAL, RGLRU, RWKV6) for k in self.layer_pattern)

    @property
    def supports_decode(self) -> bool:
        return self.causal

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        n = v * d                      # embedding
        if not self.tie_embeddings:
            n += v * d                 # unembed
        for i, kind in enumerate(self.layer_kinds):
            n += self._mixer_params(kind)
            n += self._ffn_params(i)
            n += 2 * d                 # two pre-norms (ignore post-norm nuance)
        n += d                         # final norm
        if self.mtp_depth:
            n += self.mtp_depth * (
                self._mixer_params(self.layer_kinds[-1])
                + self._ffn_params(self.n_layers - 1) + 3 * d + d * 2 * d)
        return n

    def _mixer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.head_dim
        if kind in (ATTN, LOCAL):
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d \
                + (2 * hd if self.qk_norm else 0)
        if kind == MLA:
            m = self.mla
            qr = m.q_lora_rank or d
            n = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim) if m.q_lora_rank else \
                d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
            del qr
            return n
        if kind == RGLRU:
            w = self.lru_width or d
            # linear in/out + conv1d + gates (RG-LRU a,x gates) + Λ
            return 2 * d * w + self.conv1d_width * w + 2 * w * w + w
        if kind == RWKV6:
            # r,k,v,g,o projections + time-mix lora + decay lora + u
            return 5 * d * d + 6 * (d * 32 + 32 * d) + 2 * d
        raise ValueError(kind)

    def _ffn_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.moe is not None and layer_idx >= self.first_k_dense:
            m = self.moe
            per = (3 if self.gated_ffn else 2) * d * m.d_ff_expert
            return (m.n_experts + m.n_shared) * per + d * m.n_experts  # + router
        return (3 if self.gated_ffn else 2) * d * self.d_ff

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed top_k + shared)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        m = self.moe
        per = (3 if self.gated_ffn else 2) * d * m.d_ff_expert
        inactive = 0
        n_moe_layers = self.n_layers - self.first_k_dense
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per
        return self.n_params() - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every (arch x shape) cell is defined by these.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Implements the skip rules recorded in DESIGN.md §4."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "global attention is not sub-quadratic at 500k"
    return True, ""
