"""Swallow §II-B / §V-D / Tab. III: communication-to-computation analysis.

    Communication performance = max(e/c, E/C)            (Eqn. 1)
    balanced  iff  e/c <= 1  and  E/C <= 1               (Eqn. 2)

where e = a node's data source/sink throughput demand, c = the node's
local communication capacity, E = aggregate demand, C = global (bisection)
capacity.  The paper evaluates Swallow at e/c = 2 and E/C in [8, 32]
(Tab. III) and compares SpiNNaker / Centip3De / Tile / Epiphany.

Here the same quantities are derived for a TPU mesh from a dry-run cell:
the per-chip injection demand is the per-device collective wire bytes per
step over the step's compute time (what the chip *wants* to push), and
capacity is the chip's ICI links.  E/C uses bisection bandwidth.  This is
the paper's methodology with the HLO as the "application".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16

# Paper Tab. III (bits/s) — reproduced as ground truth for tests/benches.
SWALLOW_TABLE_III = {
    #              source(bps)  sink(bps)  router(bps)  e/c    E/C
    "Swallow":    dict(e=4e9,   c=2e9,     C=4.5e9, ec=2.0, EC=(8, 32)),
    "SpiNNaker":  dict(e=6.4e6, c=240e6,   C=4e9,   ec=0.03, EC=0.42),
    "Centip3De":  dict(e=246e9, c=None,    C=4.46e9, ec=None, EC=55),
    "Tile":       dict(e=96e9,  c=1.28e12, C=2.56e12, ec=0.075, EC=2.4),
    "Epiphany":   dict(e=19.2e9, c=2e9,    C=51e9,  ec=0.10, EC=6.02),
}

ICI_LINKS_PER_CHIP = 4       # v5e: 4 usable ICI links


@dataclass
class RatioReport:
    name: str
    e: float          # per-chip injection demand, bytes/s
    c: float          # per-chip link capacity, bytes/s
    E: float          # aggregate demand across the bisection, bytes/s
    C: float          # bisection capacity, bytes/s
    ec: float
    EC: float
    balanced: bool
    bound: str        # "local" | "global" | "compute"

    def perf_bound(self) -> float:
        """Eqn. 1: max(e/c, E/C); > 1 means communication-throttled."""
        return max(self.ec, self.EC)


def swallow_ec() -> RatioReport:
    """The paper's own numbers (validates our formula against Tab. III)."""
    t = SWALLOW_TABLE_III["Swallow"]
    return RatioReport("swallow-480", e=t["e"] / 8, c=t["c"] / 8,
                       E=t["e"] / 8 * 480, C=t["C"] / 8 * 480 / 16,
                       ec=t["ec"], EC=t["EC"][1],
                       balanced=False, bound="global")


def analyze_cell(name: str, wire_bytes_per_device: float,
                 compute_seconds: float, n_chips: int,
                 mesh_shape: Dict[str, int]) -> RatioReport:
    """e/c & E/C for a dry-run cell.

    e: bytes/s the chip must inject to not stall the step's compute.
    c: per-chip ICI capacity.  E: all chips' demand crossing the mesh
    bisection (approximated as half of total traffic); C: bisection links.
    """
    t = max(compute_seconds, 1e-9)
    e = wire_bytes_per_device / t
    c = ICI_LINKS_PER_CHIP * ICI_BW
    # bisection of a 2-D (data x model) mesh: min dimension's row links
    dims = [v for k, v in mesh_shape.items() if v > 1]
    bisect_links = (min(dims) if dims else 1) * 2  # torus wrap
    E = e * n_chips / 2.0
    C = bisect_links * ICI_BW * (n_chips ** 0.5)
    ec = e / c
    EC = E / max(C, 1e-9)
    bound = "compute"
    if ec > 1 or EC > 1:
        bound = "local" if ec >= EC else "global"
    return RatioReport(name, e=e, c=c, E=E, C=C, ec=ec, EC=EC,
                       balanced=(ec <= 1 and EC <= 1), bound=bound)


def format_table(rows) -> str:
    out = [f"{'system':<28} {'e/c':>8} {'E/C':>8} {'balanced':>9} {'bound':>8}"]
    for r in rows:
        out.append(f"{r.name:<28} {r.ec:>8.3f} {r.EC:>8.3f} "
                   f"{str(r.balanced):>9} {r.bound:>8}")
    return "\n".join(out)
