"""Attribute collective wire bytes to model ops via HLO metadata op_name."""
import re
import sys
from collections import defaultdict

sys.path.insert(0, "src")
from repro.analysis import hlo  # noqa: E402


def main(path):
    text = open(path).read()
    mod = hlo.parse(text)
    mult = hlo._multipliers(mod)

    # re-scan for metadata on collective lines
    meta_by_name = {}
    for line in text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+) = ", line)
        if not m:
            continue
        om = re.search(r'op_name="([^"]+)"', line)
        if om:
            meta_by_name[m.group(1)] = om.group(1)

    agg = defaultdict(float)
    cnt = defaultdict(int)
    for c in mod.collectives:
        meta = meta_by_name.get(c.name, "?")
        # trim to the interesting tail
        key = (c.op + " | " + "/".join(meta.split("/")[-3:]))[:140]
        mul = mult.get(c.comp, 1.0)
        agg[key] += mul * c.wire_bytes()
        cnt[key] += int(mul)
    for k, v in sorted(agg.items(), key=lambda x: -x[1])[:35]:
        print(f"{v/1e9:10.2f} GB  n={cnt[k]:6d}  {k}")


if __name__ == "__main__":
    main(sys.argv[1])
