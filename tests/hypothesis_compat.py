"""Optional-hypothesis shim: property tests skip cleanly when the
library is absent (it is not in the base image; CI installs it from
requirements.txt).

Usage in a test module:

    from hypothesis_compat import given, settings, st

Example-based tests in the same module keep running either way; tests
decorated with the stub ``@given`` individually report SKIPPED.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — CI installs hypothesis
    HAVE_HYPOTHESIS = False

    def settings(**kw):
        return lambda f: f

    def given(*a, **kw):
        def deco(f):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return deco

    class _Strategies:
        """Accepts any strategy constructor at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _Strategies()
