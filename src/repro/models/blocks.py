"""Per-layer block assembly: norm -> mixer -> residual -> norm -> FFN/MoE.

Layer kinds: attn / local (GQA attention), mla (DeepSeek latent attention),
rglru (Griffin recurrent block), rwkv6 (complete RWKV layer incl. its own
channel-mix).  gemma2-style post-norms supported via cfg.post_norm.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, MLA, RGLRU, RWKV6
from repro.models import attention, mla as mla_mod, modules as nn, moe as moe_mod
from repro.models import rglru as rglru_mod, rwkv6 as rwkv6_mod


def init(key, cfg, kind: str, is_moe: bool, dtype):
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": nn.rmsnorm_init(cfg.d_model)}
    if kind in (ATTN, LOCAL):
        p["attn"] = attention.init(ks[0], cfg, dtype)
    elif kind == MLA:
        p["mla"] = mla_mod.init(ks[0], cfg, dtype)
    elif kind == RGLRU:
        p["rglru"] = rglru_mod.init(ks[0], cfg, dtype)
    elif kind == RWKV6:
        p["rwkv"] = rwkv6_mod.init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)

    p["ln2"] = nn.rmsnorm_init(cfg.d_model)
    if kind != RWKV6:
        if is_moe:
            p["moe"] = moe_mod.init(ks[1], cfg, dtype)
            if cfg.moe.n_shared:
                shared_cfg = cfg.replace(
                    d_ff=cfg.moe.d_ff_expert * cfg.moe.n_shared)
                p["shared"] = nn.ffn_init(ks[2], shared_cfg, dtype)
        else:
            p["ffn"] = nn.ffn_init(ks[1], cfg, dtype)
    if cfg.post_norm:
        p["ln1_post"] = nn.rmsnorm_init(cfg.d_model)
        p["ln2_post"] = nn.rmsnorm_init(cfg.d_model)
    return p


from repro.parallel.sharding import logical_constraint
from repro.parallel.collectives import gather_seq


def _seq_sp(y):
    """Force row-parallel partial sums to land directly in the sequence-
    sharded residual layout (reduce-scatter, not all-reduce + slice)."""
    if y.ndim == 3 and y.shape[1] > 1:
        return logical_constraint(y, "batch", "seq_sp", None)
    return y


def _post(p, cfg, name, y):
    if cfg.post_norm:
        y = nn.rmsnorm(y, p[name]["scale"], cfg.norm_eps)
    return _seq_sp(y)


def _ffn_part(p, cfg, x):
    """FFN or MoE (+ shared experts). Returns (out, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        out, aux = moe_mod.apply(p["moe"], cfg, x)
        if "shared" in p:
            shared_cfg = cfg.replace(d_ff=cfg.moe.d_ff_expert * cfg.moe.n_shared)
            out = out + nn.ffn_apply(p["shared"], shared_cfg, x)
    else:
        out = nn.ffn_apply(p["ffn"], cfg, x)
    return out, aux


def apply(p, cfg, kind: str, x, *, angles, mode: str, impl=None):
    """Full-sequence path (train / prefill).

    Returns (x, cache_out, aux).  cache_out is None in train mode; in
    prefill mode it is the layer's decode-ready cache contribution
    *before* max-len padding (the LM pads/stacks).
    """
    aux = jnp.zeros((), jnp.float32)
    # norm in the sequence-sharded domain (Megatron-SP): the AG to full
    # sequence happens *after* the norm, so its backward is a cheap
    # reduce-scatter instead of an fp32 (B,S,D) all-reduce
    h = _seq_sp(nn.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps))
    if kind in (MLA, RGLRU, RWKV6):
        # these mixers need the full sequence locally (latent projections,
        # conv/token-shift halos); attention/FFN gather inside their own
        # fused column_parallel shard_maps instead
        h = gather_seq(h)
    cache_out: Any = None

    if kind in (ATTN, LOCAL):
        out, kv = attention.apply(p["attn"], cfg, h, kind=kind,
                                  angles=angles, impl=impl)
        if mode == "prefill":
            cache_out = kv
        x = x + _post(p, cfg, "ln1_post", out)
    elif kind == MLA:
        out, lat = mla_mod.apply(p["mla"], cfg, h, angles=angles, impl=impl)
        if mode == "prefill":
            cache_out = lat
        x = x + _post(p, cfg, "ln1_post", out)
    elif kind == RGLRU:
        out, rcache = rglru_mod.apply(p["rglru"], cfg, h, impl=impl)
        if mode == "prefill":
            cache_out = rcache
        x = x + _post(p, cfg, "ln1_post", out)
    elif kind == RWKV6:
        cache0 = rwkv6_mod.cache_init(cfg, x.shape[0], x.dtype)
        out, c1 = rwkv6_mod.time_mix(p["rwkv"], cfg, h, cache0, impl=impl)
        x = x + _seq_sp(out)
        h2 = nn.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
        out2, c2 = rwkv6_mod.channel_mix(p["rwkv"], cfg, h2, c1)
        x = x + _seq_sp(out2)
        if mode == "prefill":
            cache_out = c2
        return x, cache_out, aux
    else:
        raise ValueError(kind)

    h2 = _seq_sp(nn.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps))
    out2, aux = _ffn_part(p, cfg, h2)
    x = x + _post(p, cfg, "ln2_post", out2)
    return x, cache_out, aux


def apply_decode(p, cfg, kind: str, x, cache, pos, *, angles):
    """Single-token decode path. Returns (x, new_cache)."""
    h = nn.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
    if kind in (ATTN, LOCAL):
        out, cache = attention.apply_decode(p["attn"], cfg, h, cache, pos,
                                            kind=kind, angles=angles)
        x = x + _post(p, cfg, "ln1_post", out)
    elif kind == MLA:
        out, cache = mla_mod.apply_decode(p["mla"], cfg, h, cache, pos,
                                          angles=angles)
        x = x + _post(p, cfg, "ln1_post", out)
    elif kind == RGLRU:
        out, cache = rglru_mod.apply_decode(p["rglru"], cfg, h, cache)
        x = x + _post(p, cfg, "ln1_post", out)
    elif kind == RWKV6:
        out, c1 = rwkv6_mod.time_mix(p["rwkv"], cfg, h, cache)
        x = x + out
        h2 = nn.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
        out2, cache = rwkv6_mod.channel_mix(p["rwkv"], cfg, h2, c1)
        x = x + out2
        return x, cache
    else:
        raise ValueError(kind)

    h2 = nn.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
    out2, _ = _ffn_part(p, cfg, h2)
    x = x + _post(p, cfg, "ln2_post", out2)
    return x, cache


def apply_decode_paged(p, cfg, kind: str, x, pool, block_tables, pos, *,
                       angles):
    """Single-token decode against a paged KV pool. Returns (x, pool).

    Only global attention pages cleanly (a sliding-window ring cache and
    the recurrent states are constant-size per sequence — nothing to
    page); the serving engine asserts an attention-only config.
    """
    if kind != ATTN:
        raise NotImplementedError(
            f"paged decode supports global-attention layers only, got {kind!r}")
    h = nn.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
    out, pool = attention.apply_decode_paged(p["attn"], cfg, h, pool,
                                             block_tables, pos,
                                             angles=angles)
    x = x + _post(p, cfg, "ln1_post", out)
    h2 = nn.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
    out2, _ = _ffn_part(p, cfg, h2)
    x = x + _post(p, cfg, "ln2_post", out2)
    return x, pool


def apply_prefill_paged(p, cfg, kind: str, x, pool, block_row, start,
                        n_valid, *, angles):
    """Suffix prefill against a paged KV pool (prefix-cache hit): x
    (1,W,D) tokens at positions start..start+W-1 attend the cached
    prefix through the block row.  Returns (x, pool)."""
    if kind != ATTN:
        raise NotImplementedError(
            f"paged prefill supports global-attention layers only, "
            f"got {kind!r}")
    h = nn.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
    out, pool = attention.apply_prefill_paged(p["attn"], cfg, h, pool,
                                              block_row, start, n_valid,
                                              angles=angles)
    x = x + _post(p, cfg, "ln1_post", out)
    h2 = nn.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
    out2, _ = _ffn_part(p, cfg, h2)
    x = x + _post(p, cfg, "ln2_post", out2)
    return x, pool


def paged_cache_init(cfg, kind: str, n_pages: int, page_size: int, dtype):
    if kind != ATTN:
        raise NotImplementedError(
            f"paged KV pools exist for global attention only, got {kind!r}")
    return attention.paged_cache_init(cfg, n_pages, page_size, dtype)


def paged_cache_from_prefill(cfg, kind: str, pool, raw, block_row):
    """Scatter one sequence's prefill kv into its pages."""
    if kind != ATTN:
        raise NotImplementedError(kind)
    k, v = raw
    return attention.paged_cache_from_prefill(pool, k, v, block_row)


def cache_init(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == ATTN:
        return attention.cache_init(cfg, batch, max_len, None, dtype)
    if kind == LOCAL:
        return attention.cache_init(cfg, batch, max_len, cfg.sliding_window,
                                    dtype)
    if kind == MLA:
        return mla_mod.cache_init(cfg, batch, max_len, dtype)
    if kind == RGLRU:
        return rglru_mod.cache_init(cfg, batch, dtype)
    if kind == RWKV6:
        return rwkv6_mod.cache_init(cfg, batch, dtype)
    raise ValueError(kind)


def cache_from_prefill(cfg, kind: str, raw, max_len: int):
    """Convert the prefill cache contribution into decode-ready form."""
    if kind == ATTN:
        k, v = raw
        return attention.cache_from_prefill(k, v, None, max_len)
    if kind == LOCAL:
        k, v = raw
        return attention.cache_from_prefill(k, v, cfg.sliding_window, max_len)
    if kind == MLA:
        ckv, k_rope = raw
        return mla_mod.cache_from_prefill(ckv, k_rope, max_len)
    return raw  # rglru / rwkv caches are already decode-ready
