"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are deliberately naive — materialize full score matrices, step the
recurrences one timestep at a time — and are the ground truth for the
kernel allclose sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None):
    """q,k,v (B,S,H,hd) (k/v pre-expanded to H). Full-scores oracle."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window is not None:
        ok &= (i - j) < window
    s = jnp.where(ok[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention(q, k, v, pos, *, scale=None, softcap=None):
    """q (B,H,hd); k,v (B,T,Kv,hd); pos scalar. Valid slots are <= pos."""
    B, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, Kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = jnp.arange(k.shape[1]) <= pos
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos,
                           *, scale=None, softcap=None):
    """Paged-KV oracle: gather pages, then dense masked decode attention.

    q (B,H,hd); k_pages/v_pages (P,ps,Kv,hd); block_tables (B,nmax) int32
    physical page ids; pos (B,) int32 — slots <= pos[b] are valid.
    """
    B, H, hd = q.shape
    ps, Kv = k_pages.shape[1], k_pages.shape[2]
    nmax = block_tables.shape[1]
    T = nmax * ps
    G = H // Kv
    scale = hd ** -0.5 if scale is None else scale
    k = k_pages[block_tables].reshape(B, T, Kv, hd)
    v = v_pages[block_tables].reshape(B, T, Kv, hd)
    qg = q.reshape(B, Kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = jnp.arange(T)[None, :] <= pos[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def rglru_scan(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t, stepwise. a,b (B,S,W) f32; h0 (B,W)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    hT, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                     jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hT


def rwkv6_scan(r, k, v, lw, u, S0):
    """Stepwise RWKV-6 wkv. r,k,v,lw (B,S,H,K); u (H,K); S0 (B,H,K,V)."""
    def step(S, inp):
        rt, kt, vt, lwt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, o
    seq = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
                for t in (r, k, v, lw))
    S_T, os = jax.lax.scan(step, S0.astype(jnp.float32), seq)
    return jnp.moveaxis(os, 0, 1), S_T


def moe_gemm(x, w):
    """Grouped GEMM: x (E,C,D) @ w (E,D,F) -> (E,C,F), fp32 accumulate."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
