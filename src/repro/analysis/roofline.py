"""Three-term roofline from a dry-run cell (Swallow Eqn. 1 at pod scale).

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HBM_bytes_per_chip / HBM_bw
    collective = wire_bytes_per_device / link_bw

The collective term *is* the paper's E/C methodology: Swallow Tab. III
reports communication-to-computation ratios; here the same ratio appears
as t_collective / t_compute, derived from the compiled HLO instead of
link datasheets.  MODEL_FLOPS / HLO_FLOPs exposes remat/padding waste
exactly as the paper's e/c exposes injection overhead.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.analysis import flops as flops_mod, hlo as hlo_mod
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    # counters
    hlo_flops_global: float
    hlo_flops_raw_costanalysis: Optional[float]
    hbm_bytes_per_chip: float
    wire_bytes_per_device: float
    model_flops: float
    useful_ratio: float            # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float       # t_model / max(term) — the score
    step_time_bound: float         # max of the three terms
    collective_detail: Dict[str, float]

    def to_dict(self):
        return asdict(self)


def analyze(cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
            n_chips: int, tp: int, hlo_text: Optional[str] = None,
            cost_analysis: Optional[dict] = None,
            memory_analysis=None) -> Roofline:
    cost = flops_mod.step_costs(cfg, shape, n_chips, tp=tp)

    wire = 0.0
    detail: Dict[str, float] = {}
    if hlo_text is not None:
        summ = hlo_mod.collective_summary(hlo_text)
        wire = summ["total_wire_bytes_per_device"]
        detail = dict(summ["wire_bytes_per_device"])
        detail["op_counts"] = summ["op_counts"]

    t_compute = cost.flops_total / (n_chips * PEAK_FLOPS_BF16)
    t_memory = cost.hbm_bytes_per_chip / HBM_BW
    t_collective = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    t_model = cost.model_flops / (n_chips * PEAK_FLOPS_BF16)

    raw = None
    if cost_analysis:
        raw = float(cost_analysis.get("flops", 0.0))

    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=n_chips,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        dominant=dominant,
        hlo_flops_global=cost.flops_total,
        hlo_flops_raw_costanalysis=raw,
        hbm_bytes_per_chip=cost.hbm_bytes_per_chip,
        wire_bytes_per_device=wire,
        model_flops=cost.model_flops,
        useful_ratio=cost.model_flops / max(cost.flops_total, 1.0),
        roofline_fraction=t_model / max(bound, 1e-12),
        step_time_bound=bound,
        collective_detail=detail)


def format_table(rows) -> str:
    hdr = (f"{'arch':<18} {'shape':<12} {'mesh':<7} "
           f"{'t_comp(s)':>10} {'t_mem(s)':>10} {'t_coll(s)':>10} "
           f"{'bound':>10} {'dom':>6} {'useful':>7} {'roofline':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<18} {r.shape:<12} {r.mesh:<7} "
            f"{r.t_compute:>10.4f} {r.t_memory:>10.4f} "
            f"{r.t_collective:>10.4f} {r.step_time_bound:>10.4f} "
            f"{r.dominant:>6.6s} {r.useful_ratio:>7.3f} "
            f"{r.roofline_fraction:>9.3f}")
    return "\n".join(lines)
