"""Parse compiled HLO text: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` counts a while body exactly once, and jax
scans lower to whiles — so a naive sum over the HLO text undercounts every
per-layer collective by the layer count.  This parser:

  1. splits the HLO module into computations,
  2. records every instruction's result byte-size,
  3. builds the call graph (while body/cond, fusion calls, to_apply,
     conditionals) with multipliers from ``known_trip_count`` attributes,
  4. sums *wire bytes per device* for every collective, scaled by the
     product of enclosing trip counts.

Wire-byte model (ring algorithms, g = replica-group size):
  all-gather        (g-1)/g * output_bytes
  reduce-scatter    (g-1)/g * input_bytes
  all-reduce        2(g-1)/g * input_bytes
  all-to-all        (g-1)/g * input_bytes
  collective-permute  input_bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+"
                       r"([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{\s*"n"\s*:\s*"?(\d+)')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=\[")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Collective:
    comp: str
    op: str
    name: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    attrs: str

    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        f = (g - 1) / g
        if self.op == "all-gather":
            return f * self.result_bytes
        if self.op == "reduce-scatter":
            return f * self.operand_bytes
        if self.op == "all-reduce":
            return 2 * f * self.operand_bytes
        if self.op == "all-to-all":
            return f * self.operand_bytes
        if self.op in ("collective-permute", "collective-broadcast"):
            return float(self.operand_bytes)
        return 0.0


@dataclass
class HLOModule:
    comp_instr_bytes: Dict[str, Dict[str, int]] = field(default_factory=dict)
    collectives: List[Collective] = field(default_factory=list)
    calls: Dict[str, List[Tuple[str, int]]] = field(default_factory=dict)
    entry: Optional[str] = None


def parse(hlo_text: str) -> HLOModule:
    mod = HLOModule()
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: "name (params...) -> type {" (no '=' before '(')
        if stripped.endswith("{") and "->" in stripped:
            head = stripped.split("(")[0]
            if "=" not in head:
                m = _COMP_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    mod.comp_instr_bytes.setdefault(cur, {})
                    mod.calls.setdefault(cur, [])
                    if stripped.startswith("ENTRY"):
                        mod.entry = cur
                    continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, type_str, op, rest = mi.groups()
        rbytes = shape_bytes(type_str)
        mod.comp_instr_bytes[cur][name] = rbytes

        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", rest)
            cond = re.search(r"condition=%?([\w\.\-]+)", rest)
            trip = _TRIP_RE.search(rest)
            n = int(trip.group(1)) if trip else 1
            if body:
                mod.calls[cur].append((body.group(1), n))
            if cond:
                mod.calls[cur].append((cond.group(1), n + 1))
        elif op in ("fusion", "call", "custom-call", "reduce", "sort",
                    "map", "scatter", "select-and-scatter", "reduce-window",
                    "all-reduce", "reduce-scatter"):
            for cm in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", rest):
                mod.calls[cur].append((cm.group(1), 1))
        elif op == "conditional":
            for cm in re.finditer(r"%([\w\.\-]+)", rest):
                pass  # branch computations contribute ~no collectives

        if op in COLLECTIVES:
            # operand bytes: look up operand instruction sizes
            args = rest.split("),")[0]
            operand_bytes = 0
            for om in re.finditer(r"%([\w\.\-]+)", args.split("channel_id")[0]):
                operand_bytes += mod.comp_instr_bytes[cur].get(om.group(1), 0)
            g = 1
            gb = _GROUPS_BRACE_RE.search(rest)
            gi = _GROUPS_IOTA_RE.search(rest)
            if gb:
                g = len(gb.group(1).split(","))
            elif gi:
                dims = [int(x) for x in gi.group(1).split(",")]
                # iota format [n_groups, group_size(, ...)]: product of all
                # dims after the first = group size
                g = 1
                for d in dims[1:]:
                    g *= d
                if len(dims) == 1:
                    g = dims[0]
            mod.collectives.append(Collective(
                comp=cur, op=op, name=name, result_bytes=rbytes,
                operand_bytes=operand_bytes, group_size=g, attrs=rest[:200]))
    return mod


def _multipliers(mod: HLOModule) -> Dict[str, float]:
    """Execution-count multiplier per computation (from ENTRY)."""
    mult: Dict[str, float] = defaultdict(float)
    if mod.entry is None:
        return {c: 1.0 for c in mod.comp_instr_bytes}
    stack = [(mod.entry, 1.0)]
    seen_depth = 0
    while stack and seen_depth < 100000:
        seen_depth += 1
        comp, m = stack.pop()
        mult[comp] += m
        for callee, n in mod.calls.get(comp, []):
            if callee in mod.comp_instr_bytes:
                stack.append((callee, m * n))
    return dict(mult)


def collective_summary(hlo_text: str) -> dict:
    """Total per-device collective wire bytes, trip-count-aware."""
    mod = parse(hlo_text)
    mult = _multipliers(mod)
    per_op: Dict[str, float] = defaultdict(float)
    raw_operand: Dict[str, float] = defaultdict(float)
    count: Dict[str, int] = defaultdict(int)
    for c in mod.collectives:
        m = mult.get(c.comp, 1.0)
        per_op[c.op] += m * c.wire_bytes()
        raw_operand[c.op] += m * max(c.operand_bytes, c.result_bytes)
        count[c.op] += int(m) if m >= 1 else 1
    return {
        "wire_bytes_per_device": dict(per_op),
        "operand_bytes_per_device": dict(raw_operand),
        "op_counts": dict(count),
        "total_wire_bytes_per_device": float(sum(per_op.values())),
        "total_operand_bytes_per_device": float(sum(raw_operand.values())),
    }
