"""Swallow §VIII: nOS — a nano-OS for many-core, as a mesh-slice scheduler.

nOS abstracts thread creation, mapping, network configuration and energy
optimisation.  At pod scale the analogous runtime owns: mesh slicing
(placement), job admission (the paper's "multiple non-interacting
applications"), per-slice energy accounting, and restart orchestration.
The scheduler is pure host-side logic — unit-testable, no devices
needed — and produces placements that ``jax.make_mesh`` sub-meshes can
realise.

Placement policy (paper-faithful): jobs are independent (C1), so slices
never share chips; allocation is first-fit over whole "data" rows so the
"model" axis (the high-bandwidth dimension) is never split between
tenants — locality exactly as §II-B argues.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import energy as energy_mod


@dataclass
class Job:
    name: str
    rows_needed: int                   # data-axis rows (model axis is whole)
    steps: int = 0
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    rows: Tuple[int, ...] = ()
    state: str = "pending"             # pending|running|done|failed


@dataclass
class NOS:
    """First-fit row scheduler over a (data x model) pod."""
    data_rows: int = 16
    model_cols: int = 16
    jobs: Dict[str, Job] = field(default_factory=dict)
    _free: List[int] = field(default_factory=list)

    def __post_init__(self):
        self._free = list(range(self.data_rows))

    # -- admission -----------------------------------------------------------
    def submit(self, job: Job) -> bool:
        job.submitted_at = job.submitted_at or time.time()
        self.jobs[job.name] = job
        return self._try_place(job)

    def _try_place(self, job: Job) -> bool:
        if job.state != "pending" or job.rows_needed > len(self._free):
            return False
        job.rows = tuple(sorted(self._free[:job.rows_needed]))
        self._free = self._free[job.rows_needed:]
        job.state = "running"
        job.started_at = time.time()
        return True

    def finish(self, name: str, state: str = "done"):
        job = self.jobs[name]
        self._free = sorted(self._free + list(job.rows))
        job.rows = ()
        job.state = state
        # admit pending jobs in FIFO order
        for j in sorted(self.jobs.values(), key=lambda j: j.submitted_at):
            if j.state == "pending":
                self._try_place(j)

    def fail_rows(self, rows: List[int]):
        """Hardware failure: evict jobs touching the rows, quarantine them."""
        evicted = []
        for job in self.jobs.values():
            if job.state == "running" and set(job.rows) & set(rows):
                job.state = "pending"
                self._free = sorted(set(self._free) | set(job.rows))
                job.rows = ()
                evicted.append(job.name)
        self._free = [r for r in self._free if r not in rows]
        for j in sorted(self.jobs.values(), key=lambda j: j.submitted_at):
            if j.state == "pending":
                self._try_place(j)
        return evicted

    # -- accounting -----------------------------------------------------------
    def utilisation(self) -> float:
        used = self.data_rows - len(self._free)
        return used / self.data_rows

    def power_estimate_w(self, active_w: float = 200.0,
                         idle_w: float = 60.0) -> float:
        """Fleet power (Fig. 8/9 logic): active slices at TDP-ish, free
        rows idle — energy proportionality at the allocation level."""
        used = self.data_rows - len(self._free)
        return (used * active_w + len(self._free) * idle_w) * self.model_cols

    def placement_table(self) -> str:
        rows = []
        for j in self.jobs.values():
            rows.append(f"{j.name:<16} {j.state:<8} rows={list(j.rows)}")
        rows.append(f"free rows: {self._free}")
        return "\n".join(rows)
