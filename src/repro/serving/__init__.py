"""Swallow §III + §VIII + §X-B composed: the serving subsystem.

  paged_kv   — §X-B striped store applied to KV pages (host allocator;
               page owner = core/memory_server.striped_owner)
  scheduler  — §III farmer-worker continuous batching with §VIII-style
               priced admission and page-pressure preemption
  engine     — the device-side loop: paged pools, block tables, one
               jitted decode step per batch refill

Entry points: ``repro.launch.serve --engine paged`` and
``benchmarks/serve_trace.py``; docs in docs/SERVING.md.
"""
from repro.serving.engine import PagedEngine
from repro.serving.paged_kv import NULL_PAGE, PageAllocator
from repro.serving.scheduler import (ContinuousBatchScheduler, Request,
                                     StepPlan)

__all__ = ["PagedEngine", "PageAllocator", "NULL_PAGE",
           "ContinuousBatchScheduler", "Request", "StepPlan"]
