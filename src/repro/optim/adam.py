"""AdamW in pure JAX with per-arch state-dtype policies.

opt_state_dtype: "float32" | "bfloat16" | "int8" (blockwise-quantized,
see optim/quant.py).  Moments are stored as flat per-leaf lists so that
int8 QTensor leaves coexist with arrays; quantized moments are *fully
sharded* over every mesh axis (ZeRO-1-style) — the memory policy that
lets deepseek-v3 fit a 256-chip pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import quant


@dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"   # float32 | bfloat16 | int8


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: List[Any]     # per-leaf moments (arrays or QTensors), params order
    v: List[Any]


def _encode(x, dtype: str, *, second_moment: bool):
    """Moments stay PARAM-SHAPED (sharded like the parameter plus extra
    ZeRO sharding on a data-replicated dim — see state_specs).  A flat
    fully-sharded layout was tried first and made GSPMD all-gather entire
    moment tensors each step (6.5 TB/step for deepseek: EXPERIMENTS.md
    §Perf iteration 6)."""
    if dtype == "float32":
        return x.astype(jnp.float32)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if dtype == "int8":
        if quant.aligned_ok(x.shape):
            return quant.quantize_aligned(x, sqrt_encode=second_moment)
        return quant.quantize(x, sqrt_encode=second_moment)
    raise ValueError(dtype)


def _decode(x, shape):
    if isinstance(x, quant.QTensor):
        return quant.dequantize(x)
    return x.astype(jnp.float32)


def init(params, cfg: AdamConfig) -> AdamState:
    leaves = jax.tree.leaves(params)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    m = [_encode(zeros(p), cfg.state_dtype, second_moment=False)
         for p in leaves]
    v = [_encode(zeros(p), cfg.state_dtype, second_moment=True)
         for p in leaves]
    return AdamState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def _augment_spec(shape, spec: P, batch_axes) -> P:
    """ZeRO-1: extend the param spec with the (grad-replicated) batch axes
    on the largest still-unsharded, divisible dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if isinstance(e, str):
            used.add(e)
        elif isinstance(e, tuple):
            used.update(e)
    batch_axes = {a: s for a, s in batch_axes.items() if a not in used}
    n = 1
    for a in batch_axes:
        n *= batch_axes[a]
    if n <= 1:
        return P(*entries)
    best, best_size = None, 0
    for i, (d, e) in enumerate(zip(shape, entries)):
        if e is None and d % n == 0 and d > best_size:
            best, best_size = i, d
    if best is not None:
        entries[best] = tuple(batch_axes)
    return P(*entries)


def state_specs(params, cfg: AdamConfig, param_spec_tree):
    """PartitionSpec pytree matching AdamState.

    Moments are param-shaped with the param's spec AUGMENTED by the batch
    ("pod","data") axes on a grad-replicated dim: the update math is then
    completely local (grads are replicated over those axes), and only the
    updated params are re-gathered — textbook ZeRO-1 without any moment
    movement.
    """
    from repro.parallel.sharding import current_env
    env = current_env()
    if env is None:
        batch_axes = {}
    else:
        batch_axes = {a: env.mesh.shape[a] for a in ("pod", "data")
                      if a in env.mesh.axis_names}
    all_axes = tuple(env.mesh.axis_names) if env is not None else ()
    flat2d = P(all_axes, None) if all_axes else P()
    flat1d = P(all_axes) if all_axes else P()

    p_leaves = jax.tree.leaves(params)
    s_leaves = jax.tree.leaves(param_spec_tree,
                               is_leaf=lambda x: isinstance(x, P))

    def moment_spec(p, s, sq):
        aug = _augment_spec(p.shape, s, batch_axes)
        if cfg.state_dtype == "int8":
            if quant.aligned_ok(p.shape):
                nb_scale_spec = P(*(list(aug)[:-1] + [None]))
                return quant.QTensor(q=aug, scale=nb_scale_spec,
                                     shape=p.shape, sqrt_encoded=sq,
                                     mode="aligned")
            return quant.QTensor(q=flat2d, scale=flat1d, shape=p.shape,
                                 sqrt_encoded=sq, mode="flat")
        return aug

    m = [moment_spec(p, s, False) for p, s in zip(p_leaves, s_leaves)]
    v = [moment_spec(p, s, True) for p, s in zip(p_leaves, s_leaves)]
    return AdamState(step=P(), m=m, v=v)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads, state: AdamState, params, *, lr, cfg: AdamConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)

    new_p, new_m, new_v = [], [], []
    for p, g, m_enc, v_enc in zip(p_leaves, g_leaves, state.m, state.v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * _decode(m_enc, p.shape) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_enc, p.shape) + (1 - cfg.b2) * jnp.square(g)
        delta = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay and p.ndim >= 2:   # no decay on norms/biases
            delta = delta + cfg.weight_decay * pf
        new_p.append((pf - lr * delta).astype(p.dtype))
        new_m.append(_encode(m, cfg.state_dtype, second_moment=False))
        new_v.append(_encode(v, cfg.state_dtype, second_moment=True))

    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    return params_out, AdamState(step, new_m, new_v), {"grad_norm": gnorm}


# -- schedules --------------------------------------------------------------
def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup, warm, cos)
