"""GQA attention: reference, blocked-flash (lax.scan), and decode paths.

Sharding strategy (chosen from dry-run HLO attribution, see EXPERIMENTS.md
§Perf): the attention core runs in *H-space* — q sharded on query heads
over the "model" axis, k/v all-gathered (replicated) and expanded to H
before the core.  All block-scan einsums are then shard-local: zero
collectives inside the flash loops (one AG for k/v + the Megatron-SP
AG/RS per layer remain).  Non-divisible head counts (40, 28, 10 over
TP=16) pad intermediates only.

Three implementations (cfg.impl):
  ref     — naive (S,S) scores; oracle for tests.
  blocked — q-block x kv-block online-softmax scan; bounded memory; the
            dry-run lowers this one.  Sliding-window layers slice only the
            kv window per q block (sub-quadratic compute in the HLO).
  pallas  — TPU kernel (kernels/flash_attention.py) via kernels.ops.

Caches are stored FLAT (B, T, Kv*hd) so the "model" axis always divides
top-level cache shardings (kv-head counts like 8 don't divide TP=16).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.parallel.sharding import logical_constraint

NEG_INF = -2.0 ** 30  # large-negative that survives bf16 arithmetic


class AttnCache(NamedTuple):
    # FLAT (B, T, Kv*hd); T = max len (global) or window (local)
    k: jnp.ndarray
    v: jnp.ndarray


def init(key, cfg, dtype):
    ks = jax.random.split(key, 6)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": nn.dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": nn.dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": nn.dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": nn.dense_init(ks[3], cfg.n_heads * hd, d, dtype,
                            scale=1.0 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _scale(cfg) -> float:
    return cfg.attn_logit_scale if cfg.attn_logit_scale is not None \
        else cfg.head_dim ** -0.5


def expand_kv(k, n_heads: int):
    """(B,T,Kv,hd) -> (B,T,H,hd) by repeating each kv head G times."""
    B, T, Kv, hd = k.shape
    G = n_heads // Kv
    if G == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, Kv, G, hd))
    return k.reshape(B, T, n_heads, hd)


def _qkv(p, cfg, x, angles):
    """Project (fused column-parallel) + head-split + qk-norm + rope.

    x arrives sequence-sharded; one AG inside column_parallel.  Returns
    q (B,S,H,hd) sharded on heads, k/v (B,S,Kv,hd) replicated over the
    model axis so the blocked core stays shard-local.
    """
    from repro.parallel.collectives import column_parallel
    qf, kf, vf = column_parallel(x, [p["wq"], p["wk"], p["wv"]])
    q = _split_heads(qf, cfg.n_heads, cfg.head_dim)
    k = _split_heads(kf, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(vf, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = nn.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope and angles is not None:
        q = nn.apply_rope(q, angles)
        k = nn.apply_rope(k, angles)
    q = logical_constraint(q, "batch", None, "heads", None)
    k = logical_constraint(k, "batch", None, None, None)
    v = logical_constraint(v, "batch", None, None, None)
    return q, k, v


# ---------------------------------------------------------------------------
# reference implementation — full (S, S) scores, H-space
# ---------------------------------------------------------------------------
def _mask_bias(S: int, causal: bool, window: Optional[int]) -> jnp.ndarray:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window is not None:
        ok &= (i - j) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend_ref(q, k, v, *, causal, window, scale, softcap):
    """q (B,S,H,hd); k,v (B,S,H,hd) pre-expanded -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bthd->bhqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = nn.softcap(s, softcap)
    s = s + _mask_bias(S, causal, window)[None, None]
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", w.astype(q.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o


# ---------------------------------------------------------------------------
# blocked flash — bounded memory, scan over q and kv blocks (H-space)
# ---------------------------------------------------------------------------
def attend_blocked(q, k, v, *, causal, window, scale, softcap,
                   block_q: int, block_kv: int):
    B, S, H, hd = q.shape
    bq = min(block_q, S)
    while S % bq:
        bq -= 1
    bkv = min(block_kv, S)
    while S % bkv:
        bkv -= 1
    nq, nkv = S // bq, S // bkv

    if window is not None and window + bq < S and causal:
        # sliding-window fast path: slices just the kv window per q block
        return _attend_local_blocked(q, k, v, causal=causal, window=window,
                                     scale=scale, softcap=softcap, bq=bq)

    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)     # (nq,B,bq,H,hd)
    kb = jnp.moveaxis(k.reshape(B, nkv, bkv, H, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nkv, bkv, H, hd), 1, 0)

    def q_block(qi, q_blk):
        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, hd), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            s = jnp.einsum("bqhd,bthd->bhqt", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = nn.softcap(s, softcap)
            iq = qi * bq + jnp.arange(bq)
            jk = ki * bkv + jnp.arange(bkv)
            ok = jnp.ones((bq, bkv), bool)
            if causal:
                ok &= jk[None, :] <= iq[:, None]
            if window is not None:
                ok &= (iq[:, None] - jk[None, :]) < window
            s = jnp.where(ok[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqt,bthd->bhqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nkv), kb, vb))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return jnp.moveaxis(out, 1, 2)  # (B, bq, H, hd)

    def scan_q(_, inputs):
        qi, q_blk = inputs
        return None, q_block(qi, q_blk)

    _, outs = jax.lax.scan(scan_q, None, (jnp.arange(nq), qb))
    o = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    return o.astype(q.dtype)


def _attend_local_blocked(q, k, v, *, causal, window, scale, softcap, bq):
    """Sliding-window attention: per q block, slice only the kv window.

    Compute in the HLO is O(S * (window + bq)) — genuinely sub-quadratic,
    which is what makes recurrentgemma long_500k-eligible.
    """
    B, S, H, hd = q.shape
    nq = S // bq
    span = window + bq
    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)

    def q_block(carry, inputs):
        qi, q_blk = inputs
        start = jnp.clip(qi * bq + bq - span, 0, S - span)
        k_w = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        v_w = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        s = jnp.einsum("bqhd,bthd->bhqt", q_blk, k_w,
                       preferred_element_type=jnp.float32) * scale
        s = nn.softcap(s, softcap)
        iq = qi * bq + jnp.arange(bq)
        jk = start + jnp.arange(span)
        ok = jnp.ones((bq, span), bool)
        if causal:
            ok &= jk[None, :] <= iq[:, None]
        ok &= (iq[:, None] - jk[None, :]) < window
        s = jnp.where(ok[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqt,bthd->bqhd", w.astype(v_w.dtype), v_w,
                       preferred_element_type=jnp.float32)
        return carry, o

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    o = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode — one query against the cache
# ---------------------------------------------------------------------------
# The production cache is sharded over the "model" axis on the TIME dim
# (split-T / flash-decoding): each shard scans its slice and the partial
# online-softmax stats (m, l, acc) merge with (B,H)-sized psums — the
# cache bytes never move.  (The first layout — flat features — made GSPMD
# repartition gigabytes of cache per token whenever kv_heads < TP; see
# EXPERIMENTS.md §Perf iteration 5.)

def _decode_partial(q, k, v, valid, *, scale, softcap, n_kv):
    """Partial attention over a cache slice. q (B,1,H,hd);
    k/v (B,Tl,Kv*hd); valid (Tl,) bool. Returns (m, l, acc)."""
    B, _, H, hd = q.shape
    Tl = k.shape[1]
    Kv = n_kv
    kk = k.reshape(B, Tl, Kv, hd)
    vv = v.reshape(B, Tl, Kv, hd)
    qg = q.reshape(B, Kv, H // Kv, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kk,
                   preferred_element_type=jnp.float32) * scale
    s = nn.softcap(s, softcap)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    m = s.max(-1)                                       # (B,Kv,G)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p.astype(vv.dtype), vv,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def attend_decode_sharded(q, cache: AttnCache, pos, *, window, scale,
                          softcap, n_kv: int, env):
    """Split-T decode via shard_map (cache time-sharded over "model")."""
    from repro.models.moe import _shard_map
    axes = env.resolve("seq_sp")
    axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
    B, _, H, hd = q.shape
    T = cache.k.shape[1]

    def body(q_l, k_l, v_l):
        idx = jax.lax.axis_index(axes[0])
        Tl = k_l.shape[1]
        slots = idx * Tl + jnp.arange(Tl)
        if window is None:
            valid = slots <= pos
        else:
            abs_pos = pos - ((pos - slots) % T)
            valid = (abs_pos >= 0) & (abs_pos > pos - window)
        m, l, acc = _decode_partial(q_l, k_l, v_l, valid, scale=scale,
                                    softcap=softcap, n_kv=n_kv)
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axes)
        acc_g = jax.lax.psum(acc * corr[..., None], axes)
        o = acc_g / jnp.maximum(l_g[..., None], 1e-37)
        return o.astype(q_l.dtype).reshape(q_l.shape[0], 1, H * hd)

    return _shard_map(
        body, mesh=env.mesh,
        in_specs=(env.spec("batch", None, None, None),
                  env.spec("batch", "seq_sp", None),
                  env.spec("batch", "seq_sp", None)),
        out_specs=env.spec("batch", None, None),
        check_vma=False)(q, cache.k, cache.v)


def _split_t_applicable(env, T: int) -> bool:
    if env is None:
        return False
    axes = env.resolve("seq_sp")
    axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
    if len(axes) != 1:
        return False
    n = env.mesh.shape[axes[0]]
    return n > 1 and T % n == 0


def attend_decode(q, cache: AttnCache, pos, *, window, scale, softcap,
                  n_kv: int):
    """q (B,1,H,hd); cache.k/v FLAT (B,T,Kv*hd); pos scalar int32.

    Global cache: slot = t, valid slots are <= pos.
    Local (ring) cache: slot = t % W; valid = slot abs-position in window.
    """
    from repro.parallel.sharding import current_env
    env = current_env()
    if _split_t_applicable(env, cache.k.shape[1]):
        return attend_decode_sharded(q, cache, pos, window=window,
                                     scale=scale, softcap=softcap,
                                     n_kv=n_kv, env=env)
    B, _, H, hd = q.shape
    T = cache.k.shape[1]
    Kv = n_kv
    G = H // Kv
    k = cache.k.reshape(B, T, Kv, hd)
    v = cache.v.reshape(B, T, Kv, hd)
    qg = q.reshape(B, Kv, G, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = nn.softcap(s, softcap)
    slots = jnp.arange(T)
    if window is None:
        ok = slots <= pos
    else:
        abs_pos = pos - ((pos - slots) % T)  # T == window for ring caches
        ok = (abs_pos >= 0) & (abs_pos > pos - window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o.reshape(B, 1, H * hd)


# ---------------------------------------------------------------------------
# paged decode — the KV cache as fixed-size pages named by a block table
# ---------------------------------------------------------------------------
# Swallow §X-B overlay of shared memory on distributed memory, applied to
# the KV cache: instead of one (B, T) slab per sequence, k/v live in a
# pool of (page_size, Kv*hd) pages and each sequence owns a block-index
# table row.  Page ownership follows core/memory_server.striped_owner —
# the serving allocator (repro.serving.paged_kv) is the host-side half.
# Physical page 0 is reserved as the null page: padded block-table slots
# point at it and their contribution is masked out exactly (the masked
# exp underflows to 0.0), so garbage there never reaches a real token.

class PagedAttnCache(NamedTuple):
    # k/v pools, FLAT features: (n_pages, page_size, Kv*hd)
    k: jnp.ndarray
    v: jnp.ndarray


def paged_cache_init(cfg, n_pages: int, page_size: int, dtype):
    shape = (n_pages, page_size, cfg.n_kv_heads * cfg.head_dim)
    return PagedAttnCache(k=jnp.zeros(shape, dtype),
                          v=jnp.zeros(shape, dtype))


def paged_cache_update(pool: PagedAttnCache, k_new, v_new, block_tables,
                       pos):
    """Write step-t k/v into page ``block_tables[b, t//ps]``, slot t%ps.

    k_new/v_new (B, 1, Kv, hd); block_tables (B, nmax) int32; pos (B,)
    int32 per-sequence write position.  Inactive batch slots must point
    at the null page (their writes collide there harmlessly).
    """
    B = k_new.shape[0]
    ps = pool.k.shape[1]
    k_new = k_new.reshape(B, -1)
    v_new = v_new.reshape(B, -1)
    page = jnp.take_along_axis(block_tables, (pos // ps)[:, None],
                               axis=1)[:, 0]
    slot = pos % ps
    return PagedAttnCache(k=pool.k.at[page, slot].set(k_new),
                          v=pool.v.at[page, slot].set(v_new))


def _paged_shard_axes(env, n_pages: int):
    """The mesh axis the page pools stripe over, or None when the
    single-device path applies (no env, trivial axis, or a page count
    the stripe cannot divide)."""
    if env is None:
        return None
    axes = env.resolve("pages")
    axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
    if len(axes) != 1:
        return None
    n = env.mesh.shape[axes[0]]
    if n <= 1 or n_pages % n:
        return None
    return axes


def attend_decode_paged_sharded(q, pool: PagedAttnCache, block_tables,
                                pos, *, scale, softcap, n_kv: int, env,
                                axes, impl=None):
    """Striped-pool decode via shard_map (pages sharded over "model").

    Each stripe owner attends over only the pages whose physical slab
    rows fall inside its contiguous shard ``[j*P/n, (j+1)*P/n)`` (the
    ``stripe_slab_index`` layout: logical page p lives on node p % n),
    then the per-stripe online-softmax partials (m, l, acc) merge with
    (B,Kv,G)-sized psums — the split-T decode idiom applied to the page
    axis, so the pool bytes never leave their owner node.  Block-table
    entries arriving here are already *physical* slab rows (the engine
    translates at the device boundary), so ownership is a range test.
    Exactness: stripes a sequence doesn't touch contribute m = NEG_INF,
    and exp(NEG_INF - m_global) underflows to exactly 0.0 — the merge
    adds nothing, matching the single-device masked softmax on the
    valid slots.
    """
    from repro.models.moe import _shard_map
    B, _, H, hd = q.shape
    Pn, ps = pool.k.shape[0], pool.k.shape[1]
    Kv = n_kv
    n = env.mesh.shape[axes[0]]
    L = Pn // n
    nmax = block_tables.shape[1]
    T = nmax * ps

    def body(q_l, k_l, v_l, bt_l, pos_l):
        j = jax.lax.axis_index(axes[0])
        local = bt_l - j * L
        mine = (local >= 0) & (local < L)          # (B, nmax)
        safe = jnp.where(mine, local, 0)
        if impl == "pallas":
            from repro.kernels import ops as kops
            acc, m, l = kops.paged_decode_attention(
                q_l.reshape(B, H, hd), k_l.reshape(L, ps, Kv, hd),
                v_l.reshape(L, ps, Kv, hd), safe, pos_l,
                scale=scale, softcap=softcap,
                page_mask=mine.astype(jnp.int32), partials=True)
        else:
            k = k_l[safe].reshape(B, T, Kv, hd)
            v = v_l[safe].reshape(B, T, Kv, hd)
            qg = q_l.reshape(B, Kv, H // Kv, hd)
            s = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                           preferred_element_type=jnp.float32) * scale
            s = nn.softcap(s, softcap)
            valid = (jnp.arange(T)[None, :] <= pos_l[:, None]) \
                & jnp.repeat(mine, ps, axis=1)
            s = jnp.where(valid[:, None, None, :], s, NEG_INF)
            m = s.max(-1)                                   # (B,Kv,G)
            p = jnp.exp(s - m[..., None])
            l = p.sum(-1)
            acc = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v,
                             preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axes)
        acc_g = jax.lax.psum(acc * corr[..., None], axes)
        o = acc_g / jnp.maximum(l_g[..., None], 1e-37)
        return o.astype(q_l.dtype).reshape(B, 1, H * hd)

    from jax.sharding import PartitionSpec
    pool_spec = env.spec("pages", None, None)
    repl = PartitionSpec()
    return _shard_map(
        body, mesh=env.mesh,
        in_specs=(repl, pool_spec, pool_spec, repl, repl),
        out_specs=repl, check_vma=False)(q, pool.k, pool.v,
                                         block_tables, pos)


def attend_decode_paged(q, pool: PagedAttnCache, block_tables, pos, *,
                        scale, softcap, n_kv: int, impl=None):
    """q (B,1,H,hd); pool pages (P,ps,Kv*hd); pos (B,) int32.

    Gathers the sequence's pages through the block table and runs the
    same masked decode attention as the dense path — identical arithmetic
    on the valid slots, so paged and dense decode agree token-for-token.
    Under a mesh with a non-trivial "pages" stripe the owner-partial
    shard_map path runs instead (same math, per-stripe partials merged).
    """
    from repro.parallel.sharding import current_env
    env = current_env()
    axes = _paged_shard_axes(env, pool.k.shape[0])
    if axes is not None:
        return attend_decode_paged_sharded(
            q, pool, block_tables, pos, scale=scale, softcap=softcap,
            n_kv=n_kv, env=env, axes=axes, impl=impl)
    B, _, H, hd = q.shape
    ps = pool.k.shape[1]
    Kv = n_kv
    if impl == "pallas":
        from repro.kernels import ops as kops
        P_ = pool.k.shape[0]
        o = kops.paged_decode_attention(
            q.reshape(B, H, hd), pool.k.reshape(P_, ps, Kv, hd),
            pool.v.reshape(P_, ps, Kv, hd), block_tables, pos,
            scale=scale, softcap=softcap)
        return o.reshape(B, 1, H * hd)
    nmax = block_tables.shape[1]
    T = nmax * ps
    G = H // Kv
    k = pool.k[block_tables].reshape(B, T, Kv, hd)
    v = pool.v[block_tables].reshape(B, T, Kv, hd)
    qg = q.reshape(B, Kv, G, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = nn.softcap(s, softcap)
    ok = jnp.arange(T)[None, :] <= pos[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o.reshape(B, 1, H * hd)


def paged_cache_scatter_suffix(pool: PagedAttnCache, k, v, block_row,
                               start, n_valid):
    """Scatter a prompt *suffix*'s kv (1,W,Kv,hd) into the pool at
    logical positions start..start+W-1 (a prefix-cache hit: the first
    ``start`` positions already live in shared pages).  Slots at or past
    ``n_valid`` are padding — they are routed to the null page, whose
    garbage is masked by design, so one compile serves every suffix
    length in a bucket."""
    W = k.shape[1]
    ps = pool.k.shape[1]
    k = k.reshape(W, -1)
    v = v.reshape(W, -1)
    t = start + jnp.arange(W)
    idx = jnp.clip(t // ps, 0, block_row.shape[0] - 1)
    # physical page 0 is the reserved null page (repro.serving.paged_kv):
    # padding lands there and its garbage is masked to exactly 0
    page = jnp.where(jnp.arange(W) < n_valid, block_row[idx], 0)
    slot = t % ps
    return PagedAttnCache(k=pool.k.at[page, slot].set(k),
                          v=pool.v.at[page, slot].set(v))


def attend_prefill_paged(q, pool: PagedAttnCache, block_row, start, *,
                         scale, softcap, n_kv: int):
    """Suffix-prefill attention: q (1,W,H,hd) holds query positions
    start..start+W-1; keys/values are gathered from the sequence's pages
    (cached prefix + the just-scattered suffix) and masked causally at
    ``j <= start + w`` — one batched dispatch, same arithmetic as the
    decode path, no new kernel."""
    B, W, H, hd = q.shape
    ps = pool.k.shape[1]
    nmax = block_row.shape[0]
    T = nmax * ps
    Kv = n_kv
    G = H // Kv
    k = pool.k[block_row].reshape(B, T, Kv, hd)
    v = pool.v[block_row].reshape(B, T, Kv, hd)
    qg = q.reshape(B, W, Kv, G, hd)
    s = jnp.einsum("bwkgd,btkd->bkgwt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = nn.softcap(s, softcap)
    ok = jnp.arange(T)[None, :] <= (start + jnp.arange(W))[:, None]
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgwt,btkd->bwkgd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o.reshape(B, W, H * hd)


def apply_prefill_paged(p, cfg, x, pool: PagedAttnCache, block_row, start,
                        n_valid, *, angles):
    """Paged suffix-prefill path: x (1,W,D) at positions start..;
    scatters the suffix kv then attends over the whole page run.
    Returns (out (1,W,D'), new pool)."""
    q, k_new, v_new = _qkv(p, cfg, x, angles)
    pool = paged_cache_scatter_suffix(pool, k_new, v_new, block_row,
                                      start, n_valid)
    o = attend_prefill_paged(q, pool, block_row, start, scale=_scale(cfg),
                             softcap=cfg.attn_softcap, n_kv=cfg.n_kv_heads)
    return nn.matmul(o, p["wo"]), pool


def paged_cache_from_prefill(pool: PagedAttnCache, k, v, block_row,
                             start: int = 0):
    """Scatter prefill k/v (1,S,Kv,hd) of ONE sequence into the pool.

    ``block_row`` (nmax,) int32 is the sequence's block-table row; tokens
    land at logical slots start..start+S-1.
    """
    S = k.shape[1]
    ps = pool.k.shape[1]
    k = k.reshape(S, -1)
    v = v.reshape(S, -1)
    t = start + jnp.arange(S)
    page = block_row[t // ps]
    slot = t % ps
    return PagedAttnCache(k=pool.k.at[page, slot].set(k),
                          v=pool.v.at[page, slot].set(v))


def apply_decode_paged(p, cfg, x, pool: PagedAttnCache, block_tables,
                       pos, *, angles):
    """Paged decode path: x (B,1,D), pos (B,). Returns (out, new pool)."""
    q, k_new, v_new = _qkv(p, cfg, x, angles)
    pool = paged_cache_update(pool, k_new, v_new, block_tables, pos)
    o = attend_decode_paged(q, pool, block_tables, pos, scale=_scale(cfg),
                            softcap=cfg.attn_softcap, n_kv=cfg.n_kv_heads,
                            impl=cfg.impl)
    out = nn.matmul(o, p["wo"])
    return out, pool


def cache_init(cfg, batch: int, max_len: int, window: Optional[int], dtype):
    T = min(window, max_len) if window is not None else max_len
    shape = (batch, T, cfg.n_kv_heads * cfg.head_dim)
    return AttnCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_update_decode(cache: AttnCache, k_new, v_new, pos, window):
    """Write the step-t k/v into slot t (global) or t % W (ring).

    k_new/v_new arrive as (B, 1, Kv, hd); the cache stores them flat.
    """
    B = k_new.shape[0]
    k_new = k_new.reshape(B, 1, -1)
    v_new = v_new.reshape(B, 1, -1)
    T = cache.k.shape[1]
    slot = pos % T if window is not None else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    return AttnCache(k, v)


def cache_from_prefill(k, v, window, max_len):
    """Build the flat decode cache from prefill k/v (B,S,Kv,hd)."""
    B, S, Kv, hd = k.shape
    k = k.reshape(B, S, Kv * hd)
    v = v.reshape(B, S, Kv * hd)
    if window is None:
        pad = max_len - S
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        return AttnCache(k, v)
    W = min(window, max_len)
    if S >= W:
        tail_k, tail_v = k[:, S - W:], v[:, S - W:]
        slots = (jnp.arange(S - W, S)) % W
        ck = jnp.zeros((B, W, Kv * hd), k.dtype).at[:, slots].set(tail_k)
        cv = jnp.zeros((B, W, Kv * hd), v.dtype).at[:, slots].set(tail_v)
        return AttnCache(ck, cv)
    ck = jnp.zeros((B, W, Kv * hd), k.dtype).at[:, :S].set(k)
    cv = jnp.zeros((B, W, Kv * hd), v.dtype).at[:, :S].set(v)
    return AttnCache(ck, cv)


# ---------------------------------------------------------------------------
# full layer entry points
# ---------------------------------------------------------------------------
def apply(p, cfg, x, *, kind: str, angles, impl: Optional[str] = None):
    """Train/prefill path. Returns (out, (k, v))."""
    impl = impl or cfg.impl
    window = cfg.sliding_window if kind == "local" else None
    q, k, v = _qkv(p, cfg, x, angles)
    kh = expand_kv(k, cfg.n_heads)
    vh = expand_kv(v, cfg.n_heads)
    kh = logical_constraint(kh, "batch", None, "heads", None)
    vh = logical_constraint(vh, "batch", None, "heads", None)
    kw = dict(causal=cfg.causal, window=window, scale=_scale(cfg),
              softcap=cfg.attn_softcap)
    if impl == "ref":
        o = attend_ref(q, kh, vh, **kw)
    elif impl == "blocked":
        o = attend_blocked(q, kh, vh, block_q=cfg.attn_block_q,
                           block_kv=cfg.attn_block_kv, **kw)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, kh, vh, block_q=cfg.attn_block_q,
                                 block_kv=cfg.attn_block_kv, **kw)
    else:
        raise ValueError(impl)
    o = o.reshape(*x.shape[:-1], cfg.n_heads * cfg.head_dim)
    from repro.parallel.collectives import row_parallel
    out = row_parallel(o, p["wo"])
    return out, (k, v)


def apply_decode(p, cfg, x, cache: AttnCache, pos, *, kind: str, angles):
    """Decode path: x (B,1,D). Returns (out, new_cache)."""
    window = cfg.sliding_window if kind == "local" else None
    q, k_new, v_new = _qkv(p, cfg, x, angles)
    cache = cache_update_decode(cache, k_new, v_new, pos, window)
    o = attend_decode(q, cache, pos, window=window, scale=_scale(cfg),
                      softcap=cfg.attn_softcap, n_kv=cfg.n_kv_heads)
    out = nn.matmul(o, p["wo"])
    return out, cache
