"""Sharded, atomic, async checkpointing (restart is Swallow C1 at pod scale:
any step can be recomputed from (seed, step) + the last checkpoint).

Format: <dir>/step_<N>/
    manifest.json   — pytree structure, leaf paths/shapes/dtypes, mesh info
    arrays.npz      — leaf path -> ndarray (QTensor leaves flatten to q/scale)

Atomicity: write to step_<N>.tmp, fsync, rename.  Async: a snapshot is
taken synchronously (device_get) and written by a daemon thread so the
train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.parallel.sharding import path_str


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[path_str(path)] = np.asarray(jax.device_get(leaf))
    return out


def save(ckpt_dir: str, step: int, state: Any,
         extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic checkpoint. Returns final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(state)
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "extra": extra_meta or {},
        "time": time.time(),
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore(ckpt_dir: str, state_template: Any,
            step: Optional[int] = None,
            shardings: Any = None) -> Tuple[int, Any]:
    """Restore into the structure of ``state_template``.

    ``shardings`` (optional pytree of NamedShardings) re-places leaves onto
    the current mesh — this is what makes restore *elastic*: the checkpoint
    carries no mesh assumptions, only logical arrays.
    """
    path = latest(ckpt_dir) if step is None else os.path.join(
        ckpt_dir, f"step_{step:08d}")
    if path is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_tpl, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        assert len(shard_leaves) == len(flat_tpl)
    leaves = []
    for i, (p, tpl) in enumerate(flat_tpl):
        key = path_str(p)
        arr = data[key]
        assert tuple(arr.shape) == tuple(tpl.shape), (key, arr.shape,
                                                      tpl.shape)
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)


def latest(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = latest(ckpt_dir)
    return int(p.rsplit("_", 1)[1]) if p else None


class AsyncCheckpointer:
    """Snapshot synchronously, write in a daemon thread; keep_last GC."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: Any, extra_meta: Optional[dict] = None):
        self.wait()
        arrays = _flatten(state)  # snapshot now (cheap: host copies)
        treedef = jax.tree_util.tree_structure(state)

        def _write():
            try:
                final = os.path.join(self.ckpt_dir, f"step_{step:08d}")
                tmp = final + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
                manifest = {
                    "step": step, "treedef": str(treedef),
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in arrays.items()},
                    "extra": extra_meta or {}, "time": time.time()}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e
