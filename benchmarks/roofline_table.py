"""§Roofline: the full per-cell table from the dry-run sweep results."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]


def load(path="results/dryrun.json"):
    if not os.path.exists(path):
        return []
    return [r for r in json.load(open(path)) if "roofline" in r]


def roofline_rows(path="results/dryrun.json") -> List[Row]:
    rows: List[Row] = []
    for r in sorted(load(path), key=lambda r: (r["arch"], r["shape"],
                                               r["mesh"])):
        rl = r["roofline"]
        name = f"roofline/{r['arch']}.{r['shape']}.{r['mesh']}"
        rows.append((name, rl["step_time_bound"] * 1e6,
                     f"dom={rl['dominant']};frac={rl['roofline_fraction']:.3f}"
                     f";useful={rl['useful_ratio']:.3f}"))
    return rows


def print_full_table(path="results/dryrun.json"):
    recs = load(path)
    if not recs:
        print("no dry-run results found")
        return
    hdr = (f"{'arch':<18} {'shape':<12} {'mesh':<8} {'t_comp':>8} "
           f"{'t_mem':>8} {'t_coll':>8} {'bound':>8} {'dom':>6} "
           f"{'useful':>7} {'frac':>6}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        rl = r["roofline"]
        print(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} "
              f"{rl['t_compute']:>8.4f} {rl['t_memory']:>8.4f} "
              f"{rl['t_collective']:>8.4f} {rl['step_time_bound']:>8.4f} "
              f"{rl['dominant']:>6.6s} {rl['useful_ratio']:>7.3f} "
              f"{rl['roofline_fraction']:>6.3f}")
