"""Elastic rescaling: restore a checkpoint onto a different mesh.

Checkpoints store logical arrays only (runtime/checkpoint.py), and every
sharding is derived from (mesh, rules) at restore time — so moving from
16x16 to 12x16 after losing data rows is: build new mesh -> recompute
specs -> device_put shards.  The only constraint is the global batch:
``rebatch`` keeps tokens-per-step constant by raising grad-accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

from repro import steps as steps_mod
from repro.optim import adam as adam_lib
from repro.parallel.sharding import ShardingEnv, param_specs, use_sharding
from repro.runtime import checkpoint as ckpt_lib


def state_shardings(cfg, adam_cfg, env: ShardingEnv):
    p_shape = steps_mod.abstract_params(cfg)
    p_spec = param_specs(p_shape, env)
    o_shape = steps_mod.abstract_opt_state(cfg, adam_cfg, p_shape)
    o_spec = adam_lib.state_specs(p_shape, adam_cfg, p_spec)
    mk = lambda s: jax.sharding.NamedSharding(env.mesh, s)
    return (jax.tree.map(mk, p_spec,
                         is_leaf=lambda x: isinstance(
                             x, jax.sharding.PartitionSpec)),
            jax.tree.map(mk, o_spec,
                         is_leaf=lambda x: isinstance(
                             x, jax.sharding.PartitionSpec)))


def restore_elastic(ckpt_dir: str, cfg, adam_cfg, new_mesh,
                    rules=None) -> Tuple[int, Any, Any]:
    """Restore (step, params, opt_state) re-sharded for ``new_mesh``."""
    with use_sharding(new_mesh, rules) as env:
        p_shape = steps_mod.abstract_params(cfg)
        o_shape = steps_mod.abstract_opt_state(cfg, adam_cfg, p_shape)
        shardings = None
        if env is not None:
            p_shard, o_shard = state_shardings(cfg, adam_cfg, env)
            shardings = {"params": p_shard, "opt": o_shard}
        step, state = ckpt_lib.restore(
            ckpt_dir, {"params": p_shape, "opt": o_shape},
            shardings=shardings)
    return step, state["params"], state["opt"]


def rebatch(global_batch: int, old_data: int, new_data: int,
            accum: int = 1) -> Tuple[int, int]:
    """Keep the *optimizer* batch (global_batch x accum) constant when the
    data axis changes: returns (per_step_batch, accum_steps).

    Policy: per-step batch must divide by the new data axis; any remainder
    of the optimizer batch is recovered by raising grad accumulation.
    """
    opt_tokens = global_batch * accum
    per = (global_batch // new_data) * new_data
    per = max(per, new_data)
    new_accum = max(1, round(opt_tokens / per))
    return per, new_accum


__all__ = ["restore_elastic", "state_shardings", "rebatch"]
