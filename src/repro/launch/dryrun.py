import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this lowers the right step function (train_step /
prefill_step / serve_step) against ShapeDtypeStruct inputs (no
allocation), compiles it for the 16x16 single-pod mesh and the 2x16x16
multi-pod mesh, prints memory_analysis / cost_analysis, parses the
compiled HLO for collective wire bytes, and appends a JSON record per
cell to --out (incremental, restartable).

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as roofline_mod
from repro.configs import SHAPES, cell_is_runnable, get_config, runnable_cells
from repro.launch.mesh import make_production_mesh
from repro import steps as steps_mod
from repro.parallel.sharding import use_sharding


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules=None, dump_hlo: str = None, impl: str = None) -> dict:
    cfg = get_config(arch)
    if impl:
        cfg = cfg.replace(impl=impl)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = mesh.devices.size
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": int(n_chips)}
    t0 = time.time()

    # long_500k-style shapes (global_batch=1) cannot shard the batch axis:
    # replicate batch, parallelism comes from the model axis only.
    batch_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    batch_div = 1
    for a in batch_axes:
        batch_div *= mesh.shape[a]
    if shape.global_batch % batch_div:
        rules = dict(rules or {}, batch=None)
        record["rules_override"] = {"batch": None}

    with use_sharding(mesh, rules) as env:
        adam_cfg = steps_mod.adam_config_for(cfg)
        shardings_of = lambda tree: jax.tree.map(
            lambda s: s.sharding, tree,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        if shape.kind == "train":
            params, opt = steps_mod.make_state_structs(cfg, adam_cfg, mesh, env)
            batch = steps_mod.make_batch_struct(cfg, shape, mesh, env)
            step = steps_mod.make_train_step(cfg, adam_cfg)
            # explicit out shardings so donated params/opt alias exactly
            jf = jax.jit(step, donate_argnums=(0, 1),
                         out_shardings=(shardings_of(params),
                                        shardings_of(opt), None))
            lowered = jf.lower(params, opt, batch)
        elif shape.kind == "prefill":
            params, _ = steps_mod.make_state_structs(cfg, adam_cfg, mesh, env)
            batch = steps_mod.make_batch_struct(cfg, shape, mesh, env)
            step = steps_mod.make_prefill_step(cfg, max_len=shape.seq_len)
            args = (params, batch["tokens"])
            if cfg.mrope_sections is not None:
                args = args + (batch["positions"],)
            jf = jax.jit(step)
            lowered = jf.lower(*args)
        else:  # decode
            params, _ = steps_mod.make_state_structs(cfg, adam_cfg, mesh, env)
            tok, caches, pos = steps_mod.make_decode_structs(cfg, shape, mesh,
                                                             env)
            step = steps_mod.make_serve_step(cfg)
            jf = jax.jit(step, donate_argnums=(2,),
                         out_shardings=(None, None, shardings_of(caches)))
            lowered = jf.lower(params, tok, caches, pos)

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis:")
        print(mem)
        ca = compiled.cost_analysis() or {}
        print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis: "
              f"flops={ca.get('flops')} bytes={ca.get('bytes accessed')}")
        record["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(mem, k)}
        record["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals",
             "utilization operand 0 {}", "optimal_seconds")}

        hlo_text = compiled.as_text()
        if dump_hlo:
            with open(dump_hlo, "w") as f:
                f.write(hlo_text)
        record["collectives"] = hlo_mod.collective_summary(hlo_text)

        tp = mesh.shape["model"]
        rl = roofline_mod.analyze(cfg, shape, mesh_name, n_chips, tp,
                                  hlo_text=hlo_text, cost_analysis=ca,
                                  memory_analysis=mem)
        record["roofline"] = rl.to_dict()
        print(f"[{arch} x {shape_name} x {mesh_name}] roofline: "
              f"compute={rl.t_compute:.4f}s memory={rl.t_memory:.4f}s "
              f"collective={rl.t_collective:.4f}s dominant={rl.dominant} "
              f"fraction={rl.roofline_fraction:.3f}")
    return record


def append_record(path: str, record: dict):
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            recs = json.load(f)
    recs = [r for r in recs
            if not (r.get("arch") == record["arch"]
                    and r.get("shape") == record["shape"]
                    and r.get("mesh") == record.get("mesh"))]
    recs.append(record)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(recs, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--dump-hlo", default=None)
    ap.add_argument("--impl", default=None)
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel MoE (experts striped over "
                         "'model') instead of expert-TP")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    cells = list(runnable_cells()) if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    existing = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                if "error" not in r and "skipped" not in r:
                    existing.add((r["arch"], r["shape"], r.get("mesh")))

    failures = 0
    for arch, shape_name in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            if (arch, shape_name, mesh_name) in existing:
                print(f"skip existing {arch} x {shape_name} x {mesh_name}")
                continue
            try:
                rules = {"expert": "model", "expert_ff": None} \
                    if args.moe_ep else None
                rec = run_cell(arch, shape_name, multi, rules=rules,
                               dump_hlo=args.dump_hlo, impl=args.impl)
            except Exception as e:  # noqa
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}"}
                failures += 1
            append_record(args.out, rec)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
