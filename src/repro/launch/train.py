"""CLI trainer.

  PYTHONPATH=src python -m repro.launch.train --arch tiny-100m \
      --seq 512 --batch 8 --steps 200 --ckpt-dir /tmp/ck

Use --tiny to run the reduced smoke config of any assigned arch, and
--devices N (with --data D --model M) to train on N fake CPU devices.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced smoke config of --arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--impl", default=None)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake CPU device count (0 = real devices)")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    from repro.configs import get_config, get_tiny_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_test_mesh
    from repro.runtime import train_loop

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = None
    if args.data * args.model > 1:
        mesh = make_test_mesh(args.data, args.model)

    job = train_loop.TrainJobConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, peak_lr=args.lr,
        metrics_path=args.metrics)
    out = train_loop.run(cfg, shape, mesh=mesh, job=job, impl=args.impl)
    print("final:", {k: v for k, v in out["final_metrics"].items()})


if __name__ == "__main__":
    main()
