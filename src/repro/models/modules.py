"""Shared model primitives: norms, rotary embeddings, activations, FFN."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint


def dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16, "int8": jnp.int8}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale * (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------
def matmul(x, w, out_dtype=None, row_parallel: bool = False):
    """bf16 inputs, fp32 accumulation (MXU semantics), cast back.

    row_parallel=True marks matmuls whose output is a partial sum over the
    TP axis: the sequence-sharded constraint is applied to the fp32 dot
    result *before* the cast so GSPMD lowers it as a reduce-scatter rather
    than all-reduce + slice (halves the wire bytes).
    """
    out_dtype = out_dtype or x.dtype
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if row_parallel and y.ndim == 3 and y.shape[1] > 1:
        y = logical_constraint(y, "batch", "seq_sp", None)
    return y.astype(out_dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) keeps zero-init sane; we store scale-1 at init=1
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions, head_dim: int, theta: float,
                sections: Optional[Tuple[int, int, int]] = None):
    """Angles (…, S, head_dim/2).

    positions: (B, S) int32 for standard RoPE, or (3, B, S) for M-RoPE where
    the three planes are (temporal, height, width) ids and ``sections``
    gives how many of the head_dim/2 frequencies each plane owns.
    """
    inv = rope_freqs(head_dim, theta)                    # (half,)
    if sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,half)
        return ang
    t, h, w = sections
    assert t + h + w == head_dim // 2, (sections, head_dim)
    ang3 = positions[..., None].astype(jnp.float32) * inv     # (3,B,S,half)
    sel = jnp.concatenate([jnp.zeros((t,), jnp.int32),
                           jnp.ones((h,), jnp.int32),
                           jnp.full((w,), 2, jnp.int32)])     # (half,)
    # pick, per frequency, the angle from its assigned plane
    return jnp.take_along_axis(
        jnp.moveaxis(ang3, 0, -1),                            # (B,S,half,3)
        sel[None, None, :, None], axis=-1)[..., 0]            # (B,S,half)


def apply_rope(x, angles):
    """x: (B, S, H, head_dim); angles: (B, S, head_dim/2). NeoX half-split."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(jnp.float32)   # (B,S,1,half)
    sin = jnp.sin(angles)[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU / GeGLU / plain MLP)
# ---------------------------------------------------------------------------
def ffn_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.gated_ffn:
        p["w_gate"] = dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype)
    p["w_up"] = dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    p["w_down"] = dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype,
                             scale=1.0 / max(1, cfg.n_layers) ** 0.5)
    return p


def ffn_apply(p, cfg, x):
    from repro.parallel.collectives import column_parallel
    act = activation(cfg.act)
    if cfg.gated_ffn:
        gate, up = column_parallel(x, [p["w_gate"], p["w_up"]])
        h = act(gate) * up
    else:
        (up,) = column_parallel(x, [p["w_up"]])
        h = act(up)
    h = logical_constraint(h, "batch", None, "ffn")
    from repro.parallel.collectives import row_parallel
    out = row_parallel(h, p["w_down"])
    return out
