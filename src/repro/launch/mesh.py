"""Production meshes.

Defined as functions (not module constants) so importing never touches jax
device state.  The production target is a TPU v5e pod: 16 x 16 = 256 chips
("data" x "model"), and two pods (2 x 16 x 16 = 512) for the multi-pod
dry-run, with the "pod" axis crossing the inter-pod (DCN/optical) boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 1):
    """Small mesh for CPU multi-device tests (needs XLA host-device flag)."""
    n = len(jax.devices())
    assert pod * data * model <= n, (pod, data, model, n)
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e-class chip).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~usable)
