"""Swallow §III-B: code overlays -> weight streaming & rematerialization.

The paper's overlays swap code regions through a node's 64 kB store at
run time (Fig. 4), and the paper *recommends against* them because the
interrupt-driven loads destroy timing predictability.  The pod-scale
analogues are (a) layer-weight streaming (gathering a layer's shards
just-in-time inside the scan) and (b) activation rematerialization —
both trade predictable extra traffic/compute for memory, and unlike
Swallow's interrupts both are *statically scheduled* by XLA, so the
paper's objection dissolves: the trade becomes analyzable.

``OverlayPlan`` quantifies that trade for a config so the decision is a
printed number, not folklore: extra HLO FLOPs (remat recompute) and
extra wire bytes (per-layer gathers) vs HBM bytes saved.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.analysis import flops as flops_mod
from repro.configs.base import ModelConfig, ShapeConfig


# --- paper's overlay table (Fig. 4) as executable ground truth -------------
def overlay_map(linked_kwords: int = 16, region=(0x1000, 0x2FFF),
                overlay_kwords: int = 4):
    """Reproduce Fig. 4: linked addresses -> (overlay id, runtime addr)."""
    entries = []
    lo, hi = region
    n_overlays = (hi - lo + 1) // (overlay_kwords * 1024)
    for i in range(linked_kwords // overlay_kwords):
        start = i * overlay_kwords * 1024
        end = start + overlay_kwords * 1024 - 1
        if start < lo or end > hi:
            entries.append({"linked": (start, end), "overlay": None,
                            "runtime": (start if start < lo else
                                        start - (hi + 1 - lo - overlay_kwords
                                                 * 1024), end)})
        else:
            oid = (start - lo) // (overlay_kwords * 1024)
            entries.append({"linked": (start, end), "overlay": oid,
                            "runtime": (lo, lo + overlay_kwords * 1024 - 1)})
    resident = linked_kwords - (n_overlays - 1) * overlay_kwords
    return {"entries": entries, "n_overlays": n_overlays,
            "resident_kwords": resident}


@dataclass
class OverlayPlan:
    remat: bool
    stream_weights: bool
    extra_flops: float          # recompute
    extra_wire_bytes: float     # per-layer gathers
    hbm_bytes_saved: float
    recommended: bool

    def summary(self) -> str:
        return (f"remat={self.remat} stream={self.stream_weights} "
                f"extra_flops={self.extra_flops:.3e} "
                f"extra_wire={self.extra_wire_bytes:.3e}B "
                f"saved={self.hbm_bytes_saved:.3e}B "
                f"recommended={self.recommended}")


def plan(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
         hbm_per_chip: float = 16e9, tp: int = 16) -> OverlayPlan:
    """Decide remat/streaming the way the paper decides overlays: from the
    store budget, then price the cost."""
    cost = flops_mod.step_costs(cfg, shape, n_chips, tp=tp)
    tokens = shape.global_batch * shape.seq_len
    act_dtype = 2
    # full activation stash without remat (every layer, every sublayer)
    stash = flops_mod.activation_stream_bytes(cfg, float(tokens)) / n_chips
    fits_without_remat = stash + flops_mod.param_bytes(cfg) / tp \
        < hbm_per_chip * 0.8
    remat = not fits_without_remat
    extra_flops = cost.flops_fwd if remat else 0.0
    # weight streaming (FSDP gathers) applies to MoE expert tables only
    stream = cfg.moe is not None
    extra_wire = flops_mod.param_bytes(cfg) / tp * (1 if stream else 0)
    saved = stash if remat else 0.0
    return OverlayPlan(remat=remat, stream_weights=stream,
                       extra_flops=extra_flops, extra_wire_bytes=extra_wire,
                       hbm_bytes_saved=saved,
                       recommended=remat or stream)
