"""DeepSeek-V3 671B [arXiv:2412.19437; hf-verified].

MoE: 61L, d_model=7168, 128 attention heads with MLA (q_lora 1536, kv_lora
512, nope 128 + rope 64 q/k dims, v 128), vocab=129280.  First 3 layers are
dense FFN (d_ff=18432); the remaining 58 use 1 shared + 256 routed experts
(d_ff_expert=2048), sigmoid-score top-8 routing, plus 1 multi-token-
prediction module.  671B total / ~37B active parameters.

Memory policy (16 GB/chip on a 256-chip pod): bf16 parameters and int8
block-quantized Adam moments (see optim/adam.py).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # MLA: heads share one latent; kept for bookkeeping
    head_dim=128,
    d_ff=18432,            # dense layers (first_k_dense)
    vocab_size=129280,
    layer_pattern=("mla",),
    first_k_dense=3,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  capacity_factor=1.25, score_func="sigmoid"),
    mtp_depth=1,
    act="silu",
    gated_ffn=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    opt_state_dtype="int8",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, first_k_dense=1,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      capacity_factor=1.25, score_func="sigmoid"),
        mtp_depth=1, param_dtype="float32", opt_state_dtype="float32",
        attn_block_q=16, attn_block_kv=32)
