"""Generate the §Dry-run / §Roofline tables in EXPERIMENTS.md from
results/dryrun.json (run after sweeps; keeps the hand-written sections)."""
import json
import sys


def main(path="results/dryrun.json"):
    recs = [r for r in json.load(open(path)) if "roofline" in r]
    recs.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))

    dry = []
    dry.append("| arch | shape | mesh | lower(s) | compile(s) | "
               "args GB/dev | temp GB/dev | wire GB/dev | collectives |")
    dry.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        m = r.get("memory", {})
        c = r.get("collectives", {})
        counts = c.get("op_counts", {})
        dry.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('lower_s', 0):.0f} | {r.get('compile_s', 0):.0f} "
            f"| {m.get('argument_size_in_bytes', 0)/1e9:.2f} "
            f"| {m.get('temp_size_in_bytes', 0)/1e9:.2f} "
            f"| {c.get('total_wire_bytes_per_device', 0)/1e9:.1f} "
            f"| {sum(counts.values())} |")

    roof = []
    roof.append("| arch | shape | t_compute | t_memory | t_collective | "
                "bound(s) | dominant | MODEL/HLO | roofline frac |")
    roof.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        rl = r["roofline"]
        roof.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute']:.4f} "
            f"| {rl['t_memory']:.4f} | {rl['t_collective']:.4f} "
            f"| {rl['step_time_bound']:.4f} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.3f} | {rl['roofline_fraction']:.3f} |")

    text = open("EXPERIMENTS.md").read()
    for marker, table in (("DRYRUN_TABLE", dry), ("ROOFLINE_TABLE", roof)):
        start = text.index(f"<!-- {marker} -->")
        end = text.index(f"<!-- /{marker} -->")
        text = text[:start] + f"<!-- {marker} -->\n" + "\n".join(table) \
            + "\n" + text[end:]
    open("EXPERIMENTS.md", "w").write(text)
    print(f"wrote {len(recs)} cells")


if __name__ == "__main__":
    main(*sys.argv[1:])
