"""Cost engine (§V link model composed with FLOPs + energy), the layout
autotuner, and cost-aware nOS admission."""
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES, ShapeConfig
from repro.core import costs, network, nos


# --- network (§V-B/C): paper ground truth -------------------------------------
def test_link_rate_hits_paper_500mbit():
    # fastest setting (Ts=2, Tt=1) at 500 MHz -> 500 Mbit/s per link
    assert network.link_rate_bps(ts=2, tt=1, hz=500e6) == pytest.approx(
        500e6, rel=1e-9)


def test_packet_rate_matches_paper_435mbit():
    # 3-byte header + control token on the paper's ~28-byte payload
    assert network.packet_rate_bps(28) == pytest.approx(437.5e6, rel=0.01)
    assert 430e6 < network.packet_rate_bps(28) < 440e6
    # overhead vanishes with payload size, never exceeds the raw link rate
    assert network.packet_rate_bps(10_000) < network.link_rate_bps()
    assert network.packet_rate_bps(10_000) > 0.99 * network.link_rate_bps()


def test_crossover_bytes_monotone_in_group():
    # from g=3 up, the per-hop setup latency dominates and the crossover
    # grows strictly with group size; g=2 sits above g=3 only because the
    # ring efficiency factor g/(g-1) is worst there
    xs = [network.crossover_bytes(g) for g in range(3, 65)]
    assert all(b > a for a, b in zip(xs, xs[1:]))
    assert network.crossover_bytes(2) > network.crossover_bytes(3)


@pytest.mark.parametrize("kind", ["all_gather", "reduce_scatter",
                                  "all_reduce", "all_to_all"])
def test_ring_circuit_never_slower_than_packet(kind):
    for group in (2, 4, 8, 16, 64):
        for nbytes in (1e2, 1e4, 1e6, 1e8, 1e10):
            t_c = network.ring_collective_time(nbytes, group, kind,
                                               mode="circuit")
            t_p = network.ring_collective_time(nbytes, group, kind,
                                               mode="packet")
            assert t_c <= t_p, (kind, group, nbytes)


# --- cost engine --------------------------------------------------------------
def test_estimate_components_sum():
    cfg = get_config("qwen3-14b")
    est = costs.estimate(cfg, costs.Layout(16, 16), "circuit",
                         SHAPES["train_4k"])
    assert est.step_time_s == pytest.approx(
        max(est.compute_s, est.hbm_s) + est.ici_s)
    assert est.energy.total_j > 0
    assert est.ici_bytes_per_chip > 0          # TP + grad-sync traffic
    assert est.tokens_per_s > 0


def test_estimate_packet_costs_at_least_circuit():
    cfg = get_config("qwen3-14b")
    for shape in (SHAPES["train_4k"], SHAPES["decode_32k"]):
        c = costs.estimate(cfg, costs.Layout(16, 16), "circuit", shape)
        p = costs.estimate(cfg, costs.Layout(16, 16), "packet", shape)
        assert p.step_time_s >= c.step_time_s


def test_single_chip_layout_has_no_ici():
    cfg = get_config("qwen3-1.7b")
    est = costs.estimate(cfg, costs.Layout(1, 1), shape=SHAPES["train_4k"])
    assert est.ici_s == 0.0 and est.ici_bytes_per_chip == 0.0


def test_candidate_layouts_cover_factorizations():
    lays = costs.candidate_layouts(16)
    assert {(l.data, l.model) for l in lays} == {
        (16, 1), (8, 2), (4, 4), (2, 8), (1, 16)}
    assert all(l.n_chips == 16 for l in lays)


# --- autotuner: picks the analytically-optimal layout -------------------------
@pytest.mark.parametrize("arch,shape", [
    ("qwen3-14b", SHAPES["train_4k"]),
    ("gemma2-27b", SHAPES["decode_32k"]),
    ("rwkv6-1.6b", SHAPES["train_4k"]),
])
def test_autotuner_picks_analytic_optimum(arch, shape):
    from repro.parallel.sharding import autotune_layout
    cfg = get_config(arch)
    best, ranked = autotune_layout(cfg, shape, n_chips=64)
    # exhaustive re-derivation: the chosen layout is the argmin over every
    # factorization priced directly through estimate()
    brute = min((costs.estimate(cfg, lay, "circuit", shape)
                 for lay in costs.candidate_layouts(64)),
                key=lambda e: e.step_time_s)
    assert (best.layout.data, best.layout.model) == \
        (brute.layout.data, brute.layout.model)
    assert best.step_time_s == pytest.approx(brute.step_time_s)
    assert [e.step_time_s for e in ranked] == \
        sorted(e.step_time_s for e in ranked)


def test_autotuner_directional_preferences():
    from repro.parallel.sharding import autotune_layout
    # big-model decode is weight-read bound -> wants tensor parallelism
    decode_best, _ = autotune_layout(get_config("gemma2-27b"),
                                     SHAPES["decode_32k"], n_chips=64)
    assert decode_best.layout.model > 1
    # small-model big-batch training is compute bound -> mostly data parallel
    train_best, _ = autotune_layout(get_config("rwkv6-1.6b"),
                                    SHAPES["train_4k"], n_chips=64)
    assert train_best.layout.data > train_best.layout.model


# --- cost-aware nOS -----------------------------------------------------------
def test_nos_costed_submit_sizes_and_accounts():
    s = nos.NOS(data_rows=16, model_cols=16)
    cfg = get_config("qwen3-14b")
    assert s.submit(cfg, name="train", shape=SHAPES["train_4k"],
                    steps=10, max_rows=8)
    job = s.jobs["train"]
    assert job.state == "running"
    assert 1 <= job.rows_needed <= 8
    assert job.estimate is not None and job.estimate.step_time_s > 0
    # engine-estimated draw replaces the flat TDP assumption
    p = s.power_estimate_w()
    flat = job.rows_needed * 16 * 200.0 + (16 - job.rows_needed) * 16 * 60.0
    assert p != flat and p > 0
    s.finish("train")
    acct = s.energy_account()
    n_chips = job.rows_needed * 16
    assert acct["train"] == pytest.approx(
        10 * job.estimate.energy.total_j * n_chips)


def test_nos_costed_job_queues_then_runs():
    s = nos.NOS(data_rows=4, model_cols=4)
    s.submit(nos.Job("hog", rows_needed=4))
    cfg = get_config("qwen3-1.7b")
    assert not s.submit(cfg, name="late", shape=SHAPES["decode_32k"],
                        steps=5)
    assert s.jobs["late"].state == "pending"
    s.finish("hog")
    assert s.jobs["late"].state == "running"
    assert s.jobs["late"].rows_needed >= 1


def test_nos_legacy_row_submit_still_works():
    s = nos.NOS(data_rows=16)
    assert s.submit(nos.Job("a", rows_needed=8))
    assert s.submit(nos.Job("b", rows_needed=8))
    assert not s.submit(nos.Job("c", rows_needed=4))
    s.finish("a")
    assert s.jobs["c"].state == "running"


# --- cost sweep benchmark -----------------------------------------------------
def test_cost_sweep_mixed_trace():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import cost_sweep
    sched, rows, totals = cost_sweep.simulate()
    assert len(rows) >= 4
    kinds = {r["kind"] for r in rows}
    assert "train" in kinds and "decode" in kinds
    assert all(r["energy_kj"] > 0 for r in rows)
    assert 0 < totals["utilisation"] <= 1.0
    assert totals["fleet_energy_mj"] >= totals["job_energy_mj"] > 0
    table = cost_sweep.format_table(rows, totals, "circuit")
    for r in rows:
        assert r["name"] in table
