"""Per-tenant SLO classes for the chunked-prefill scheduler (§VIII nOS
admission, made latency-aware).

Swallow's nOS admits work by *pricing* it against the cost engine; this
module gives the serving scheduler the other half of that contract: what
each tenant was promised.  A class bundles

* ``ttft_steps`` — the first-token deadline, measured on the scheduler's
  deterministic step clock (one decode step == one tick).  Admission is
  earliest-deadline-first over ``arrived_step + ttft_steps``; fixed
  deadlines on a monotonic clock make EDF starvation-free — a waiting
  request's deadline only gets *relatively* earlier as time passes.
* ``stall_frac`` — the tolerable prefill interference per decode window,
  as a fraction of the window's decode seconds.  A running tenant with
  ``stall_frac = 0.25`` accepts tok/s no worse than ``rate / 1.25``:
  the chunk budget for a window is ``window_s * min(stall_frac over
  running)`` seconds, priced against chunk cost via
  :func:`repro.core.costs.estimate`'s ``prefill_cost_s`` — the same
  EDP-style pricing nOS uses for placement, applied to interference.
* ``priority`` — tie-break between equal deadlines (lower = sooner).

Classes are deliberately coarse (interactive / standard / batch): the
paper's argument is that a scalable system is judged by its *tail*
behaviour under contention, and three well-separated tiers are enough to
expose whether the scheduler defends them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class SLOClass:
    name: str
    ttft_steps: int      # first-token deadline, scheduler steps after arrival
    stall_frac: float    # prefill seconds tolerated per decode-second
    priority: int        # deadline tie-break; lower admits first

    def deadline(self, arrived_step: int) -> int:
        return arrived_step + self.ttft_steps

    def tpot_target_s(self, decode_cost_s: float) -> float:
        """Per-token latency ceiling implied by ``stall_frac``: the pure
        decode cost inflated by the tolerated interference."""
        return decode_cost_s * (1.0 + self.stall_frac)


SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", ttft_steps=8, stall_frac=0.25,
                            priority=0),
    "standard": SLOClass("standard", ttft_steps=32, stall_frac=0.5,
                         priority=1),
    "batch": SLOClass("batch", ttft_steps=256, stall_frac=1.0, priority=2),
}

DEFAULT_SLO = "standard"


def get_slo(name: str) -> SLOClass:
    """Resolve a class name, listing the registry on a miss (mirrors the
    harness's fail-fast trace validation)."""
    try:
        return SLO_CLASSES[name]
    except KeyError:
        valid = ", ".join(sorted(SLO_CLASSES))
        raise KeyError(f"unknown SLO class {name!r}; valid: {valid}") from None
