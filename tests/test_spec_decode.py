"""N-gram speculative decoding: proposer semantics, rollback
(``PageAllocator.truncate_to``) refcount safety, scheduler
``complete_spec`` bookkeeping, and the acceptance gate — greedy tokens
bit-identical with speculation on or off, under prefix-cache hits,
forced preemption and fused windows, with ``dispatches_per_token``
actually dropping on repetitive text."""
import numpy as np
import pytest

from conftest import dense_oracle, get_tiny_model, make_engine, \
    seeded_prompts
from repro.serving import (ContinuousBatchScheduler, NGramSpec,
                           PageAllocator, Request, propose_ngram)


# --- proposer: weightless prompt-lookup drafting -------------------------------
def test_propose_ngram_prefers_longest_ngram_and_earliest_match():
    #          0  1  2  3  4  5  6  7
    history = [1, 2, 3, 9, 1, 2, 3, 9]          # period-4 loop
    # last 3 tokens [2,3,9] occur earliest at i=1 -> continuation from 4
    assert propose_ngram(history, 4, max_n=3) == [1, 2, 3, 9]
    # k is clipped at the end of history
    assert propose_ngram(history, 99, max_n=3) == [1, 2, 3, 9]
    # the n=2 pattern [1,2] matches earliest at i=1 -> continuation from 3
    h = [5, 1, 2, 7, 7, 1, 2]
    assert propose_ngram(h, 3, max_n=3) == [7, 7, 1]
    # n=1 fallback when nothing longer matches
    assert propose_ngram([4, 8, 4], 2, max_n=3) == [8, 4]


def test_propose_ngram_empty_cases():
    assert propose_ngram([], 4) == []
    assert propose_ngram([7], 4) == []                 # no earlier history
    assert propose_ngram([1, 2, 3], 0) == []           # k = 0
    assert propose_ngram([1, 2, 3], 4) == []           # no repeat at all
    # min_n=2 refuses a unigram-only match
    assert propose_ngram([1, 5, 2, 5], 2, max_n=3, min_n=2) == []


def test_ngram_spec_accept_rule_is_greedy_exact():
    spec = NGramSpec(k=8)
    # full accept: drafts match greedy everywhere -> drafts + bonus token
    assert spec.accept([4, 5, 6], [4, 5, 6, 7]) == [4, 5, 6, 7]
    # first mismatch replaced by the verifier's own token, rest dropped
    assert spec.accept([4, 9, 6], [4, 5, 6, 7]) == [4, 5]
    # immediate mismatch still emits exactly the greedy token
    assert spec.accept([9], [4, 5]) == [4]
    s = spec.stats
    assert (s.drafted, s.accepted, s.verifies) == (7, 4, 3)
    assert s.accept_rate == pytest.approx(4 / 7)


# --- allocator: speculative rollback -------------------------------------------
def test_truncate_to_releases_whole_rejected_pages():
    a = PageAllocator(n_pages=12, page_size=4, n_nodes=2)
    a.alloc("r", 5)                       # capacity 20 tokens
    assert a.truncate_to("r", 9) == 2     # keep ceil(9/4) = 3 pages
    assert len(a.held["r"]) == 3 and a.free_pages == 8
    assert a.truncate_to("r", 9) == 0     # idempotent
    assert a.truncate_to("r", 12) == 0    # already within bound
    assert a.check_conservation()
    a.free("r")
    assert a.pages_in_use == 0


def test_truncate_to_respects_refcounts_of_shared_pages():
    a = PageAllocator(n_pages=12, page_size=4, n_nodes=1)
    pages = list(a.alloc("r", 4))         # snapshot: held mutates in place
    a.share(pages[3])                     # e.g. a cache node took the tail
    freed = a.truncate_to("r", 4)         # drop pages 1..3 (keep 1)
    assert freed == 2                     # the shared page did NOT free
    assert a.refcount_of(pages[3]) == 1   # other holder's reference lives
    assert len(a.held["r"]) == 1
    assert a.check_conservation()
    a.release_page(pages[3])
    a.free("r")
    assert a.free_pages == 11


def test_truncate_to_zero_and_conservation():
    a = PageAllocator(n_pages=8, page_size=4, n_nodes=1)
    a.alloc("r", 3)
    assert a.truncate_to("r", 0) == 3     # keep nothing
    assert a.held["r"] == [] and a.check_conservation()
    a.free("r")


# --- scheduler: multi-token verified emission ----------------------------------
def test_complete_spec_advances_pos_and_finishes():
    a = PageAllocator(n_pages=16, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2)
    s.submit(Request(rid="r", prompt_len=4, gen=6))
    plan = s.plan_step()
    req = plan.admitted[0]
    s.note_first_token(req, 11)
    assert req.pos == 4
    assert s.complete_spec(req, [12, 13, 14]) == []
    assert req.pos == 7 and req.tokens == [11, 12, 13, 14]
    done = s.complete_spec(req, [15, 16])         # reaches gen = 6
    assert done == [req] and req.state == "finished"
    assert req.tokens == [11, 12, 13, 14, 15, 16]
    assert a.pages_in_use == 0 and s.conserved(1)


# --- engine acceptance gates: spec on == spec off == dense ---------------------
def _run(prompts, gens, *, n_pages=48, budget=2.0, fused=True,
         spec=False, cache=False, max_batch=3, spec_k=6, max_len=None):
    cfg, params = get_tiny_model()
    max_len = max_len or max(p.shape[0] + g for p, g in zip(prompts, gens))
    eng = make_engine(cfg, params, max_batch=max_batch, n_pages=n_pages,
                      max_len=max_len, prefill_budget=budget, fused=fused,
                      spec_decode=spec, spec_k=spec_k, prefix_cache=cache)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(np.asarray(p), g, rid=f"r{i}")
    fin = eng.run()
    return eng, {r.rid: list(r.tokens) for r in fin}


def test_spec_tokens_identical_and_dispatches_drop():
    """The base gate: speculation on/off/dense all emit the same tokens,
    and on the looping continuations the tiny model produces, verified
    windows cut model passes per token."""
    cfg, params = get_tiny_model()
    S, gens = 12, [14, 12, 16, 10]
    prompts = seeded_prompts(cfg, len(gens), S, motif=4)
    max_len = S + max(gens)
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng_off, toks_off = _run(prompts, gens, spec=False)
    eng_on, toks_on = _run(prompts, gens, spec=True)
    assert toks_on == toks_off == dense
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_on["spec_verifies"] >= 1 and m_on["accept_rate"] > 0.0
    assert m_on["model_passes"] < m_off["model_passes"]
    assert m_on["dispatches_per_token"] < m_off["dispatches_per_token"]
    assert eng_on.alloc.check_conservation()
    assert eng_on.alloc.pages_in_use == 0


def test_spec_tokens_identical_under_forced_preemption():
    """Tight pool + unthrottled admission: preemption occurs with
    speculation on, recompute (re-drafting from a shorter history) stays
    bit-exact, and every page is returned."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 12, 6, 6
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng, toks = _run(prompts, [gen] * n_req, n_pages=14, budget=0.0,
                     spec=True)
    assert toks == dense
    assert eng.metrics()["preemptions"] >= 1
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_spec_tokens_identical_with_prefix_cache_hits():
    """Speculation composed with COW prefix sharing: hits skip prefill,
    drafts verify against pages that start shared, and tokens still
    match the all-off run exactly."""
    cfg, params = get_tiny_model()
    total, shared = 14, 10            # divergence mid-page (page_size 4)
    gens = [10, 9, 11, 8]
    prompts = seeded_prompts(cfg, len(gens), total, shared=shared, seed=3)
    eng_off, toks_off = _run(prompts, gens)
    eng_on, toks_on = _run(prompts, gens, spec=True, cache=True)
    assert toks_on == toks_off
    m = eng_on.metrics()
    assert m["prefix_hits"] >= 1
    assert m["spec_verifies"] >= 1
    assert eng_on.alloc.check_conservation()
    assert eng_on.alloc.pages_in_use == eng_on.cache.shared_pages


def test_spec_rollback_releases_pages_and_stays_exact():
    """A rejected draft that crossed a page boundary rolls whole pages
    back to the free list (truncate_to) without perturbing tokens."""
    cfg, params = get_tiny_model()
    S, gens = 12, [18, 16]
    prompts = seeded_prompts(cfg, len(gens), S, motif=3, seed=11)
    max_len = S + max(gens)
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng, toks = _run(prompts, gens, spec=True, spec_k=8, max_batch=2)
    assert toks == dense
    m = eng.metrics()
    assert m["spec_rollbacks"] >= 1, "trace never exercised rollback"
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_spec_forced_rejection_invalidates_row_signature_and_stays_exact():
    """Adversarial proposer: every draft is wrong, so every verify
    rejects and rolls pages back.  Pop-then-regrow can restore the same
    page COUNT with different physical pages — invisible to the (rid,
    preemptions, len) dirty-tracking signature — so the engine must
    forget the slot signature on rollback (or a stale device block row
    would write one tenant's KV into another's page).  Tokens must stay
    bit-identical to dense throughout, and the signature must be
    observed invalidated on a rollback window."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 8, 8, 3
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S, seed=5)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng = make_engine(cfg, params, max_batch=2, n_pages=13,
                      max_len=max_len, prefill_budget=0.0,
                      spec_decode=True, spec_k=4)

    def wrong(prompt, tokens, k_cap):
        if k_cap < 1 or not tokens:
            return []
        return [(int(tokens[-1]) + 1) % cfg.vocab_size] * min(3, k_cap)
    eng.spec.propose = wrong
    for i, p in enumerate(prompts):
        eng.submit(np.asarray(p), gen, rid=f"r{i}")
    saw_invalidation = False
    while eng.sched.waiting or eng.sched.running:
        before = eng.spec.stats.verifies
        eng.step()
        if eng.spec.stats.verifies > before and eng.sched.running:
            # the rejected slot's signature was forgotten this window
            saw_invalidation |= any(
                eng._slot_sig[s] is None for s in eng.sched.running)
    assert saw_invalidation
    assert eng.spec.stats.accepted == 0          # every draft was wrong
    assert eng.spec.stats.rollbacks >= 1
    toks = {r.rid: list(r.tokens) for r in eng.sched.finished}
    assert toks == dense
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_spec_shallow_drafts_never_cost_passes_at_wide_batch():
    """The worth-it gate: when the batch is wide and drafts are shallow
    (draft depth <= the fused window the slot rides for free), the
    engine must NOT pay a verify pass per slot — the batched scan
    amortizes better.  Speculation on may match but never materially
    exceed the plain path's model passes, and tokens stay identical."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 12, 12, 3
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S, motif=4, seed=2)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng_off, toks_off = _run(prompts, [gen] * n_req, spec=False,
                             max_len=max_len)
    # spec_k=2: drafts of at most 2 tokens against 4..8-token windows
    eng_on, toks_on = _run(prompts, [gen] * n_req, spec=True, spec_k=2,
                           max_len=max_len)
    assert toks_on == toks_off == dense
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_on["model_passes"] <= m_off["model_passes"]


def test_spec_off_by_default_and_metrics_gated():
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params)
    assert eng.spec is None
    m = eng.metrics()
    assert "accept_rate" not in m
    assert "model_passes" in m and "dispatches_per_token" in m
