"""Paged-KV serving engine: allocator striping, paged-vs-dense numerics,
scheduler conservation under preemption, trace-replay smoke.

Shared fixtures (tiny model, prompts, dense oracle) live in conftest.py
— docs/TESTING.md documents the oracle ladder they anchor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dense_oracle, get_tiny_model, make_engine, \
    seeded_prompts
from repro.core.memory_server import striped_owner
from repro.serving import (ContinuousBatchScheduler, NULL_PAGE,
                           PageAllocator, PagedEngine, Request)

KEY = jax.random.PRNGKey(3)


# --- allocator: the striping rule is core/memory_server's ---------------------
def test_allocator_owner_matches_striped_owner():
    a = PageAllocator(n_pages=33, page_size=8, n_nodes=4)
    for p in range(a.n_pages):
        assert a.owner(p) == striped_owner(p, 4)


def test_allocator_stripes_logical_pages_round_robin():
    a = PageAllocator(n_pages=33, page_size=8, n_nodes=4)
    pages = a.alloc("r0", 8)
    # logical page j lands on node j % n (the paper's address%n rule)
    assert [a.owner(p) for p in pages] == [striped_owner(j, 4)
                                           for j in range(8)]
    assert NULL_PAGE not in pages
    # a second tenant still gets a balanced stripe
    pages2 = a.alloc("r1", 4)
    assert [a.owner(p) for p in pages2] == [0, 1, 2, 3]
    occ = a.occupancy_by_node()
    assert max(occ) - min(occ) <= 1


def test_allocator_alloc_grow_free_roundtrip():
    a = PageAllocator(n_pages=9, page_size=4, n_nodes=2)
    assert a.free_pages == 8
    assert a.alloc("r0", 8) is not None
    assert a.alloc("r1", 1) is None        # all-or-nothing
    assert not a.grow("r0")
    assert a.free("r0") == 8
    assert a.free_pages == 8
    assert a.alloc("r1", 3) is not None and a.grow("r1", 2)
    assert len(a.held["r1"]) == 5


def test_allocator_rejects_degenerate_stripe():
    """A stripe wider than the allocatable pool leaves some node owning
    zero pages — its controller starves and conservation accounting
    skews — so construction must fail loudly, not limp along."""
    with pytest.raises(ValueError, match="at least one page"):
        PageAllocator(n_pages=3, page_size=4, n_nodes=3)
    with pytest.raises(ValueError, match="at least one page"):
        PageAllocator(n_pages=2, page_size=4, n_nodes=8)
    # boundary: n_nodes == n_pages - 1 is the thinnest legal stripe —
    # every node owns exactly one allocatable page
    a = PageAllocator(n_pages=4, page_size=4, n_nodes=3)
    pages = a.alloc("r", 3)
    assert sorted(a.owner(p) for p in pages) == [0, 1, 2]
    assert a.check_conservation()


def test_pages_for_zero_tokens_is_zero():
    a = PageAllocator(n_pages=9, page_size=4, n_nodes=2)
    assert a.pages_for(0) == 0
    assert a.pages_for(-3) == 0
    assert a.pages_for(1) == 1
    assert a.pages_for(4) == 1
    assert a.pages_for(5) == 2


def test_engine_rejects_empty_prompt_at_submit():
    """Zero-length (and non-1-D) prompts are rejected AT SUBMIT with a
    ValueError — not deep in prefill — and the rejection leaves the
    engine fully serviceable."""
    cfg, params = get_tiny_model()
    eng = make_engine(cfg, params)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((2, 3), np.int32), 4)
    # the failed submits left no residue: a real request still serves
    p = seeded_prompts(cfg, 1, 8, seed=5)[0]
    eng.submit(p, 3, rid="ok")
    fin = eng.run()
    assert len(fin) == 1 and len(fin[0].tokens) == 3
    assert eng.alloc.check_conservation()


def test_serve_cli_exits_2_on_empty_prompt():
    """--prompt-len 0 must exit with status 2 (CLI usage error) before
    any engine work, with the reason on stderr."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--tiny",
         "--engine", "paged", "--prompt-len", "0", "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 2, (r.returncode, r.stderr)
    assert "--prompt-len" in r.stderr


def test_allocator_reserve_is_best_effort_capacity():
    a = PageAllocator(n_pages=6, page_size=4, n_nodes=1)
    a.alloc("r", 1)
    # covers write positions < 10 -> 3 pages -> 12 token capacity
    assert a.reserve("r", 10) == 12
    assert len(a.held["r"]) == 3
    # pool only has 5 allocatable pages: best-effort, not all-or-nothing
    assert a.reserve("r", 40) == 20
    assert len(a.held["r"]) == 5
    a.free("r")
    assert a.free_pages == 5


# --- paged vs dense decode attention agree numerically ------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ps,nmax,Kv,G", [(8, 4, 2, 4), (16, 2, 1, 8)])
def test_paged_decode_attention_matches_dense(ps, nmax, Kv, G, dtype):
    from repro.kernels import ref
    B, hd = 3, 64
    H = Kv * G
    T = nmax * ps
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    # pool with a garbage null page; each sequence owns disjoint pages
    P = 1 + B * nmax
    k_pages = jax.random.normal(ks[1], (P, ps, Kv, hd),
                                jnp.float32).astype(dtype)
    v_pages = jax.random.normal(ks[2], (P, ps, Kv, hd),
                                jnp.float32).astype(dtype)
    bt = (1 + jnp.arange(B * nmax, dtype=jnp.int32)).reshape(B, nmax)
    pos = jnp.array([T - 1, ps + 3, 0], jnp.int32)
    # dense oracle on the gathered contiguous layout, per sequence
    o_paged = ref.paged_decode_attention(q, k_pages, v_pages, bt, pos)
    for b in range(B):
        kc = k_pages[bt[b]].reshape(1, T, Kv, hd)
        vc = v_pages[bt[b]].reshape(1, T, Kv, hd)
        o_dense = ref.decode_attention(q[b:b + 1], kc, vc, int(pos[b]))
        err = jnp.abs(o_paged[b:b + 1].astype(jnp.float32)
                      - o_dense.astype(jnp.float32)).max()
        assert err < (2e-2 if dtype == jnp.bfloat16 else 1e-6), (b, float(err))


def test_paged_decode_kernel_matches_ref():
    from repro.kernels import ops, ref
    B, H, hd, Kv, ps, nmax = 2, 8, 64, 2, 8, 3
    P = 1 + B * nmax
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pages = jax.random.normal(ks[1], (P, ps, Kv, hd))
    v_pages = jax.random.normal(ks[2], (P, ps, Kv, hd))
    bt = (1 + jnp.arange(B * nmax, dtype=jnp.int32)).reshape(B, nmax)
    pos = jnp.array([17, 9], jnp.int32)
    o_ref = ref.paged_decode_attention(q, k_pages, v_pages, bt, pos)
    o = ops.paged_decode_attention(q, k_pages, v_pages, bt, pos)
    assert jnp.abs(o - o_ref).max() < 2e-5


def test_paged_decode_attention_block_t_sweep_matches_ref():
    """The block_t hook sweeps several pages per grid step (padding the
    block table with null pages when nmax doesn't divide) — same output
    as the one-page-per-step schedule and the oracle."""
    from repro.kernels import ops, ref
    B, H, hd, Kv, ps, nmax = 2, 8, 64, 2, 8, 3
    P = 1 + B * nmax
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pages = jax.random.normal(ks[1], (P, ps, Kv, hd))
    v_pages = jax.random.normal(ks[2], (P, ps, Kv, hd))
    bt = (1 + jnp.arange(B * nmax, dtype=jnp.int32)).reshape(B, nmax)
    pos = jnp.array([17, 9], jnp.int32)
    o_ref = ref.paged_decode_attention(q, k_pages, v_pages, bt, pos)
    for block_t in (2 * ps, 4 * ps):     # nmax=3: both need null padding
        o = ops.paged_decode_attention(q, k_pages, v_pages, bt, pos,
                                       block_t=block_t)
        assert jnp.abs(o - o_ref).max() < 2e-5, block_t


def test_paged_decode_ignores_null_page_garbage():
    """Padded block-table slots point at the null page; its contents must
    not leak into the output."""
    from repro.kernels import ref
    B, H, hd, Kv, ps, nmax = 1, 4, 32, 2, 4, 3
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pages = jax.random.normal(ks[1], (4, ps, Kv, hd))
    v_pages = jax.random.normal(ks[2], (4, ps, Kv, hd))
    bt = jnp.array([[1, NULL_PAGE, NULL_PAGE]], jnp.int32)
    pos = jnp.array([ps - 1], jnp.int32)   # only page 1 is valid
    o1 = ref.paged_decode_attention(q, k_pages, v_pages, bt, pos)
    k2 = k_pages.at[NULL_PAGE].set(1e6)    # poison the null page
    v2 = v_pages.at[NULL_PAGE].set(-1e6)
    o2 = ref.paged_decode_attention(q, k2, v2, bt, pos)
    assert jnp.array_equal(o1, o2)


# --- engine: paged and dense produce identical tokens -------------------------


def test_paged_engine_tokens_match_dense_under_preemption():
    cfg, params = get_tiny_model()
    S, gen, n_req = 12, 6, 6
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    # tight pool + unthrottled admission -> preemption must occur
    eng = PagedEngine(cfg, params, max_batch=3, page_size=4, n_pages=14,
                      max_len=max_len, prefill_budget=0.0)
    for p in prompts:
        eng.submit(np.asarray(p), gen)
    finished = eng.run()
    assert len(finished) == n_req
    m = eng.metrics()
    assert m["preemptions"] >= 1, "pool was sized to force preemption"
    for r in finished:
        assert r.tokens == dense[r.rid], (r.rid, r.preemptions)
    assert eng.alloc.pages_in_use == 0     # every page returned


def test_paged_engine_interleaves_arrivals():
    """A request submitted mid-flight is served without disturbing the
    tokens of in-flight requests (continuous batching, not batch swap)."""
    cfg, params = get_tiny_model()
    S, gen = 8, 5
    prompts = seeded_prompts(cfg, 3, S)
    dense = dense_oracle(cfg, params, prompts, gen, S + gen)
    eng = PagedEngine(cfg, params, max_batch=2, page_size=4, n_pages=16,
                      max_len=S + gen)
    eng.submit(np.asarray(prompts[0]), gen, rid="r0")
    eng.step()
    eng.submit(np.asarray(prompts[1]), gen, rid="r1")
    eng.step()
    eng.submit(np.asarray(prompts[2]), gen, rid="r2")
    finished = eng.run()
    assert {r.rid for r in finished} == {"r0", "r1", "r2"}
    for r in finished:
        assert r.tokens == dense[r.rid]


# --- fused multi-token windows ------------------------------------------------
def _request_tokens(finished):
    return {r.rid: list(r.tokens) for r in finished}


def test_fused_windows_match_perstep_and_dense():
    """Fused K-step windows are token-for-token identical to per-step
    decode and to the dense engine — with varied gen lengths so
    completions land mid-trace and windows get cut to the horizon, and
    prompt_len == 2*page_size so windows start exactly on a page
    boundary and cross another one mid-window (pre-reserved)."""
    cfg, params = get_tiny_model()
    S, page = 8, 4
    gens = [3, 5, 8, 2, 6, 4]
    max_len = S + max(gens)
    prompts = seeded_prompts(cfg, len(gens), S)
    dense = dense_oracle(cfg, params, prompts, gens, max_len)

    def run(fused):
        eng = PagedEngine(cfg, params, max_batch=3, page_size=page,
                          n_pages=40, max_len=max_len, fused=fused,
                          max_window=8)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            eng.submit(np.asarray(p), g, rid=f"r{i}")
        return eng, _request_tokens(eng.run())

    eng_f, toks_f = run(True)
    eng_p, toks_p = run(False)
    assert toks_f == toks_p == dense
    # fused actually batched steps into windows
    assert eng_f.windows_run < eng_f.steps_run
    assert eng_p.windows_run == eng_p.decode_steps


def test_fused_windows_match_dense_under_forced_preemption():
    """Same tight-pool trace as the per-step preemption gate, but with
    fused windows: horizon shrinks instead of preempting mid-window,
    and the recompute stays exact."""
    cfg, params = get_tiny_model()
    S, gen, n_req = 12, 6, 6
    max_len = S + gen
    prompts = seeded_prompts(cfg, n_req, S)
    dense = dense_oracle(cfg, params, prompts, gen, max_len)
    eng = PagedEngine(cfg, params, max_batch=3, page_size=4, n_pages=14,
                      max_len=max_len, prefill_budget=0.0, fused=True,
                      max_window=8)
    for p in prompts:
        eng.submit(np.asarray(p), gen)
    finished = eng.run()
    assert len(finished) == n_req
    assert eng.metrics()["preemptions"] >= 1
    for r in finished:
        assert r.tokens == dense[r.rid], (r.rid, r.preemptions)
    assert eng.alloc.pages_in_use == 0


def test_fused_transfer_counters_drop_to_per_window():
    """Host<->device syncs: O(1 per token) per-step vs O(1 per window)
    fused — the transfer counter is the acceptance observable."""
    cfg, params = get_tiny_model()
    S, gen = 8, 9          # first token at prefill + one full 8-window
    prompts = seeded_prompts(cfg, 2, S)

    def run(fused):
        eng = PagedEngine(cfg, params, max_batch=2, page_size=4,
                          n_pages=24, max_len=S + gen, fused=fused,
                          max_window=8, prefill_budget=0.0)
        for i, p in enumerate(prompts):
            eng.submit(np.asarray(p), gen, rid=f"r{i}")
        return eng, _request_tokens(eng.run())

    eng_f, toks_f = run(True)
    eng_p, toks_p = run(False)
    assert toks_f == toks_p
    # per-step: one push + one pull per decode step (8 of them), plus
    # one push + one blocking pull per admitted prefill (2 requests)
    assert eng_p.decode_steps == 8
    assert eng_p.d2h_syncs == 8 + 2
    assert eng_p.h2d_syncs == 8 + 2
    # fused: both requests decode in ONE 8-step window dispatch
    assert eng_f.decode_steps == 8
    assert eng_f.windows_run == 1
    assert eng_f.d2h_syncs == 1 + 2
    assert eng_f.h2d_syncs <= 2 + 2
    m = eng_f.metrics()
    assert m["syncs_per_token"] < eng_p.metrics()["syncs_per_token"]


def test_metrics_count_emitted_tokens_in_flight():
    """tokens_out counts emitted work (prefill first token + decode),
    not just finished requests; finished-only is reported alongside."""
    cfg, params = get_tiny_model()
    [prompt] = seeded_prompts(cfg, 1, 8)
    eng = PagedEngine(cfg, params, max_batch=2, page_size=4, n_pages=16,
                      max_len=16, fused=True, max_window=8)
    eng.submit(np.asarray(prompt), 6)
    eng.step()         # prefill (1 token) + a 4-step window (5 -> pow2 4)
    m = eng.metrics()
    assert m["finished"] == 0 and m["tokens_finished"] == 0
    assert m["tokens_out"] == 5          # in-flight work is visible
    assert m["tok_per_s"] > 0.0
    eng.run()
    m = eng.metrics()
    assert m["tokens_out"] == m["tokens_finished"] == 6


# --- scheduler: safe horizon (host-only) ---------------------------------------
def test_safe_horizon_completion_and_admission_events():
    a = PageAllocator(n_pages=20, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2)
    assert s.safe_horizon(8) == 0          # nothing running
    s.submit(Request(rid="a", prompt_len=4, gen=10))
    plan = s.plan_step()
    s.note_first_token(plan.admitted[0], 1)
    # remaining 9, no waiting: capped by max_window, pages pre-reserved
    assert s.safe_horizon(8) == 8
    assert len(a.held["a"]) >= a.pages_for(4 + 8)
    # remaining tokens bound the horizon (completion only at window end)
    s.running[0].tokens = [1] * 7          # remaining = 3
    assert s.safe_horizon(8) == 3
    # a waiting request with a free slot + free pages -> horizon 1
    s.running[0].tokens = [1]
    s.submit(Request(rid="b", prompt_len=4, gen=2))
    assert s.safe_horizon(8) == 1


def test_safe_horizon_ignores_budget_blocked_head():
    """A waiting head whose prefill alone busts the interference budget
    cannot be admitted while anything runs — it must not collapse every
    fused window to K=1."""
    a = PageAllocator(n_pages=20, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2,
                                 prefill_cost_s=lambda n: 10.0 if n > 4
                                 else 0.1,
                                 decode_cost_s=1.0, prefill_budget=2.0)
    s.submit(Request(rid="a", prompt_len=4, gen=10))
    plan = s.plan_step()
    s.note_first_token(plan.admitted[0], 1)
    s.submit(Request(rid="big", prompt_len=8, gen=2))
    assert s.safe_horizon(8) == 8      # head is budget-blocked: no event
    # an admissible head (cost within budget) still caps the window
    s.submit(Request(rid="small", prompt_len=4, gen=2))
    s.waiting.sort(key=lambda r: r.prompt_len)   # make it the head
    assert s.safe_horizon(8) == 1


def test_safe_horizon_shrinks_under_page_pressure():
    a = PageAllocator(n_pages=7, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2)
    for rid in ("a", "b"):
        s.submit(Request(rid=rid, prompt_len=8, gen=8))
    plan = s.plan_step()
    assert len(plan.admitted) == 2         # 3 pages each, pool is dry
    for req in plan.admitted:
        s.note_first_token(req, 1)
    # remaining 7, but reserve() cannot grow past the held 12-token
    # capacity: horizon shrinks to 12 - 8 = 4 instead of preempting
    assert s.safe_horizon(8) == 4
    assert a.free_pages == 0


# --- scheduler: conservation under preemption (host-only) ---------------------
def _drive(sched, max_steps=500):
    """Drive the scheduler with fake tokens until it drains."""
    steps = 0
    while (sched.waiting or sched.running) and steps < max_steps:
        plan = sched.plan_step()
        for req in plan.admitted:
            sched.note_first_token(req, token=1)
        sched.complete_step({s: 1 for s in list(sched.running)})
        steps += 1
    return steps


def test_scheduler_conserves_requests_under_pressure():
    a = PageAllocator(n_pages=10, page_size=4, n_nodes=2)
    s = ContinuousBatchScheduler(a, max_batch=4)
    n = 8
    for i in range(n):
        s.submit(Request(rid=f"q{i}", prompt_len=6, gen=10))
    steps = _drive(s)
    assert steps < 500, "scheduler wedged"
    assert s.conserved(n)
    assert len(s.finished) == n
    for r in s.finished:
        assert len(r.tokens) == r.gen      # no dropped/duplicated tokens
    assert sum(r.preemptions for r in s.finished) >= 1
    assert a.pages_in_use == 0 and a.free_pages == a.n_pages - 1


def test_scheduler_rejects_request_larger_than_pool():
    a = PageAllocator(n_pages=4, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2)
    with pytest.raises(ValueError):
        s.submit(Request(rid="big", prompt_len=10, gen=10))


def test_scheduler_prices_admission_with_cost_engine():
    """A tight prefill budget staggers admissions; budget 0 disables
    pricing and admits as fast as slots allow."""
    def throttled(budget):
        a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
        s = ContinuousBatchScheduler(a, max_batch=4,
                                     prefill_cost_s=lambda n: 1.0,
                                     decode_cost_s=1.0,
                                     prefill_budget=budget)
        for i in range(4):
            s.submit(Request(rid=f"q{i}", prompt_len=4, gen=4))
        return len(s.plan_step().admitted)
    assert throttled(0.0) == 4         # pricing off
    assert throttled(1.0) == 1         # one prefill-step per step
    assert throttled(2.0) == 2


# --- chunked prefill: bit-identity + SLO scheduling ---------------------------
def test_chunked_prefill_tokens_match_monolithic_and_dense():
    """Chunked prefill is a KV-composition transform: any chunk size —
    page-multiple, page-sized, or deliberately misaligned — must emit
    tokens identical to the monolithic engine and the dense oracle,
    including prompts whose final chunk is a partial page."""
    cfg, params = get_tiny_model()
    gens = [5, 6, 4, 7]
    lens = [13, 10, 16, 9]           # non-page-aligned tails included
    max_len = max(s + g for s, g in zip(lens, gens))
    prompts = [seeded_prompts(cfg, 1, s, seed=50 + i)[0]
               for i, s in enumerate(lens)]
    dense = dense_oracle(cfg, params, prompts, gens, max_len)

    def run(chunked, chunk_tokens=0):
        eng = PagedEngine(cfg, params, max_batch=3, page_size=4,
                          n_pages=40, max_len=max_len, fused=True,
                          max_window=4, chunked_prefill=chunked,
                          chunk_tokens=chunk_tokens)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            eng.submit(np.asarray(p), g, rid=f"r{i}", slo="interactive")
        toks = {r.rid: list(r.tokens) for r in eng.run()}
        assert eng.alloc.pages_in_use == 0
        return eng, toks

    _, mono = run(False)
    assert mono == dense
    for ct in (8, 4, 5):             # 2 pages, 1 page, misaligned
        eng, toks = run(True, ct)
        assert toks == dense, f"chunk_tokens={ct}"
        m = eng.metrics()
        assert m["chunk_dispatches"] >= len(prompts)
        assert m["chunk_tasks"] >= len(prompts)


def test_chunked_admission_is_edf_not_fifo():
    """Chunked admission orders the waiting queue by SLO deadline, not
    arrival: an interactive request submitted AFTER a batch request (same
    step) is admitted first.  The monolithic scheduler keeps FIFO."""
    a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=1, chunked=True)
    s.submit(Request(rid="slow", prompt_len=8, gen=2, slo="batch"))
    s.submit(Request(rid="fast", prompt_len=8, gen=2, slo="interactive"))
    plan = s.plan_step()
    assert [r.rid for r in plan.admitted] == ["fast"]
    assert s.prefilling and s.waiting[0].rid == "slow"


def test_plan_chunks_strict_progress_and_page_alignment():
    """Every prefilling request advances by at least one chunk per round
    (starvation-freedom), every non-final chunk boundary is page-aligned,
    and a throttled budget still drains the queue."""
    a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=4, chunked=True,
                                 chunk_tokens=4,
                                 prefill_cost_s=lambda n: float(n),
                                 decode_cost_s=1.0)
    for i, plen in enumerate((13, 9, 11)):
        s.submit(Request(rid=f"q{i}", prompt_len=plen, gen=3,
                         slo="interactive"))
    # park a decoding request so the budget is active (priced, tight)
    s.submit(Request(rid="dec", prompt_len=4, gen=30, slo="interactive"))
    plan = s.plan_step()
    dec = next(r for r in plan.admitted if r.rid == "dec")
    # promote dec to running so plan_chunks prices against its stall_frac
    dec.prefilled = dec.prompt_len
    s.finish_prefill(dec, token=1)
    rounds = 0
    while s.prefilling and rounds < 50:
        before = {r.rid: r.prefilled for r in s.prefilling.values()}
        tasks = s.plan_chunks(window=1)
        seen = set()
        for req, start, n in tasks:
            assert n >= 1 and start + n <= req.prompt_len
            if start + n < req.prompt_len:
                assert (start + n) % a.page_size == 0, \
                    "non-final chunk boundary off the page grid"
            seen.add(req.rid)
        # strict progress: every prefilling request got >= 1 chunk
        assert seen == set(before)
        for req in list(s.prefilling.values()):
            if req.prefilled == req.prompt_len:
                s.finish_prefill(req, token=1)
        rounds += 1
    assert not s.prefilling, "chunk rounds starved a request"
    assert rounds >= 2, "budget never throttled (all drained in one round)"
    assert s.chunk_tasks >= 3


def test_plan_chunks_drains_at_full_speed_when_idle():
    """With nothing decoding, the budget is unbounded: a whole prompt
    drains in ONE round (the monolithic fast path recovered)."""
    a = PageAllocator(n_pages=64, page_size=4, n_nodes=1)
    s = ContinuousBatchScheduler(a, max_batch=2, chunked=True,
                                 chunk_tokens=4,
                                 prefill_cost_s=lambda n: float(n),
                                 decode_cost_s=1.0)
    s.submit(Request(rid="solo", prompt_len=17, gen=2, slo="batch"))
    s.plan_step()
    tasks = s.plan_chunks(window=8)
    req = s.prefilling[next(iter(s.prefilling))]
    assert req.prefilled == req.prompt_len
    assert len(tasks) == 5           # 17 tokens / 4-token chunks


def test_chunked_requests_carry_wall_and_deadline_stamps():
    cfg, params = get_tiny_model()
    [p] = seeded_prompts(cfg, 1, 10, seed=91)
    eng = PagedEngine(cfg, params, max_batch=2, page_size=4, n_pages=16,
                      max_len=16, chunked_prefill=True)
    req = eng.submit(np.asarray(p), 4, slo="interactive")
    from repro.serving import get_slo
    assert req.deadline_step == req.arrived_step \
        + get_slo("interactive").ttft_steps
    eng.run()
    assert req.arrived_wall is not None
    assert req.first_token_wall >= req.arrived_wall
    assert req.finished_wall >= req.first_token_wall


def test_get_slo_rejects_unknown_class():
    from repro.serving import get_slo
    with pytest.raises(KeyError, match="interactive"):
        get_slo("platinum")


# --- trace replay smoke -------------------------------------------------------
def test_serve_trace_smoke():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import serve_trace
    eng, rows, totals = serve_trace.replay(
        serve_trace.default_tenants(quick=True), max_batch=2, page_size=4)
    assert totals["tokens"] > 0 and totals["steps"] > 0
    assert 0 < totals["occupancy_peak"] <= 1.0
    by_tenant = {r["tenant"]: r for r in rows}
    assert by_tenant["chat"]["requests"] == 6
    assert by_tenant["burst"]["requests"] == 4
    table = serve_trace.format_table(rows, totals)
    assert "chat" in table and "burst" in table
    fleet = serve_trace.fleet_view(eng)
    assert "chat" in fleet


def test_replay_accepts_trace_names_and_validates_tenants():
    """replay() called programmatically with a bad trace name or a
    malformed tenants list must fail fast with exit code 2 listing the
    valid traces — not deep inside prompt_for (mirrors run.py --only)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import serve_trace
    for bad in ("definitely-not-a-trace", [], ["not-a-tenant"],
                [serve_trace.Tenant("t", 0, 0.0, 8, 4)],
                [serve_trace.Tenant("t", 2, 0.0, 8, 4, slo="platinum")],
                object()):
        with pytest.raises(SystemExit) as exc:
            serve_trace.resolve_tenants(bad)
        assert exc.value.code == 2, bad
    # valid names resolve to the registered factories
    for name, factory in serve_trace.TRACES.items():
        got = serve_trace.resolve_tenants(name, quick=True)
        assert got == factory(True), name


def test_replay_bad_trace_exits_2_in_subprocess():
    """End-to-end contract: the process exits 2 and stderr names the
    valid traces (same shape as run.py --only's unknown-pattern error)."""
    import os
    import subprocess
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    code = ("import sys; sys.path[:0] = ['src', '.'];\n"
            "from benchmarks.serve_trace import replay\n"
            "replay('definitely-not-a-trace')\n")
    proc = subprocess.run([sys.executable, "-c", code], cwd=root,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 2
    assert "valid traces:" in proc.stderr
    for name in ("mixed", "overload", "shared-prefix", "repetitive"):
        assert name in proc.stderr
