"""Benchmark driver: one section per paper table/figure + micro timings +
the roofline table.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    sys.path.insert(0, ".")
    from benchmarks import cost_sweep as cs
    from benchmarks import paper_tables as pt
    from benchmarks import perf_micro as pm
    from benchmarks import roofline_table as rt

    sections = [
        ("Table II (link energies)", pt.table2_link_energy),
        ("Table III (e/c, E/C)", pt.table3_ec_ratio),
        ("Table IV (power/core)", pt.table4_power),
        ("Fig 3 (memory/task)", pt.fig3_memory_per_task),
        ("Fig 5 (thread throughput)", pt.fig5_thread_throughput),
        ("Fig 9/10 (DVFS)", pt.fig9_fig10_dvfs),
        ("Fig 11 (neuron scaling)", pt.fig11_neuron_scaling),
        ("Fig 8/9 (nOS cost sweep)", cs.sweep_rows),
        ("micro: train grad", pm.micro_train_steps),
        ("micro: kernels", pm.micro_kernels),
        ("micro: data", pm.micro_data_pipeline),
        ("micro: checkpoint", pm.micro_checkpoint),
        ("roofline table", rt.roofline_rows),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception:
            traceback.print_exc()
            failures += 1
    print("# --- full roofline table ---")
    try:
        rt.print_full_table()
    except Exception:
        traceback.print_exc()
        failures += 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
