"""Model-stack behaviour: ref-vs-blocked equivalence, decode-vs-forward
consistency, segment construction."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import get_config, get_tiny_config
from repro.models import attention, lm, modules as nn, rglru, rwkv6


def test_attention_ref_vs_blocked():
    k = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 128, 4, 16
    ks = jax.random.split(k, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    kk = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    for causal, window, cap in [(True, None, None), (True, 32, None),
                                (False, None, None), (True, None, 30.0),
                                (True, 32, 30.0)]:
        r = attention.attend_ref(q, kk, v, causal=causal, window=window,
                                 scale=0.25, softcap=cap)
        b = attention.attend_blocked(q, kk, v, causal=causal, window=window,
                                     scale=0.25, softcap=cap,
                                     block_q=16, block_kv=32)
        assert jnp.abs(r - b).max() < 1e-4, (causal, window, cap)


def test_rglru_assoc_matches_ref():
    k = jax.random.PRNGKey(1)
    B, S, W = 2, 128, 64
    ks = jax.random.split(k, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    hs1, hT1 = rglru._scan_ref(a, b, h0)
    hs2, hT2 = rglru._scan_assoc(a, b, h0)
    assert jnp.abs(hs1 - hs2).max() < 1e-4
    assert jnp.abs(hT1 - hT2).max() < 1e-4


def test_rwkv_chunked_matches_ref():
    k = jax.random.PRNGKey(2)
    B, S, H, K = 2, 128, 2, 16
    ks = jax.random.split(k, 6)
    r = jax.random.normal(ks[0], (B, S, H, K))
    kk = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 1.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    S0 = jax.random.normal(ks[5], (B, H, K, K)).astype(jnp.float32)
    o1, s1 = rwkv6._wkv_ref(r, kk, v, lw, u, S0)
    o2, s2 = rwkv6._wkv_chunked(r, kk, v, lw, u, S0)
    assert jnp.abs(o1 - o2).max() < 1e-3
    assert jnp.abs(s1 - s2).max() < 1e-3


@pytest.mark.parametrize("arch", ["qwen3-14b", "gemma2-27b",
                                  "recurrentgemma-2b", "rwkv6-1.6b",
                                  "deepseek-v3-671b"])
def test_decode_matches_forward(arch):
    """Prefill(S) + decode(token S) logits == forward(S+1) last logits."""
    cfg = get_tiny_config(arch).replace(impl="ref")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0,
                              cfg.vocab_size)
    h, _, _ = lm.forward(params, cfg, toks, mode="train")
    hn = nn.rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    full = lm.head_logits(params, cfg, hn)[:, S]
    _, caches = lm.prefill(params, cfg, toks[:, :S], max_len=S + 8)
    dl, _ = lm.decode_step(params, cfg, toks[:, S:S + 1], caches, S)
    rel = jnp.abs(full - dl[:, 0]).max() / (jnp.abs(full).max() + 1e-9)
    assert rel < 2e-2, (arch, float(rel))


def test_multi_step_decode_consistency():
    """Greedy decode step-by-step == teacher-forced forward argmaxes."""
    cfg = get_tiny_config("qwen3-14b").replace(impl="ref")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S, G = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                              cfg.vocab_size)
    logits, caches = lm.prefill(params, cfg, toks, max_len=S + G)
    seq = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for i in range(G - 1):
        logits, caches = lm.decode_step(params, cfg, seq[-1], caches, S + i)
        seq.append(jnp.argmax(logits, -1).astype(jnp.int32))
    gen = jnp.concatenate(seq, axis=1)
    # teacher-force the generated tokens through the full forward
    full = jnp.concatenate([toks, gen], axis=1)
    h, _, _ = lm.forward(params, cfg, full, mode="train")
    hn = nn.rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits_all = lm.head_logits(params, cfg, hn)
    want = jnp.argmax(logits_all[:, S - 1:S + G - 1], -1)
    assert (want == gen).mean() > 0.95  # ties under fp tolerance


def test_segments():
    segs = lm.make_segments(get_config("deepseek-v3-671b"))
    assert [(s.n_cycles, s.is_moe) for s in segs] == [(3, False), (58, True)]
    segs = lm.make_segments(get_config("recurrentgemma-2b"))
    assert segs[0].kinds == ("rglru", "rglru", "local")
    assert segs[0].n_cycles == 8
    assert sum(s.n_cycles * len(s.kinds) for s in segs) == 26
    segs = lm.make_segments(get_config("gemma2-27b"))
    assert segs[0].kinds == ("local", "attn") and segs[0].n_cycles == 23


def test_loss_decreases_under_training():
    from repro.configs.base import ShapeConfig
    from repro.runtime import train_loop
    cfg = get_tiny_config("qwen3-14b")
    shape = ShapeConfig("t", 64, 4, "train")
    job = train_loop.TrainJobConfig(steps=30, log_every=10, peak_lr=3e-3,
                                    warmup=5)
    out = train_loop.run(cfg, shape, job=job)
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < first - 0.2, (first, last)
