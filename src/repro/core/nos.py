"""Swallow §VIII: nOS — a nano-OS for many-core, as a mesh-slice scheduler.

nOS abstracts thread creation, mapping, network configuration and energy
optimisation.  At pod scale the analogous runtime owns: mesh slicing
(placement), job admission (the paper's "multiple non-interacting
applications"), per-slice energy accounting, and restart orchestration.
The scheduler is pure host-side logic — unit-testable, no devices
needed — and produces placements that ``jax.make_mesh`` sub-meshes can
realise.

Placement policy (paper-faithful): jobs are independent (C1), so slices
never share chips; allocation is first-fit over whole "data" rows so the
"model" axis (the high-bandwidth dimension) is never split between
tenants — locality exactly as §II-B argues.

Cost-aware admission: a job submitted with a ``ModelConfig`` (instead of
a bare row count) is priced by the :mod:`repro.core.costs` engine at
placement time — every feasible row count is a candidate slice, each is
priced as a ``Layout(data=rows, model=model_cols)``, and the scheduler
picks the one minimising the per-step energy-delay product (the §VIII
"energy optimisation" responsibility, made concrete).  The chosen
estimate also drives per-job power/energy accounting, replacing the flat
active-watts assumption in ``power_estimate_w``.

Serving telemetry (what is extrapolated beyond the paper): the paged
serving engine (:mod:`repro.serving`) reports per-job KV pages held,
tokens emitted, queue latency, preemptions and engine-priced energy
through :meth:`NOS.update_serving`; ``serving_table()`` renders the
fleet view — the paper's "program that can measure its own power",
widened to a tenant that can measure its own cache footprint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.serving.telemetry import MetricsRegistry, gauge_attr


def _gauge(name=None):
    # Job's serving gauges live in its per-job MetricsRegistry (attr
    # ``metrics``) — unannotated class attributes, so the dataclass
    # constructor/repr surface is unchanged (scheduling fields only)
    return gauge_attr(name, registry="metrics", default=0)


@dataclass
class Job:
    name: str
    rows_needed: int = 0               # data-axis rows (model axis is whole);
                                       # 0 => cost engine chooses at placement
    steps: int = 0
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    rows: Tuple[int, ...] = ()
    state: str = "pending"             # pending|running|done|failed
    # -- cost-aware extension (set when submitted with a config) -----------
    config: Optional[object] = None    # repro.configs.base.ModelConfig
    shape: Optional[object] = None     # repro.configs.base.ShapeConfig
    link_mode: str = "circuit"         # §V model used to price placement
    auto_size: bool = False            # engine re-sizes at every attempt
    max_rows: int = 0                  # tenant quota; 0 = unlimited
    estimate: Optional[object] = None  # costs.CostEstimate of chosen slice
    energy_j: float = 0.0              # accrued at finish()

    # -- serving extension (paged engine reports through update_serving) ----
    # registry-backed gauges: one MetricsRegistry per job (``metrics``),
    # same external attribute names as the old dataclass fields
    pages_held = _gauge()              # KV pages currently allocated
    peak_pages = _gauge()
    tokens_out = _gauge()              # tokens emitted so far
    queue_latency_s = _gauge()         # mean admission->first-token latency
    preemptions = _gauge()
    # -- prefix-sharing overlay (repro.serving.prefix_cache) ----------------
    shared_pages = _gauge()            # pages owned by the radix tree
    prefix_hit_rate = _gauge()         # admissions served from shared pages
    bytes_deduped = _gauge()           # KV bytes NOT re-prefilled
    # -- speculative decoding (repro.serving.spec_decode) --------------------
    accept_rate = _gauge()             # draft tokens the verifier kept
    dispatches_per_token = _gauge()    # sequential model passes per token
    spec_k = _gauge()                  # mean adaptive draft depth requested
    # -- SLO telemetry (repro.serving.slo + the chunked scheduler) -----------
    ttft_p99_s = _gauge()              # tail first-token latency observed
    ttft_target_s = _gauge()           # the class deadline, priced to seconds
    goodput_frac = _gauge()            # fraction of tokens from SLO-met reqs
    # -- fault plane (repro.serving.faults) ----------------------------------
    pages_quarantined = _gauge()       # pages lost to dead stripes (cumul.)
    requests_recovered = _gauge()      # fault resets recomputed exactly
    tokens_recomputed = _gauge()       # emitted tokens discarded by resets
    recovery_steps_p99 = _gauge()      # reset -> first-token tail latency
    # -- predicted-vs-measured attribution (repro.serving.telemetry) ---------
    predicted_s = _gauge()             # cost-engine seconds, dispatch spans
    measured_s = _gauge()              # wall seconds over the same spans
    predicted_j = _gauge()             # §VI joules over the same spans
    model_error = None                 # per-phase rollup dict (or None)

    def __post_init__(self):
        self.metrics = MetricsRegistry()


@dataclass
class NOS:
    """First-fit row scheduler over a (data x model) pod."""
    data_rows: int = 16
    model_cols: int = 16
    jobs: Dict[str, Job] = field(default_factory=dict)
    _free: List[int] = field(default_factory=list)
    _quarantined: set = field(default_factory=set)

    def __post_init__(self):
        self._free = list(range(self.data_rows))
        self._quarantined = set()

    # -- admission -----------------------------------------------------------
    def submit(self, job, *, name: Optional[str] = None, shape=None,
               steps: int = 0, mode: str = "circuit",
               max_rows: int = 0) -> bool:
        """Admit a job.

        Accepts either a prepared :class:`Job`, or a ``ModelConfig``
        (plus ``name``/``shape``/``steps``/``max_rows`` keywords) — the
        cost-aware path, where the engine sizes the slice instead of the
        caller (``max_rows`` is the tenant's quota).
        """
        if not isinstance(job, Job):
            job = Job(name=name or getattr(job, "name", "job"),
                      config=job, shape=shape, steps=steps, link_mode=mode,
                      max_rows=max_rows)
        if job.config is not None and job.rows_needed == 0:
            job.auto_size = True
        job.submitted_at = job.submitted_at or time.time()
        self.jobs[job.name] = job
        return self._try_place(job)

    def _size_from_costs(self, job: Job) -> int:
        """Price every feasible row count; return the EDP-optimal one."""
        from repro.core import costs as costs_mod
        free = len(self._free)
        if job.max_rows:
            free = min(free, job.max_rows)
        if free == 0:
            return 1            # nothing free: ask for the minimal slice
        B = job.shape.global_batch if job.shape is not None else 0
        candidates = [r for r in range(1, free + 1) if not B or B % r == 0]
        if not candidates:
            candidates = list(range(1, free + 1))
        best_r, best = None, None
        for r in candidates:
            lay = costs_mod.Layout(data=r, model=self.model_cols)
            est = costs_mod.estimate(job.config, lay, job.link_mode,
                                     job.shape)
            if best is None or est.edp() < best.edp():
                best_r, best = r, est
        job.estimate = best
        return best_r

    def _try_place(self, job: Job) -> bool:
        if job.state != "pending":
            return False
        if job.config is not None:
            if job.auto_size:
                job.rows_needed = self._size_from_costs(job)
            elif job.estimate is None:
                from repro.core import costs as costs_mod
                lay = costs_mod.Layout(data=max(job.rows_needed, 1),
                                       model=self.model_cols)
                job.estimate = costs_mod.estimate(job.config, lay,
                                                  job.link_mode, job.shape)
        if job.rows_needed <= 0 or job.rows_needed > len(self._free):
            return False
        job.rows = tuple(sorted(self._free[:job.rows_needed]))
        self._free = self._free[job.rows_needed:]
        job.state = "running"
        job.started_at = time.time()
        return True

    def finish(self, name: str, state: str = "done"):
        job = self.jobs[name]
        if job.estimate is not None and job.steps:
            n_chips = len(job.rows) * self.model_cols
            job.energy_j += job.steps * job.estimate.energy.total_j * n_chips
        self._free = sorted(self._free + list(job.rows))
        job.rows = ()
        job.state = state
        # admit pending jobs in FIFO order
        for j in sorted(self.jobs.values(), key=lambda j: j.submitted_at):
            if j.state == "pending":
                self._try_place(j)

    def fail_rows(self, rows: List[int]):
        """Hardware failure: evict jobs touching the rows, quarantine them."""
        evicted = []
        for job in self.jobs.values():
            if job.state == "running" and set(job.rows) & set(rows):
                job.state = "pending"
                self._free = sorted(set(self._free) | set(job.rows))
                job.rows = ()
                evicted.append(job.name)
        self._free = [r for r in self._free if r not in rows]
        self._quarantined |= {r for r in rows if 0 <= r < self.data_rows}
        for j in sorted(self.jobs.values(), key=lambda j: j.submitted_at):
            if j.state == "pending":
                self._try_place(j)
        return evicted

    def restore_rows(self, rows: List[int]) -> List[str]:
        """Elastic re-join — the inverse of :meth:`fail_rows`: rows a
        failure quarantined return to the free pool, and pending jobs
        re-place in FIFO order against the recovered capacity.  Rows
        that were never quarantined are ignored (restoring is idempotent
        and never double-frees a row a running job holds).  Returns the
        names of jobs placed by the recovery."""
        back = {r for r in rows if r in self._quarantined}
        self._quarantined -= back
        self._free = sorted(set(self._free) | back)
        placed = []
        for j in sorted(self.jobs.values(), key=lambda j: j.submitted_at):
            if j.state == "pending" and self._try_place(j):
                placed.append(j.name)
        return placed

    # -- accounting -----------------------------------------------------------
    def utilisation(self) -> float:
        used = self.data_rows - len(self._free)
        return used / self.data_rows

    def power_estimate_w(self, active_w: float = 200.0,
                         idle_w: float = 60.0) -> float:
        """Fleet power (Fig. 8/9 logic): costed jobs contribute their
        engine-estimated per-chip draw, uncosted slices a flat TDP-ish
        figure, free rows idle — energy proportionality at the
        allocation level."""
        total = len(self._free) * idle_w * self.model_cols
        for job in self.jobs.values():
            if job.state != "running":
                continue
            per_chip = (job.estimate.energy.w_per_chip
                        if job.estimate is not None else active_w)
            total += len(job.rows) * self.model_cols * per_chip
        return total

    def energy_account(self) -> Dict[str, float]:
        """Joules accrued per finished job (the paper's 'program that can
        measure its own power', at the scheduler level)."""
        return {j.name: j.energy_j for j in self.jobs.values()
                if j.energy_j > 0.0}

    def update_serving(self, name: str, *, pages_held: Optional[int] = None,
                       peak_pages: Optional[int] = None,
                       tokens_out: Optional[int] = None,
                       queue_latency_s: Optional[float] = None,
                       preemptions: Optional[int] = None,
                       energy_j: Optional[float] = None,
                       shared_pages: Optional[int] = None,
                       prefix_hit_rate: Optional[float] = None,
                       bytes_deduped: Optional[int] = None,
                       accept_rate: Optional[float] = None,
                       dispatches_per_token: Optional[float] = None,
                       spec_k: Optional[float] = None,
                       ttft_p99_s: Optional[float] = None,
                       ttft_target_s: Optional[float] = None,
                       goodput_frac: Optional[float] = None,
                       pages_quarantined: Optional[int] = None,
                       requests_recovered: Optional[int] = None,
                       tokens_recomputed: Optional[int] = None,
                       recovery_steps_p99: Optional[float] = None,
                       predicted_s: Optional[float] = None,
                       measured_s: Optional[float] = None,
                       predicted_j: Optional[float] = None,
                       model_error: Optional[dict] = None):
        """Serving-engine telemetry (§VIII: nOS owns per-application
        accounting).  The paged engine calls this per replay/step batch;
        ``energy_j`` accrues (engine-priced decode energy), ``peak_pages``
        is monotone, the rest are gauges.  The prefix-sharing gauges
        (``shared_pages`` / ``prefix_hit_rate`` / ``bytes_deduped``)
        surface the §X-B overlay: how much of the striped store is
        serving more than one tenant, and how much prefill it saved.
        The speculative-decoding gauges (``accept_rate`` /
        ``dispatches_per_token`` / ``spec_k``) surface the §V
        payload-per-dispatch lever: how many sequential model passes
        each emitted token cost, and how deep the per-tenant adaptive
        controller is currently drafting.  The SLO gauges (``ttft_p99_s``
        vs ``ttft_target_s``, ``goodput_frac``) surface the chunked
        scheduler's deadline contract: tail first-token latency against
        the tenant's class deadline (priced to seconds by the cost
        engine's ``decode_cost_s``) and the fraction of emitted tokens
        that came from requests whose deadline was met.  The fault-plane
        gauges (``pages_quarantined`` / ``requests_recovered`` /
        ``tokens_recomputed`` / ``recovery_steps_p99``) surface the
        §VIII failure story: how much of the striped store a dead node
        took with it, how many tenants were reset and recomputed
        exactly, and the tail latency of that recovery.  The
        attribution gauges (``predicted_s`` / ``measured_s`` /
        ``predicted_j``, plus the per-phase ``model_error`` rollup from
        :func:`repro.serving.telemetry.rollup_dispatch_events`) surface
        the §IV contract — the cost model's priced seconds and §VI
        joules against the wall clock the dispatch spans actually
        measured — rendered fleet-wide by :meth:`attribution_table`."""
        job = self.jobs[name]
        if pages_held is not None:
            job.pages_held = pages_held
            job.peak_pages = max(job.peak_pages, pages_held)
        if peak_pages is not None:
            job.peak_pages = max(job.peak_pages, peak_pages)
        if tokens_out is not None:
            job.tokens_out = tokens_out
        if queue_latency_s is not None:
            job.queue_latency_s = queue_latency_s
        if preemptions is not None:
            job.preemptions = preemptions
        if energy_j is not None:
            job.energy_j += energy_j
        if shared_pages is not None:
            job.shared_pages = shared_pages
        if prefix_hit_rate is not None:
            job.prefix_hit_rate = prefix_hit_rate
        if bytes_deduped is not None:
            job.bytes_deduped = bytes_deduped
        if accept_rate is not None:
            job.accept_rate = accept_rate
        if dispatches_per_token is not None:
            job.dispatches_per_token = dispatches_per_token
        if spec_k is not None:
            job.spec_k = spec_k
        if ttft_p99_s is not None:
            job.ttft_p99_s = ttft_p99_s
        if ttft_target_s is not None:
            job.ttft_target_s = ttft_target_s
        if goodput_frac is not None:
            job.goodput_frac = goodput_frac
        if pages_quarantined is not None:
            job.pages_quarantined = pages_quarantined
        if requests_recovered is not None:
            job.requests_recovered = requests_recovered
        if tokens_recomputed is not None:
            job.tokens_recomputed = tokens_recomputed
        if recovery_steps_p99 is not None:
            job.recovery_steps_p99 = recovery_steps_p99
        if predicted_s is not None:
            job.predicted_s = predicted_s
        if measured_s is not None:
            job.measured_s = measured_s
        if predicted_j is not None:
            job.predicted_j = predicted_j
        if model_error is not None:
            job.model_error = dict(model_error)

    def attribution_table(self) -> str:
        """Fleet-level predicted-vs-measured view (§IV 'measure your own
        power', applied to the cost model itself): per job — and per
        dispatch phase when a ``model_error`` rollup was reported — the
        cost engine's priced seconds and §VI joules next to measured
        wall seconds, with the measured/predicted ratio that says how
        honest the model is."""
        hdr = (f"{'job/phase':<24} {'count':>6} {'pred_s':>10} "
               f"{'meas_s':>10} {'meas/pred':>9} {'pred_J':>10} "
               f"{'comm_s':>9}")
        rows = [hdr, "-" * len(hdr)]
        for j in self.jobs.values():
            if not (j.measured_s or j.model_error):
                continue
            ratio = (j.measured_s / j.predicted_s
                     if j.predicted_s else float("nan"))
            comm = sum(r.get("predicted_comms_s", 0.0)
                       for r in (j.model_error or {}).values())
            rows.append(f"{j.name:<24} {'':>6} {j.predicted_s:>10.4f} "
                        f"{j.measured_s:>10.4f} {ratio:>9.2f} "
                        f"{j.predicted_j:>10.3f} {comm:>9.4f}")
            for phase in sorted(j.model_error or ()):
                r = j.model_error[phase]
                pr = (r["measured_s"] / r["predicted_s"]
                      if r.get("predicted_s") else float("nan"))
                rows.append(f"  {phase:<22} {int(r.get('count', 0)):>6} "
                            f"{r.get('predicted_s', 0.0):>10.4f} "
                            f"{r.get('measured_s', 0.0):>10.4f} "
                            f"{pr:>9.2f} {r.get('predicted_j', 0.0):>10.3f} "
                            f"{r.get('predicted_comms_s', 0.0):>9.4f}")
        return "\n".join(rows)

    def serving_table(self) -> str:
        """Fleet view of the serving gauges (pages, tokens, TTFT, the
        prefix-sharing overlay columns, the SLO contract: observed p99
        TTFT vs the class target plus goodput, and the fault plane:
        quarantined pages, recovered requests, recomputed tokens, and
        the recovery tail)."""
        rows = [f"{'job':<18} {'pages':>6} {'peak':>5} {'tokens':>8} "
                f"{'ttft_s':>9} {'preempt':>7} {'energy_J':>10} "
                f"{'shared':>6} {'hit%':>5} {'dedupKB':>8} "
                f"{'acc%':>5} {'disp/tok':>8} {'K':>5} "
                f"{'p99/tgt_s':>18} {'good%':>5} "
                f"{'quar':>5} {'recov':>5} {'recomp':>6} {'rcvp99':>6}"]
        for j in self.jobs.values():
            if j.tokens_out == 0 and j.peak_pages == 0:
                continue
            slo = f"{j.ttft_p99_s:>8.2e}/{j.ttft_target_s:<8.2e}" \
                if j.ttft_target_s > 0 else f"{'-':>18}"
            rows.append(f"{j.name:<18} {j.pages_held:>6} {j.peak_pages:>5} "
                        f"{j.tokens_out:>8} {j.queue_latency_s:>9.2e} "
                        f"{j.preemptions:>7} {j.energy_j:>10.3g} "
                        f"{j.shared_pages:>6} "
                        f"{j.prefix_hit_rate * 100:>5.0f} "
                        f"{j.bytes_deduped / 1024:>8.0f} "
                        f"{j.accept_rate * 100:>5.0f} "
                        f"{j.dispatches_per_token:>8.2f} "
                        f"{j.spec_k:>5.1f} "
                        f"{slo} "
                        f"{j.goodput_frac * 100:>5.0f} "
                        f"{j.pages_quarantined:>5} "
                        f"{j.requests_recovered:>5} "
                        f"{j.tokens_recomputed:>6} "
                        f"{j.recovery_steps_p99:>6.1f}")
        return "\n".join(rows)

    def placement_table(self) -> str:
        rows = []
        for j in self.jobs.values():
            line = f"{j.name:<16} {j.state:<8} rows={list(j.rows)}"
            if j.estimate is not None:
                line += (f" step={j.estimate.step_time_s * 1e3:.2f}ms"
                         f" {j.estimate.energy.w_per_chip:.0f}W/chip")
            rows.append(line)
        rows.append(f"free rows: {self._free}")
        return "\n".join(rows)
