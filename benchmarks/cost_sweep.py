"""Fig. 8/9-style utilisation & energy sweep through the cost-aware nOS.

Replays a mixed train + serve job trace through :class:`repro.core.nos.NOS`
with cost-engine admission: every job arrives as a bare ``ModelConfig`` +
shape and the scheduler sizes its slice by pricing candidate placements
with ``repro.core.costs.estimate``.  An event-driven clock advances from
arrival to completion; the output is the paper's Fig. 8/9 table at pod
scale — per-job slice, predicted step time, power, energy, plus fleet
utilisation and the energy-proportionality gap.

Run:  PYTHONPATH=src python benchmarks/cost_sweep.py [--mode packet]
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass
from typing import List, Optional

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.configs.base import SHAPES, ShapeConfig        # noqa: E402
from repro.core import nos as nos_mod                     # noqa: E402


@dataclass(frozen=True)
class TraceEntry:
    at: float            # arrival time, seconds
    name: str
    arch: str
    shape: ShapeConfig
    steps: int
    max_rows: int = 0    # tenant quota (rows); 0 = unlimited


def default_trace() -> List[TraceEntry]:
    """A ≥4-job mixed train+serve trace over the standard shapes."""
    return [
        TraceEntry(0.0, "train/qwen3-14b", "qwen3-14b",
                   SHAPES["train_4k"], steps=20, max_rows=8),
        TraceEntry(0.0, "serve/gemma2-27b", "gemma2-27b",
                   SHAPES["decode_32k"], steps=4000, max_rows=4),
        TraceEntry(5.0, "serve/qwen3-1.7b", "qwen3-1.7b",
                   SHAPES["decode_32k"], steps=20000, max_rows=4),
        TraceEntry(10.0, "train/rwkv6-1.6b", "rwkv6-1.6b",
                   SHAPES["train_4k"], steps=50, max_rows=8),
        TraceEntry(20.0, "serve/qwen3-14b", "qwen3-14b",
                   ShapeConfig("prefill_8k", 8192, 32, "prefill"),
                   steps=500, max_rows=4),
    ]


def simulate(trace: Optional[List[TraceEntry]] = None, data_rows: int = 16,
             model_cols: int = 16, mode: str = "circuit"):
    """Event-driven replay; returns (scheduler, per-job rows, totals)."""
    trace = trace if trace is not None else default_trace()
    s = nos_mod.NOS(data_rows=data_rows, model_cols=model_cols)
    arrivals = sorted(trace, key=lambda e: e.at)
    end_at = {}          # running job name -> completion time
    placed_at = {}
    clock = 0.0
    util_x_time = 0.0
    energy_fleet_j = 0.0

    def note_new_running():
        for j in s.jobs.values():
            if j.state == "running" and j.name not in end_at:
                placed_at[j.name] = clock
                end_at[j.name] = clock + j.steps * j.estimate.step_time_s

    while arrivals or end_at:
        candidates = []
        if arrivals:
            candidates.append(arrivals[0].at)
        if end_at:
            candidates.append(min(end_at.values()))
        t_next = max(min(candidates), clock)
        dt = t_next - clock
        util_x_time += s.utilisation() * dt
        energy_fleet_j += s.power_estimate_w() * dt
        clock = t_next
        while arrivals and arrivals[0].at <= clock:
            e = arrivals.pop(0)
            s.submit(get_config(e.arch), name=e.name, shape=e.shape,
                     steps=e.steps, mode=mode, max_rows=e.max_rows)
        for name in [n for n, t in end_at.items() if t <= clock]:
            del end_at[name]
            s.finish(name)
        note_new_running()

    makespan = clock
    rows = []
    for j in s.jobs.values():
        est = j.estimate
        rows.append(dict(
            name=j.name, kind=j.shape.kind, rows=j.rows_needed,
            chips=j.rows_needed * model_cols,
            step_ms=est.step_time_s * 1e3, w_per_chip=est.energy.w_per_chip,
            start_s=placed_at.get(j.name, 0.0),
            end_s=placed_at.get(j.name, 0.0)
            + j.steps * est.step_time_s,
            energy_kj=j.energy_j / 1e3, mode=est.mode))
    totals = dict(
        makespan_s=makespan,
        utilisation=util_x_time / max(makespan, 1e-12),
        avg_power_w=energy_fleet_j / max(makespan, 1e-12),
        fleet_energy_mj=energy_fleet_j / 1e6,
        job_energy_mj=sum(j.energy_j for j in s.jobs.values()) / 1e6,
        idle_floor_w=data_rows * model_cols * 60.0)
    return s, rows, totals


def format_table(rows, totals, mode: str) -> str:
    out = [f"# nOS cost sweep — {len(rows)} jobs, link model: {mode}",
           f"{'job':<18} {'kind':<8} {'rows':>4} {'chips':>5} "
           f"{'step_ms':>9} {'W/chip':>7} {'start_s':>8} {'end_s':>9} "
           f"{'energy_kJ':>10}"]
    for r in sorted(rows, key=lambda r: r["start_s"]):
        out.append(f"{r['name']:<18} {r['kind']:<8} {r['rows']:>4} "
                   f"{r['chips']:>5} {r['step_ms']:>9.2f} "
                   f"{r['w_per_chip']:>7.0f} {r['start_s']:>8.1f} "
                   f"{r['end_s']:>9.1f} {r['energy_kj']:>10.1f}")
    t = totals
    out.append(f"makespan {t['makespan_s']:.1f}s  "
               f"utilisation {t['utilisation'] * 100:.1f}%  "
               f"avg fleet power {t['avg_power_w'] / 1e3:.1f} kW  "
               f"fleet energy {t['fleet_energy_mj']:.2f} MJ "
               f"(jobs {t['job_energy_mj']:.2f} MJ, idle floor "
               f"{t['idle_floor_w'] / 1e3:.1f} kW)")
    return "\n".join(out)


def sweep_rows():
    """(name, us_per_call, derived) rows for benchmarks/run.py."""
    for mode in ("circuit", "packet"):
        _, rows, totals = simulate(mode=mode)
        for r in rows:
            yield (f"nos_{mode}_{r['name'].replace('/', '_')}",
                   r["step_ms"] * 1e3,
                   f"rows={r['rows']} energy={r['energy_kj']:.0f}kJ")
        yield (f"nos_{mode}_fleet", totals["makespan_s"] * 1e6,
               f"util={totals['utilisation'] * 100:.0f}% "
               f"energy={totals['fleet_energy_mj']:.2f}MJ")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="circuit",
                    choices=["circuit", "packet"])
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    args = ap.parse_args()
    _, rows, totals = simulate(data_rows=args.rows, model_cols=args.cols,
                               mode=args.mode)
    print(format_table(rows, totals, args.mode))


if __name__ == "__main__":
    main()
