"""Pallas TPU decode attention (single query vs KV cache), GQA-aware.

Grid (B, Kv, nT): for each kv head, its G query heads attend to the cache
in (block_t, hd) tiles with an online softmax carried in VMEM scratch.
The cache stays in its native (B, T, Kv, hd) layout — no H-expansion copy
in HBM (decode is memory-bound; the cache read is the roofline term).
Slots beyond ``pos`` are masked (ring/global semantics handled by the
caller's mask offset).

``paged_decode_attention`` is the paged-KV variant behind the serving
engine (Swallow §X-B: the KV cache as a striped distributed store): the
cache lives in fixed-size pages (P, ps, Kv, hd) and each sequence names
its pages through a block-index table.  The table is a scalar-prefetch
operand, so the BlockSpec index map DMAs exactly the pages the sequence
owns — the kernel never assumes a contiguous cache, and per-sequence
lengths replace the single shared ``pos``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -2.0 ** 30


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, scale, softcap, block_t, nt):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    t_start = ti * block_t

    @pl.when(t_start <= pos)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (bt, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        slots = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slots <= pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ti == nt - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...], 1e-37)[:, None]
                            ).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, *, scale=None, softcap=None,
                     block_t=512, interpret=True):
    """q (B,H,hd); k,v (B,T,Kv,hd); pos () int32. Returns (B,H,hd)."""
    B, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = hd ** -0.5 if scale is None else scale
    bt = min(block_t, T)
    while T % bt:
        bt -= 1
    nt = T // bt
    qg = q.reshape(B, Kv, G, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_dec_kernel, scale=scale, softcap=softcap,
                               block_t=bt, nt=nt)
    out = pl.pallas_call(
        kernel,
        grid=(B, Kv, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, t: (b, kv, 0, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, kv, t: (b, t, kv, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, kv, t: (b, t, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kv, t: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, qg, k, v)
    return out.reshape(B, H, hd)


def _paged_dec_kernel(*args, scale, softcap, page_size, pages_per_blk,
                      n_blocks, masked, partials):
    """Grid (B, Kv, n_blocks); each block sweeps ``pages_per_blk`` pages
    (block_t = pages_per_blk * page_size cache slots) with one online
    softmax carried in VMEM scratch.  Scalar-prefetch operands are the
    block table, per-sequence pos, and (when ``masked``) a page
    ownership mask; the remaining refs unpack as the q ref,
    pages_per_blk k page refs, pages_per_blk v page refs, the
    output(s), then scratch.  ``partials`` emits the raw online-softmax
    state (acc, m, l) instead of the normalized output — the sharded
    caller merges per-stripe partials with psums."""
    m_ = pages_per_blk
    if masked:
        bt_ref, pos_ref, pm_ref, q_ref, *refs = args
    else:
        bt_ref, pos_ref, q_ref, *refs = args
        pm_ref = None
    k_refs, v_refs = refs[:m_], refs[m_:2 * m_]
    if partials:
        o_acc_ref, o_m_ref, o_l_ref, m_ref, l_ref, acc_ref = refs[2 * m_:]
    else:
        o_ref, m_ref, l_ref, acc_ref = refs[2 * m_:]
    b = pl.program_id(0)
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]
    for i in range(m_):
        t_start = (blk * m_ + i) * page_size
        live = t_start <= pos
        if masked:
            # an unowned page's slot in the safe table points at local
            # row 0 — skip it entirely, the merge recovers exactness
            live = live & (pm_ref[b, blk * m_ + i] != 0)

        @pl.when(live)
        def _compute(i=i, t_start=t_start):
            q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
            k = k_refs[i][0, :, 0].astype(jnp.float32)     # (ps, hd)
            v = v_refs[i][0, :, 0].astype(jnp.float32)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) \
                * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            slots = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(slots <= pos, s, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
            acc_ref[...] = acc_ref[...] * corr[:, None] \
                + jax.lax.dot_general(
                    p, v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            m_ref[...] = m_new

    @pl.when(blk == n_blocks - 1)
    def _finish():
        if partials:
            o_acc_ref[0, 0, ...] = acc_ref[...]
            o_m_ref[0, 0, ...] = m_ref[...]
            o_l_ref[0, 0, ...] = l_ref[...]
        else:
            o_ref[0, 0, ...] = (acc_ref[...]
                                / jnp.maximum(l_ref[...], 1e-37)[:, None]
                                ).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos, *,
                           scale=None, softcap=None, block_t=None,
                           page_mask=None, partials=False,
                           interpret=True):
    """q (B,H,hd); k_pages/v_pages (P,ps,Kv,hd); block_tables (B,nmax)
    int32 physical page ids; pos (B,) int32 per-sequence last valid slot.

    Logical slot t of sequence b lives at page ``block_tables[b, t//ps]``,
    offset ``t % ps``.  Pages past ``pos[b]`` must still name a real page
    (the serving engine points them at the reserved null page 0); their
    contribution is masked out exactly.

    ``block_t`` is the time-tile sweep hook: a multiple of ``page_size``
    makes each grid step DMA ``block_t // page_size`` pages (each through
    its own scalar-prefetched index map) and sweep them in one kernel
    invocation — fewer grid steps against the same scattered pool.  The
    block table is padded with null pages when nmax doesn't divide.
    ``None`` keeps the one-page-per-step schedule.

    ``page_mask`` (B,nmax) int32 marks which table entries this caller
    owns (striped pools: a shard passes its local safe table plus the
    ownership mask; unowned entries are skipped, not attended).
    ``partials=True`` returns the raw online-softmax state
    ``(acc (B,Kv,G,hd) f32, m (B,Kv,G) f32, l (B,Kv,G) f32)`` instead of
    the normalized (B,H,hd) output, for cross-stripe psum merging.
    """
    B, H, hd = q.shape
    ps, Kv = k_pages.shape[1], k_pages.shape[2]
    nmax = block_tables.shape[1]
    G = H // Kv
    scale = hd ** -0.5 if scale is None else scale
    m_ = 1 if block_t is None else max(1, block_t // ps)
    masked = page_mask is not None
    qg = q.reshape(B, Kv, G, hd)
    bt = jnp.asarray(block_tables, jnp.int32)
    pm = None if page_mask is None \
        else jnp.asarray(page_mask, jnp.int32)
    if nmax % m_:
        pad = m_ - nmax % m_
        # pad with the reserved null page (id 0); t_start > pos masks it
        bt = jnp.pad(bt, ((0, 0), (0, pad)), constant_values=0)
        if masked:
            pm = jnp.pad(pm, ((0, 0), (0, pad)), constant_values=0)
        nmax += pad
    n_blocks = nmax // m_
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(_paged_dec_kernel, scale=scale,
                               softcap=softcap, page_size=ps,
                               pages_per_blk=m_, n_blocks=n_blocks,
                               masked=masked, partials=partials)

    def page_spec(i):
        # the block-index table drives the page DMA: page i of block p
        # of sequence b is physical page bt[b, p*m_+i] (pref[0] is the
        # table whatever the scalar-prefetch arity)
        return pl.BlockSpec(
            (1, ps, 1, hd),
            lambda b, kv, p, *pref, i=i: (pref[0][b, p * m_ + i], 0, kv, 0))

    def head_spec(shape):
        return pl.BlockSpec(shape, lambda b, kv, p, *pref: (b, kv) +
                            (0,) * (len(shape) - 2))

    if partials:
        out_specs = [head_spec((1, 1, G, hd)), head_spec((1, 1, G)),
                     head_spec((1, 1, G))]
        out_shape = [jax.ShapeDtypeStruct((B, Kv, G, hd), jnp.float32),
                     jax.ShapeDtypeStruct((B, Kv, G), jnp.float32),
                     jax.ShapeDtypeStruct((B, Kv, G), jnp.float32)]
    else:
        out_specs = head_spec((1, 1, G, hd))
        out_shape = jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 if masked else 2,
        grid=(B, Kv, n_blocks),
        in_specs=(
            [head_spec((1, 1, G, hd))]
            + [page_spec(i) for i in range(m_)]      # k pages
            + [page_spec(i) for i in range(m_)]),    # v pages
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    scalars = (bt, pos_arr, pm) if masked else (bt, pos_arr)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*scalars, qg, *([k_pages] * m_), *([v_pages] * m_))
    if partials:
        acc, m, l = out
        return acc, m, l
    return out.reshape(B, H, hd)
