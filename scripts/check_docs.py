#!/usr/bin/env python
"""Docs CI gate: every ```bash block under docs/*.md must run (or be
fenced as ```bash no-run), and every repo-relative link / module path in
README.md and docs/*.md must resolve.

Run from the repo root:  python scripts/check_docs.py [--list]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

FENCE_RE = re.compile(r"^```(\S*)[ \t]*(.*)$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
BACKTICK_RE = re.compile(r"`([^`]+)`")


def fenced_blocks(text: str):
    """Yield (info, extra, body, lineno) for every fenced code block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1):
            info, extra = m.group(1), m.group(2).strip()
            body, start = [], i + 1
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield info, extra, "\n".join(body), start
        i += 1


def run_bash_blocks(path: str) -> list:
    """Run each ```bash block; returns a list of failure strings."""
    failures = []
    with open(path) as f:
        text = f.read()
    for info, extra, body, lineno in fenced_blocks(text):
        if info != "bash":
            continue
        if "no-run" in extra:
            print(f"  [skip] {path}:{lineno} (no-run)")
            continue
        print(f"  [run ] {path}:{lineno}")
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        try:
            proc = subprocess.run(["bash", "-euo", "pipefail", "-c", body],
                                  cwd=ROOT, env=env, capture_output=True,
                                  text=True, timeout=900)
        except subprocess.TimeoutExpired:
            failures.append(f"{path}:{lineno} timed out after 900s\n"
                            f"--- block ---\n{body}")
            continue
        if proc.returncode != 0:
            failures.append(
                f"{path}:{lineno} exited {proc.returncode}\n"
                f"--- block ---\n{body}\n--- stderr ---\n"
                f"{proc.stderr[-2000:]}")
    return failures


def check_paths(path: str) -> list:
    """Relative markdown links and backticked src/... paths must exist."""
    failures = []
    with open(path) as f:
        text = f.read()
    base = os.path.dirname(path)
    # strip fenced code so shell snippets aren't parsed as links
    prose = []
    in_fence = False
    for line in text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            prose.append(line)
    prose = "\n".join(prose)
    for target in LINK_RE.findall(prose):
        if "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(os.path.join(base, target)):
            failures.append(f"{path}: broken link -> {target}")
    for span in BACKTICK_RE.findall(prose):
        span = span.strip()
        if not span.startswith(("src/", "docs/", "benchmarks/", "scripts/",
                                "tests/", "examples/")):
            continue
        if any(c in span for c in " ,()*"):
            continue
        if not os.path.exists(os.path.join(ROOT, span)):
            failures.append(f"{path}: module path does not exist -> {span}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="list blocks without running them")
    args = ap.parse_args()

    doc_files = sorted(
        os.path.join(ROOT, "docs", f)
        for f in os.listdir(os.path.join(ROOT, "docs")) if f.endswith(".md"))
    failures = []
    for path in [os.path.join(ROOT, "README.md")] + doc_files:
        rel = os.path.relpath(path, ROOT)
        print(f"[docs] {rel}")
        failures += check_paths(path)
        if rel != "README.md":          # README blocks are the quickstart;
            if args.list:               # docs/*.md blocks are the contract
                with open(path) as f:
                    for info, extra, _, ln in fenced_blocks(f.read()):
                        if info == "bash":
                            print(f"  {rel}:{ln} bash {extra}")
            else:
                failures += run_bash_blocks(path)
    if failures:
        print(f"\n{len(failures)} docs check(s) FAILED:", file=sys.stderr)
        for f in failures:
            print(" -", f, file=sys.stderr)
        sys.exit(1)
    print("\nall docs checks passed")


if __name__ == "__main__":
    main()
