"""Validate the analytic cost model against XLA's own counting.

cost_analysis() counts scan bodies once, so the comparison uses a config
whose layers are UNROLLED (single-cycle segments) and remat disabled —
there the two countings must agree on FLOPs within tolerance."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.analysis import flops as flops_mod, hlo as hlo_mod
from repro.configs import get_tiny_config
from repro.configs.base import ShapeConfig
from repro.models import lm


def _xla_flops(cfg, B, S):
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B=B, S=S)

    def step(p, b):
        loss, _ = lm.loss_fn(p, cfg, b)
        return loss

    grad_fn = jax.jit(jax.value_and_grad(step))
    c = grad_fn.lower(params, batch).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per computation
        ca = ca[0]
    return ca["flops"]


@pytest.mark.parametrize("arch", ["qwen3-14b", "rwkv6-1.6b"])
def test_analytic_flops_vs_xla(arch):
    cfg = get_tiny_config(arch).replace(n_layers=1, remat=False,
                                        mtp_depth=0)
    B, S = 2, 64
    xla = _xla_flops(cfg, B, S)
    shape = ShapeConfig("t", S, B, "train")
    cost = flops_mod.step_costs(cfg, shape, n_chips=1, tp=1)
    # remat disabled: analytic total = 3x fwd
    analytic = cost.flops_fwd * 3.0
    ratio = analytic / xla
    assert 0.6 < ratio < 1.7, (arch, analytic, xla, ratio)


def test_model_flops_definition():
    from repro.configs import get_config
    cfg = get_config("qwen3-14b")
    shape = ShapeConfig("train_4k", 4096, 256, "train")
    cost = flops_mod.step_costs(cfg, shape, n_chips=256)
    want = 6.0 * cfg.n_active_params() * 4096 * 256
    assert abs(cost.model_flops - want) / want < 1e-6
    # HLO-equivalent >= model flops (waste is non-negative)
    assert cost.flops_total > cost.model_flops


def test_decode_costs_scale_with_cache():
    from repro.configs import get_config
    cfg = get_config("qwen3-14b")
    c1 = flops_mod.step_costs(cfg, ShapeConfig("d", 8192, 128, "decode"),
                              n_chips=256)
    c2 = flops_mod.step_costs(cfg, ShapeConfig("d", 32768, 128, "decode"),
                              n_chips=256)
    # decode FLOPs and HBM both grow with the cache length (weights-read
    # stays constant, the cache term ~4x between 8k and 32k)
    assert c2.flops_total > 1.5 * c1.flops_total
    assert c2.hbm_bytes_per_chip > 1.3 * c1.hbm_bytes_per_chip


def test_local_attention_subquadratic():
    from repro.configs import get_config
    cfg = get_config("recurrentgemma-2b")
    s1 = flops_mod.attention_core_flops(cfg, "local", 32768, 1, "prefill", 16)
    s2 = flops_mod.attention_core_flops(cfg, "local", 65536, 1, "prefill", 16)
    assert s2 / s1 < 2.5      # ~linear, not ~4x
    g1 = flops_mod.attention_core_flops(cfg, "attn", 32768, 1, "prefill", 16)
    g2 = flops_mod.attention_core_flops(cfg, "attn", 65536, 1, "prefill", 16)
    assert g2 / g1 > 3.5      # quadratic


def test_hlo_parser_on_real_program():
    """Trip-count-aware collective accounting on a scanned program."""
    # single-device program has no collectives; just exercise the parser
    cfg = get_tiny_config("qwen3-14b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    c = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b)).lower(
        params, batch).compile()
    summ = hlo_mod.collective_summary(c.as_text())
    assert summ["total_wire_bytes_per_device"] == 0.0


def test_shape_bytes():
    assert hlo_mod.shape_bytes("f32[16,4096,2048]{2,1,0}") \
        == 16 * 4096 * 2048 * 4
    assert hlo_mod.shape_bytes("(bf16[8,4]{1,0}, s32[3]{0})") \
        == 8 * 4 * 2 + 3 * 4
    assert hlo_mod.shape_bytes("pred[7]{0}") == 7
