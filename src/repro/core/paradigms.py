"""Swallow §III / Fig. 2: the target computational paradigms.

  farmer-worker (scatter-gather)  — a coordinator splits work over
      identical workers and reduces the results.  At pod scale this *is*
      data parallelism: ``farmer_worker`` shards a batch over an axis,
      maps, and psum-reduces.
  pipelined / streaming — stages own disjoint program parts and stream
      activations (parallel/pipeline.py implements 1F1B over "pod").
  multiple independent applications — disjoint mesh slices, one job per
      slice (core/nos.py schedules them).

These wrappers exist so examples/benchmarks can exercise the paradigm
shapes directly, with explicit shard_map communication.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.parallel.sharding import current_env

from repro.parallel.sharding import compat_shard_map as _shard_map


def farmer_worker(work_fn: Callable, data, *, reduce: str = "sum",
                  axis: str = "data"):
    """Scatter ``data`` over ``axis``, apply ``work_fn`` per shard, gather
    or reduce the results (Fig. 2a).  Off-mesh it degrades to work_fn."""
    env = current_env()
    if env is None or axis not in env.mesh.axis_names:
        out = work_fn(data)
        return out

    from jax.sharding import PartitionSpec as P

    def body(shard):
        out = work_fn(shard)
        if reduce == "sum":
            out = jax.lax.psum(out, axis)
        elif reduce == "mean":
            out = jax.lax.pmean(out, axis)
        return out

    n = env.mesh.shape[axis]
    assert data.shape[0] % n == 0, (data.shape, n)
    in_spec = P(axis)
    out_spec = P() if reduce in ("sum", "mean") else P(axis)
    return _shard_map(body, mesh=env.mesh, in_specs=(in_spec,),
                      out_specs=out_spec, check_vma=False)(data)


def streaming_pipeline(stage_fns: Sequence[Callable], x,
                       *, microbatches: int = 1):
    """Fig. 2b: composed stages with bounded per-stage storage.  On one
    host this runs the stages over microbatch slices — the scheduling
    skeleton the pipeline-parallel runtime uses (parallel/pipeline.py
    distributes the same structure over the "pod"/"stage" axis)."""
    if microbatches == 1:
        for f in stage_fns:
            x = f(x)
        return x
    assert x.shape[0] % microbatches == 0
    parts = jnp.split(x, microbatches, axis=0)
    outs = []
    for mb in parts:
        y = mb
        for f in stage_fns:
            y = f(y)
        outs.append(y)
    return jnp.concatenate(outs, axis=0)
