"""Bursty multi-tenant Poisson trace through the paged serving engine.

The serving-side companion of cost_sweep.py: where that benchmark replays
whole *jobs* through the cost-aware nOS, this one replays individual
*requests* through :mod:`repro.serving` — the paged-KV continuous-batching
engine — and emits a throughput / TTFT / page-occupancy table per tenant,
plus the nOS fleet serving view (pages, energy, queue latency).

Arrivals are Poisson per tenant in units of engine steps (the engine
step is the farmer's clock), with a burst tenant that dumps its whole
load at once — the mixed pattern that makes continuous batching and
page-pressure preemption visible.

``--trace shared-prefix`` switches to the multi-tenant shared-prefix
trace (N tenants x M requests sharing per-tenant system prompts) and
``--prefix-cache on`` serves it through the copy-on-write prefix cache
(docs/PREFIX_CACHE.md); ``bench_prefix_comparison`` replays it twice —
cache on vs off — into BENCH_prefix.json (token identity, hit rate,
prefill-token reduction).

``--trace repetitive`` is the speculative-decoding exemplar: a single
latency-bound stream (batch 1) of motif-tiled prompts whose greedy
continuations loop, so device-resident n-gram drafting
(``--spec-decode on``, with ``--spec-k auto`` adaptive depth or a
fixed integer) verifies many tokens per model pass;
``bench_spec_comparison`` replays it twice — speculation on vs off —
into BENCH_spec.json (token identity, dispatches per token, accept
rate, and the wall-clock split wall_s = scan_s + draft_verify_s +
host_s with the spec_speedup verdict).

``--trace overload`` is the load harness (docs/LOAD_TESTING.md): an
interactive tenant under diurnal-modulated Poisson arrivals, a batch
tenant with heavy-tailed Pareto prompt lengths, and a surge tenant that
dumps a pile at once (overload-and-recover).  Per-request TTFT/TPOT is
recorded on both the deterministic step clock and the wall clock, and
``bench_slo_comparison`` replays it twice — ``--chunk-prefill on`` vs
monolithic — into BENCH_slo.json: p50/p95/p99 TTFT per SLO class,
goodput (tokens from deadline-met requests), token identity, and the
gated ``p99_ttft_ratio`` / ``goodput_ratio`` verdicts
(scripts/check_bench.py::check_slo).

``--trace-out`` arms the step-clock flight recorder
(docs/OBSERVABILITY.md) on any replay and exports Chrome trace-event
JSON; ``bench_obs_comparison`` replays the overload trace with tracing
off vs on into BENCH_obs.json (token bit-identity, the gated
``overhead_ratio`` <= 1.05x, the per-phase predicted-vs-measured
model-error rollup, and an embedded schema-validated trace excerpt),
gated by ``scripts/check_bench.py::check_obs``.

``--trace chaos`` is the fault-injection harness
(docs/FAULT_TOLERANCE.md): interactive + batch tenants whose requests
stripe across every node of the paged pool, driven under a seeded
:class:`repro.serving.FaultPlan` (``--fault-plan chaos``) of node
failures, transient admission errors and straggler slowdowns;
``bench_chaos_comparison`` replays it twice — fault-free vs chaos —
into BENCH_chaos.json (survivor token bit-identity, goodput retained,
recovery-step percentiles, zero stale reads), gated by
``scripts/check_bench.py::check_chaos``.

Run:  PYTHONPATH=src python benchmarks/serve_trace.py [--quick]
      PYTHONPATH=src python benchmarks/serve_trace.py --quick \
          --trace shared-prefix --prefix-cache on
      PYTHONPATH=src python benchmarks/serve_trace.py --quick \
          --trace repetitive --batch 1 --spec-decode on
      PYTHONPATH=src python benchmarks/serve_trace.py --quick \
          --trace overload --chunk-prefill on
      PYTHONPATH=src python benchmarks/serve_trace.py --quick \
          --trace chaos --nodes 4 --fault-plan chaos
"""
from __future__ import annotations

import argparse
import math
import sys
from dataclasses import dataclass, replace as dc_replace
from typing import List, Optional, Union

import numpy as np

sys.path.insert(0, "src")

from repro.serving.telemetry import (HistogramDigest,  # noqa: E402
                                     rollup_dispatch_events)

# Pareto-drawn prompt lengths are quantized to this grid so the
# monolithic prefill path (which retraces per prompt length) compiles a
# bounded kernel set; the heavy tail survives quantization
LEN_QUANTUM = 8


@dataclass(frozen=True)
class Tenant:
    name: str
    n_requests: int
    rate: float          # mean arrivals per engine step (Poisson); 0 = burst
    prompt_len: int
    gen: int
    at_step: int = 0     # burst tenants: every request arrives here
    shared_prefix: int = 0   # leading tokens all of the tenant's requests
                             # share (its "system prompt"); 0 = fully unique
    motif: int = 0           # > 0: prompts are a per-request motif of this
                             # many tokens tiled to prompt_len (repetitive
                             # text — speculative-decoding fodder)
    slo: str = "standard"    # repro.serving.slo class for every request
    pareto_alpha: float = 0.0   # > 0: prompt lengths are heavy-tailed —
                                # prompt_len * (1 + Pareto(alpha)), capped
                                # at max_prompt_len, LEN_QUANTUM-quantized
    max_prompt_len: int = 0     # Pareto cap (0: 4x prompt_len)
    rate_period: int = 0     # > 0: diurnal arrivals — the Poisson rate is
                             # modulated by a sine of this period (steps)
    rate_amp: float = 0.0    # diurnal modulation depth in [0, 1)


def default_tenants(quick: bool = False) -> List[Tenant]:
    if quick:
        return [Tenant("chat", 6, 0.5, 12, 6),
                Tenant("burst", 4, 0.0, 8, 4, at_step=5)]
    return [
        Tenant("chat", 12, 0.4, 16, 8),          # steady interactive load
        Tenant("batch", 8, 0.15, 32, 16),        # long-prompt background
        Tenant("burst", 8, 0.0, 12, 6, at_step=10),  # arrives all at once
    ]


def shared_prefix_tenants(quick: bool = False) -> List[Tenant]:
    """The multi-tenant shared-prefix trace (BENCH_prefix.json): N
    tenants x M requests, each tenant's requests sharing a per-tenant
    "system prompt".  The prefix length is deliberately NOT page aligned
    (22 tokens over 8-token pages) so every hit diverges inside a page
    and exercises the copy-on-write path, not just whole-page sharing."""
    if quick:
        return [Tenant("tenantA", 4, 0.5, 30, 6, shared_prefix=22),
                Tenant("tenantB", 4, 0.5, 30, 6, shared_prefix=22),
                Tenant("tenantC", 4, 0.5, 30, 6, shared_prefix=22)]
    return [Tenant(f"tenant{c}", 8, 0.4, 46, 10, shared_prefix=38)
            for c in "ABCD"]


def repetitive_tenants(quick: bool = False) -> List[Tenant]:
    """The repetitive-text trace (BENCH_spec.json): one latency-bound
    stream (run it with max_batch=1 — the regime where batching cannot
    amortize dispatches and speculation is the only lever) of long-gen
    requests whose prompts tile a short motif.  Greedy decode on such
    prompts falls into loops, which is exactly the repetitive-output
    regime (templates, code, retrieval) n-gram drafting exploits."""
    if quick:
        return [Tenant("loop", 3, 0.0, 24, 40, at_step=0, motif=4)]
    return [Tenant("loop", 6, 0.0, 32, 64, at_step=0, motif=4)]


def overload_tenants(quick: bool = False) -> List[Tenant]:
    """The heavy-traffic trace (BENCH_slo.json, docs/LOAD_TESTING.md):

    * ``interactive`` — short prompts under a diurnal-modulated Poisson
      stream (the sine-modulated rate is the burst pattern a day of chat
      traffic shows), SLO class ``interactive`` (tight TTFT deadline);
    * ``batch`` — heavy-tailed Pareto prompt lengths (alpha ~1.1: most
      prompts near the floor, rare prompts many times longer — the
      long-prompt head-of-line hazard), class ``batch``;
    * ``surge`` — the overload-and-recover phase: a pile of standard
      requests lands at one step, far over slot capacity, and the queue
      must drain without starving anyone.

    Full mode is thousands of requests; ``--quick`` keeps the same shape
    at CI scale."""
    if quick:
        return [
            Tenant("interactive", 18, 0.6, 8, 6, slo="interactive",
                   rate_period=24, rate_amp=0.8),
            Tenant("batch", 6, 0.08, 24, 4, slo="batch",
                   pareto_alpha=1.1, max_prompt_len=96),
            Tenant("surge", 10, 0.0, 8, 4, at_step=30, slo="standard"),
        ]
    return [
        Tenant("interactive", 1200, 0.8, 12, 8, slo="interactive",
               rate_period=200, rate_amp=0.8),
        Tenant("batch", 500, 0.12, 32, 8, slo="batch",
               pareto_alpha=1.1, max_prompt_len=256),
        Tenant("surge", 300, 0.0, 12, 6, at_step=400, slo="standard"),
    ]


def chaos_tenants(quick: bool = False) -> List[Tenant]:
    """The fault-injection trace (BENCH_chaos.json,
    docs/FAULT_TOLERANCE.md): an interactive tenant under steady Poisson
    arrivals plus a long-prompt batch tenant, both sized so every
    request's pages span all stripes of a 4-node pool (prompt + gen
    >= 4 pages at 8-token pages) — a node failure therefore always
    lands on live requests, exercising quarantine + exact-recompute
    recovery rather than only free-list shrinkage."""
    if quick:
        return [
            Tenant("interactive", 8, 0.4, 16, 12, slo="interactive"),
            Tenant("batch", 4, 0.12, 32, 8, slo="batch"),
        ]
    return [
        Tenant("interactive", 48, 0.5, 24, 16, slo="interactive"),
        Tenant("batch", 16, 0.1, 48, 12, slo="batch"),
    ]


TRACES = {
    "mixed": default_tenants,
    "shared-prefix": shared_prefix_tenants,
    "repetitive": repetitive_tenants,
    "overload": overload_tenants,
    "chaos": chaos_tenants,
}


def resolve_tenants(tenants, quick: bool = False) -> List[Tenant]:
    """Fail-fast trace validation: ``tenants`` may be a trace name, a
    list of :class:`Tenant`, or None (the default trace).  Anything else
    — or tenant fields that would only blow up deep inside ``prompt_for``
    / the engine — exits 2 listing the valid traces, so programmatic
    callers get the same contract as ``--trace`` argparse choices."""
    from repro.serving.slo import SLO_CLASSES
    valid = ", ".join(sorted(TRACES))

    def bail(msg: str):
        print(f"serve_trace: {msg}; valid traces: {valid}",
              file=sys.stderr)
        raise SystemExit(2)

    if tenants is None:
        return default_tenants(quick)
    if isinstance(tenants, str):
        if tenants not in TRACES:
            bail(f"unknown trace {tenants!r}")
        return TRACES[tenants](quick)
    try:
        tenants = list(tenants)
    except TypeError:
        bail(f"tenants must be a trace name or a list of Tenant, "
             f"got {type(tenants).__name__}")
    if not tenants:
        bail("empty tenants list")
    for t in tenants:
        if not isinstance(t, Tenant):
            bail(f"tenants list holds a {type(t).__name__}, not a Tenant")
        if t.n_requests <= 0 or t.prompt_len <= 0 or t.gen <= 0:
            bail(f"tenant {t.name!r} has non-positive "
                 f"n_requests/prompt_len/gen")
        if t.shared_prefix > t.prompt_len:
            bail(f"tenant {t.name!r} shared_prefix {t.shared_prefix} "
                 f"exceeds prompt_len {t.prompt_len}")
        if t.slo not in SLO_CLASSES:
            bail(f"tenant {t.name!r} has unknown SLO class {t.slo!r} "
                 f"(valid: {', '.join(sorted(SLO_CLASSES))})")
    return tenants


def prompt_for(cfg, t: Tenant, rid: int, plen: Optional[int] = None):
    """Request ``rid``'s prompt: the tenant's system prompt (stable
    per-tenant seed) + a unique per-request tail — or, for ``motif``
    tenants, a per-request motif tiled to the length.  ``plen``
    overrides the tenant's nominal length (Pareto draws)."""
    import jax
    import zlib
    plen = t.prompt_len if plen is None else plen
    if t.motif > 0:
        pat = np.asarray(jax.random.randint(jax.random.PRNGKey(rid),
                                            (t.motif,), 2, cfg.vocab_size),
                         np.int32)
        return np.tile(pat, -(-plen // t.motif))[:plen]
    tail_len = plen - t.shared_prefix
    parts = []
    if t.shared_prefix > 0:
        seed = zlib.crc32(t.name.encode()) % (2 ** 31)
        parts.append(jax.random.randint(jax.random.PRNGKey(seed),
                                        (t.shared_prefix,), 2,
                                        cfg.vocab_size))
    if tail_len > 0:
        parts.append(jax.random.randint(jax.random.PRNGKey(rid),
                                        (tail_len,), 2, cfg.vocab_size))
    return np.concatenate([np.asarray(p, np.int32) for p in parts])


def _draw_len(t: Tenant, rng: np.random.Generator) -> int:
    """Prompt length for one request: the nominal length, or a
    heavy-tailed Pareto draw quantized to LEN_QUANTUM (bounded compile
    set) and capped (bounded pool demand)."""
    if t.pareto_alpha <= 0.0:
        return t.prompt_len
    cap = t.max_prompt_len or 4 * t.prompt_len
    raw = t.prompt_len * (1.0 + rng.pareto(t.pareto_alpha))
    q = (int(raw) // LEN_QUANTUM) * LEN_QUANTUM
    return min(cap, max(t.prompt_len, q))


def arrivals_for(t: Tenant, rng: np.random.Generator):
    """(step, prompt_len) arrival list — Poisson gaps (optionally
    diurnal-modulated), or one burst."""
    if t.rate <= 0.0:
        return [(t.at_step, _draw_len(t, rng))
                for _ in range(t.n_requests)]
    out, now = [], 0.0
    for _ in range(t.n_requests):
        r = t.rate
        if t.rate_period > 0 and t.rate_amp > 0.0:
            # inhomogeneous Poisson via per-gap rate: the day/night sine
            r = t.rate * (1.0 + t.rate_amp
                          * math.sin(2.0 * math.pi * now / t.rate_period))
            r = max(r, 0.05 * t.rate)       # night floor, never zero
        now += rng.exponential(1.0 / r)
        out.append((t.at_step + int(now), _draw_len(t, rng)))
    return out


def replay(tenants: Union[str, List[Tenant], None] = None, *,
           quick: bool = False, seed: int = 0,
           max_batch: int = 4, page_size: int = 8, n_pages: int = 0,
           arch: str = "tiny-100m", link_mode: str = "circuit",
           prefill_budget: float = 2.0, fused: bool = True,
           max_window: int = 8, warmup: bool = False, params=None,
           prefix_cache: bool = False, spec_decode: bool = False,
           spec_k="auto", chunk_prefill: bool = False,
           chunk_tokens: int = 0, n_nodes: int = 1, fault_plan=None,
           trace: bool = False, trace_capacity: int = 4096):
    """Drive the engine window by window, injecting arrivals between
    dispatches.  With ``fused`` the engine decodes multi-token windows,
    capped to the next pending arrival so the trace's admission clock
    stays faithful; ``fused=False`` is the legacy per-step loop.

    ``tenants`` is a trace name from :data:`TRACES`, an explicit
    ``Tenant`` list, or None (the default trace); anything malformed
    exits 2 up front (see :func:`resolve_tenants`) instead of failing
    deep inside ``prompt_for``.

    ``fault_plan`` arms the deterministic fault plane
    (:class:`repro.serving.FaultPlan`) AFTER warmup and the metrics
    reset, so plan step 0 is the first measured step and warmup never
    consumes fault events; ``n_nodes`` stripes the page pool so a node
    failure quarantines a real fraction of it.

    ``trace`` arms the step-clock flight recorder (docs/OBSERVABILITY
    .md): request-lifecycle + dispatch spans with predicted-vs-measured
    attribution, exportable via ``eng.tracer.write_chrome``.  Tracing
    never changes scheduling (the engine's clock is the step index, not
    the wall), so traced and untraced replays emit identical tokens —
    ``bench_obs_comparison`` gates exactly that.

    Returns (engine, per-tenant rows, totals).
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm
    from repro.serving import PagedEngine

    tenants = resolve_tenants(tenants, quick)
    rng = np.random.default_rng(seed)
    # materialize the whole trace up front — (step, tenant, rid, plen)
    # — BEFORE sizing the engine: Pareto tenants only reveal their
    # worst-case length once drawn
    arrivals = sorted([(step, t, plen)
                       for t in tenants
                       for (step, plen) in arrivals_for(t, rng)],
                      key=lambda a: a[0])
    max_len = max(plen + t.gen for (_, t, plen) in arrivals)
    if not n_pages:
        # ~75% of worst-case demand: page pressure without thrash — but
        # never below one request's peak need (batch-1 traces would
        # otherwise be rejected at submit)
        worst = max_batch * (-(-max_len // page_size))
        n_pages = max(int(worst * 0.75), -(-max_len // page_size), 2) + 1

    cfg = get_tiny_config(arch)
    if params is None:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # materialize every arrival's prompt up front too: trace
    # construction is not serving work, and jax.random compiles per
    # prompt shape
    pending = [(step, t, i, prompt_for(cfg, t, i, plen))
               for i, (step, t, plen) in enumerate(arrivals)]
    eng = PagedEngine(cfg, params, max_batch=max_batch,
                      page_size=page_size, n_pages=n_pages,
                      max_len=max_len, link_mode=link_mode,
                      prefill_budget=prefill_budget, fused=fused,
                      max_window=max_window, prefix_cache=prefix_cache,
                      spec_decode=spec_decode, spec_k=spec_k,
                      chunked_prefill=chunk_prefill,
                      chunk_tokens=chunk_tokens, n_nodes=n_nodes,
                      trace=trace, trace_capacity=trace_capacity)
    if warmup:
        # compile every window bucket + a prefill per DISTINCT
        # materialized prompt length (prefill retraces per length;
        # chunked engines compile their pow2 chunk buckets the same way)
        # outside the timed region
        eng.warmup_windows()
        lens = sorted({p.shape[0] for (_, _, _, p) in pending})
        for i, plen in enumerate(lens):
            warm = jax.random.randint(jax.random.PRNGKey(10_000 + i),
                                      (plen,), 2, cfg.vocab_size)
            eng.submit(np.asarray(warm), min(2, max_len - plen),
                       rid=f"warmup{i}")
        eng.run()
        # compile the COW copy + suffix-prefill buckets the trace will
        # hit: one miss/hit pair per (prompt_len, shared_prefix)
        for plen, sp in sorted({(t.prompt_len, t.shared_prefix)
                                for t in tenants}):
            eng.warmup_prefix(plen, sp)
        eng.reset_metrics()
        if eng.cache is not None:
            eng.cache.clear()      # measured run starts with a cold tree
        eng.sched.step_idx = 0
    if fault_plan is not None:
        # arm AFTER warmup/reset: the plane's epoch pins plan step 0 to
        # the current scheduler step, so the fault schedule replays
        # identically whether or not compiles were warmed
        eng.install_faults(fault_plan)

    occupancy = []
    while pending or eng.sched.waiting or eng.sched.prefilling \
            or eng.sched.running:
        while pending and pending[0][0] <= eng.sched.step_idx:
            _, t, rid, prompt = pending.pop(0)
            eng.submit(prompt, t.gen, tenant=t.name,
                       rid=f"{t.name}/{rid}", slo=t.slo)
        before = eng.steps_run
        if eng.sched.waiting or eng.sched.prefilling or eng.sched.running:
            # never decode past the next arrival: windows respect the
            # trace's clock, not just the scheduler's safe horizon
            cap = pending[0][0] - eng.sched.step_idx if pending else None
            eng.step(max_window=cap)
        else:
            eng.sched.step_idx += 1   # idle gap before the next arrival
        # one sample per *scheduler* step (a fused window covers several)
        # so fused and per-step occupancy means weight phases identically
        occupancy += [eng.alloc.pages_in_use] * max(eng.steps_run - before,
                                                    1)

    rows = []
    for t in tenants:
        fin = [r for r in eng.sched.finished if r.tenant == t.name]
        ttft = HistogramDigest.of(r.first_token_step - r.arrived_step
                                  for r in fin)
        met = [r for r in fin if r.first_token_step <= r.deadline_step]
        rows.append(dict(
            tenant=t.name, slo=t.slo, requests=len(fin),
            tokens=sum(len(r.tokens) for r in fin),
            ttft_mean=ttft.mean,
            ttft_p95=ttft.percentile(95),
            ttft_p99=ttft.percentile(99),
            slo_met_frac=len(met) / max(len(fin), 1),
            preemptions=sum(r.preemptions for r in fin)))
    m = eng.metrics()
    totals = dict(
        steps=eng.steps_run, windows=m["windows"], tokens=m["tokens_out"],
        tokens_finished=m["tokens_finished"],
        tok_per_s=m["tok_per_s"], decode_tok_per_s=m["decode_tok_per_s"],
        wall_s=m["wall_s"], decode_s=m["decode_s"],
        h2d_syncs=m["h2d_syncs"], d2h_syncs=m["d2h_syncs"],
        syncs_per_token=m["syncs_per_token"],
        occupancy_mean=float(np.mean(occupancy)) / max(n_pages - 1, 1),
        occupancy_peak=m["peak_pages"] / max(n_pages - 1, 1),
        preemptions=m["preemptions"], n_pages=n_pages,
        page_size=page_size, prefill_tokens=m["prefill_tokens"],
        model_passes=m["model_passes"],
        dispatches_per_token=m["dispatches_per_token"])
    if eng.spec is not None:
        totals.update(
            accept_rate=m["accept_rate"], spec_drafted=m["spec_drafted"],
            spec_accepted=m["spec_accepted"],
            spec_verifies=m["spec_verifies"],
            spec_rollbacks=m["spec_rollbacks"],
            spec_k_mean=m["spec_k_mean"],
            spec_verify_s=m["spec_verify_s"])
    if eng.cache is not None:
        totals.update(
            hit_rate=m["prefix_hit_rate"],
            prefill_tokens_cached=m["prefill_tokens_cached"],
            cow_copies=m["cow_copies"], shared_pages=m["shared_pages"],
            prefix_evictions=m["prefix_evictions"],
            bytes_deduped=m["bytes_deduped"])
    if eng.sched.chunked:
        totals.update(
            chunk_dispatches=m["chunk_dispatches"],
            chunk_rounds=m["chunk_rounds"],
            chunk_tasks=m["chunk_tasks"],
            chunk_preemptions=m["chunk_preemptions"])
    if eng.faults is not None:
        totals.update(
            node_failures=m["node_failures"],
            node_joins=m["node_joins"],
            pages_quarantined=m["pages_quarantined"],
            requests_recovered=m["requests_recovered"],
            requests_shed=m["requests_shed"],
            tokens_recomputed=m["tokens_recomputed"],
            transient_rejections=m["transient_rejections"],
            quarantined_served=m["quarantined_served"],
            recovery_steps_p50=m["recovery_steps_p50"],
            recovery_steps_p99=m["recovery_steps_p99"])
    return eng, rows, totals


def slo_stats(eng) -> dict:
    """Per-SLO-class percentile digest of a finished replay.

    TTFT percentiles are reported on two clocks: the deterministic
    engine-step clock (``ttft_steps_*`` — what check_bench gates, stable
    across machines) and the wall clock (``ttft_wall_*_s`` —
    informational).  ``goodput_tokens`` counts only tokens from requests
    whose first token landed by their class deadline — the "useful work"
    number an overloaded fleet optimizes, as opposed to raw throughput
    that happily burns pages on requests nobody is waiting for any more.

    Percentiles come from the shared streaming
    :class:`repro.serving.telemetry.HistogramDigest` — in its exact
    regime (every trace this repo ships) bit-equal to the
    ``np.percentile`` calls it replaced, and bounded-memory beyond.
    """
    from repro.serving.slo import get_slo

    out = {}
    for r in eng.sched.finished:
        out.setdefault(r.slo, []).append(r)
    digest = {}
    for name, reqs in sorted(out.items()):
        slo = get_slo(name)
        ttft = HistogramDigest.of(r.first_token_step - r.arrived_step
                                  for r in reqs)
        wall = HistogramDigest.of((r.first_token_wall or 0.0)
                                  - (r.arrived_wall or 0.0) for r in reqs)
        tpot = HistogramDigest.of(((r.finished_wall or 0.0)
                                   - (r.first_token_wall or 0.0))
                                  / max(len(r.tokens) - 1, 1)
                                  for r in reqs)
        met = [r for r in reqs
               if r.first_token_step <= r.deadline_step]
        digest[name] = dict(
            requests=len(reqs),
            ttft_target_steps=slo.ttft_steps,
            ttft_steps_p50=ttft.percentile(50),
            ttft_steps_p95=ttft.percentile(95),
            ttft_steps_p99=ttft.percentile(99),
            ttft_wall_p50_s=wall.percentile(50),
            ttft_wall_p99_s=wall.percentile(99),
            tpot_wall_mean_s=tpot.mean,
            slo_met_frac=len(met) / max(len(reqs), 1),
            goodput_tokens=sum(len(r.tokens) for r in met),
            tokens=sum(len(r.tokens) for r in reqs))
    return digest


def bench_slo_comparison(*, quick: bool = True, seed: int = 0,
                         max_batch: int = 4, page_size: int = 8,
                         max_window: int = 8, chunk_tokens: int = 0,
                         arch: str = "tiny-100m"):
    """Replay the overload trace twice — chunked prefill (SLO-aware EDF
    admission, deadline-budgeted chunk rounds) vs the monolithic priced
    FIFO — with shared params and warmed-up compiles, asserting
    per-request token identity (chunking is a KV-composition transform,
    not a sampler change).

    Returns the BENCH_slo.json payload (see
    scripts/check_bench.py::check_slo).  The gated verdicts are
    deterministic: ``p99_ttft_ratio`` compares the interactive class's
    p99 TTFT on the engine-step clock (chunked must not be worse — the
    whole point of slicing long prefills is that short interactive
    requests stop waiting behind them), and ``goodput_ratio`` compares
    deadline-met tokens (chunking must not win latency by throwing away
    throughput).
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm

    tenants = overload_tenants(quick)
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out, toks = {}, {}
    for mode, chunked in (("chunked", True), ("monolithic", False)):
        eng, rows, totals = replay(tenants, seed=seed,
                                   max_batch=max_batch,
                                   page_size=page_size,
                                   max_window=max_window,
                                   chunk_prefill=chunked,
                                   chunk_tokens=chunk_tokens,
                                   warmup=True, params=params, arch=arch)
        toks[mode] = {r.rid: list(r.tokens) for r in eng.sched.finished}
        per_class = slo_stats(eng)
        out[mode] = dict(
            tokens=totals["tokens"], steps=totals["steps"],
            tok_per_s=totals["tok_per_s"],
            prefill_tokens=totals["prefill_tokens"],
            preemptions=totals["preemptions"],
            goodput_tokens=sum(c["goodput_tokens"]
                               for c in per_class.values()),
            slo=per_class)
        if chunked:
            out[mode].update(
                chunk_dispatches=totals["chunk_dispatches"],
                chunk_rounds=totals["chunk_rounds"],
                chunk_tasks=totals["chunk_tasks"],
                chunk_preemptions=totals["chunk_preemptions"])
    inter_c = out["chunked"]["slo"]["interactive"]
    inter_m = out["monolithic"]["slo"]["interactive"]
    return {
        "schema": "swallow.bench.slo/v1",
        "arch": arch, "batch": max_batch, "page_size": page_size,
        "max_window": max_window, "trace": "overload",
        "quick": quick, "seed": seed,
        "chunked": out["chunked"], "monolithic": out["monolithic"],
        "tokens_match": toks["chunked"] == toks["monolithic"],
        "p99_ttft_ratio": inter_c["ttft_steps_p99"]
        / max(inter_m["ttft_steps_p99"], 1e-9),
        "goodput_ratio": out["chunked"]["goodput_tokens"]
        / max(out["monolithic"]["goodput_tokens"], 1),
    }


def bench_chaos_comparison(*, quick: bool = True, seed: int = 0,
                           max_batch: int = 4, page_size: int = 8,
                           max_window: int = 8, n_nodes: int = 4,
                           arch: str = "tiny-100m"):
    """Replay the chaos trace twice — fault-free vs a seeded
    :class:`repro.serving.FaultPlan` with >= 2 node failures — with
    shared params and warmed-up compiles, asserting that every request
    the chaos run finishes (the survivors) emits tokens bit-identical
    to the fault-free run.  Greedy recompute through the preemption
    machinery is exact, so fault recovery is a *placement* event, not a
    sampler change — the same invariant every other serving transform
    in this file is held to.

    The fault schedule is sized from the fault-free run's own step
    count, so failures always land while requests are in flight, and
    the plan's heartbeat/straggler detection runs on the deterministic
    step clock — the whole chaos run replays bit-identically from
    (seed, trace).

    Returns the BENCH_chaos.json payload (see
    scripts/check_bench.py::check_chaos).  Gated verdicts:
    ``tokens_match`` (survivor bit-identity), ``goodput_retained``
    (deadline-met tokens, chaos/fault-free — recovery must degrade
    gracefully, not collapse), ``quarantined_served == 0`` (no dispatch
    ever read a dead stripe) and ``node_failures >= 2`` both planned
    and detected."""
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm
    from repro.serving import FaultPlan

    tenants = chaos_tenants(quick)
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    common = dict(seed=seed, max_batch=max_batch, page_size=page_size,
                  max_window=max_window, n_nodes=n_nodes, warmup=True,
                  params=params, arch=arch)

    base_eng, _, base_totals = replay(tenants, **common)
    base_toks = {r.rid: list(r.tokens) for r in base_eng.sched.finished}
    base_good = sum(c["goodput_tokens"]
                    for c in slo_stats(base_eng).values())

    # size the schedule from the fault-free run: failures land in the
    # first ~half of the trace, while the pool is under live load
    horizon = max(int(base_totals["steps"]) * 3 // 4, 16)
    plan = FaultPlan.seeded(seed, n_nodes=n_nodes, horizon=horizon,
                            n_fails=2, n_transients=2, n_slow=1)
    chaos_eng, _, chaos_totals = replay(tenants, fault_plan=plan,
                                        **common)
    chaos_toks = {r.rid: list(r.tokens)
                  for r in chaos_eng.sched.finished}
    chaos_good = sum(c["goodput_tokens"]
                     for c in slo_stats(chaos_eng).values())
    survivors_match = all(toks == base_toks[rid]
                          for rid, toks in chaos_toks.items())

    def block(totals, good, n_finished):
        out = dict(tokens=totals["tokens"], steps=totals["steps"],
                   tok_per_s=totals["tok_per_s"],
                   preemptions=totals["preemptions"],
                   requests_finished=n_finished, goodput_tokens=good)
        return out

    chaos_blk = block(chaos_totals, chaos_good, len(chaos_toks))
    chaos_blk.update(
        node_failures=chaos_totals["node_failures"],
        node_joins=chaos_totals["node_joins"],
        pages_quarantined=chaos_totals["pages_quarantined"],
        requests_recovered=chaos_totals["requests_recovered"],
        requests_shed=chaos_totals["requests_shed"],
        tokens_recomputed=chaos_totals["tokens_recomputed"],
        transient_rejections=chaos_totals["transient_rejections"],
        quarantined_served=chaos_totals["quarantined_served"],
        recovery_steps_p50=chaos_totals["recovery_steps_p50"],
        recovery_steps_p99=chaos_totals["recovery_steps_p99"])
    return {
        "schema": "swallow.bench.chaos/v1",
        "arch": arch, "batch": max_batch, "page_size": page_size,
        "max_window": max_window, "n_nodes": n_nodes,
        "trace": "chaos", "quick": quick, "seed": seed,
        "planned_failures": plan.n_node_failures,
        "planned_events": len(plan.events),
        "fault_free": block(base_totals, base_good, len(base_toks)),
        "chaos": chaos_blk,
        "tokens_match": bool(survivors_match),
        "survivors": len(chaos_toks),
        "goodput_retained": chaos_good / max(base_good, 1),
    }


def bench_obs_comparison(*, quick: bool = True, seed: int = 0,
                         max_batch: int = 4, page_size: int = 8,
                         max_window: int = 8, repeats: int = 3,
                         arch: str = "tiny-100m"):
    """Replay the overload trace with the flight recorder off vs on —
    shared params, warmed-up compiles — and price what observability
    costs.

    Scheduling runs on the deterministic step clock and the tracer only
    *reads* it, so the traced replay must emit per-request tokens
    bit-identical to the untraced one (``tokens_match``); the wall-clock
    ``overhead_ratio`` (min-of-``repeats`` traced wall / min untraced
    wall, alternated to decorrelate host drift) is gated at
    ``PERF_SMOKE_MAX_OBS_OVERHEAD`` (default 1.05 — a flight recorder
    that taxes serving >5% would never stay armed in production).

    The payload embeds the traced run's model-error rollup (per-phase
    cost-engine predicted vs measured wall) and a truncated copy of the
    Chrome trace events, which ``scripts/check_bench.py::check_obs``
    validates against the trace-event schema — the same document
    ``--trace-out`` ships to Perfetto.

    Returns the BENCH_obs.json payload.
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm

    tenants = overload_tenants(quick)
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    common = dict(seed=seed, max_batch=max_batch, page_size=page_size,
                  max_window=max_window, warmup=True, params=params,
                  arch=arch)

    walls = {"off": [], "on": []}
    stats, toks, traced_eng = {}, {}, None
    for rep in range(repeats):
        for mode in ("off", "on"):
            eng, rows, totals = replay(tenants, trace=mode == "on",
                                       **common)
            walls[mode].append(totals["wall_s"])
            t = {r.rid: list(r.tokens) for r in eng.sched.finished}
            assert toks.setdefault(mode, t) == t, \
                f"{mode} replay is not deterministic across repeats"
            stats[mode] = dict(tokens=totals["tokens"],
                               steps=totals["steps"],
                               tok_per_s=totals["tok_per_s"],
                               wall_s=min(walls[mode]))
            if mode == "on":
                traced_eng = eng

    tracer = traced_eng.tracer
    tracer.finalize(traced_eng.sched.step_idx)
    report = tracer.model_error_report()
    stats["on"].update(spans_recorded=tracer.recorded,
                       spans_dropped=tracer.dropped)
    doc = tracer.chrome_trace()
    events = doc["traceEvents"]
    keep = 600                     # enough for schema validation without
    return {                       # bloating the committed artifact
        "schema": "swallow.bench.obs/v1",
        "arch": arch, "batch": max_batch, "page_size": page_size,
        "max_window": max_window, "trace": "overload",
        "quick": quick, "seed": seed, "repeats": repeats,
        "off": stats["off"], "on": stats["on"],
        "tokens_match": toks["off"] == toks["on"],
        "overhead_ratio": min(walls["on"]) / max(min(walls["off"]), 1e-9),
        "model_error": report,
        "trace_events_total": len(events),
        "trace_events": events[:keep],
    }


def bench_tenants() -> List[Tenant]:
    """Decode-heavy pinned trace for BENCH_serve.json: one burst of
    long-gen requests at batch pressure, so fused windows actually reach
    ``max_window``.  (The docs quick trace is arrival-dominated — its
    windows are capped near K=1 by the admission clock, which makes it a
    TTFT exemplar, not a decode-throughput one.)"""
    return [Tenant("decode", 8, 0.0, 16, 24, at_step=0)]


def bench_fused_comparison(*, quick: bool = True, seed: int = 0,
                           max_batch: int = 4, page_size: int = 8,
                           max_window: int = 8, arch: str = "tiny-100m"):
    """Replay the pinned decode-burst trace twice — fused windows vs
    legacy per-step — with shared params, warmed-up compiles and an
    uncontended pool (speedup A/B, not a preemption stressor), asserting
    token identity per request.

    Returns the BENCH_serve.json payload (see scripts/check_bench.py).
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm

    tenants = bench_tenants()
    if not quick:
        tenants = [Tenant("decode", 16, 0.0, 32, 48, at_step=0)]
    max_len = max(t.prompt_len + t.gen for t in tenants)
    n_pages = max_batch * (-(-max_len // page_size)) + 1
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    toks = {}
    for mode, fused in (("fused", True), ("perstep", False)):
        eng, rows, totals = replay(tenants, seed=seed,
                                   max_batch=max_batch,
                                   page_size=page_size, n_pages=n_pages,
                                   fused=fused,
                                   max_window=max_window, warmup=True,
                                   params=params, arch=arch)
        toks[mode] = {r.rid: list(r.tokens) for r in eng.sched.finished}
        out[mode] = dict(
            tokens=totals["tokens"], steps=totals["steps"],
            windows=totals["windows"],
            decode_tok_per_s=totals["decode_tok_per_s"],
            tok_per_s=totals["tok_per_s"],
            h2d_syncs=totals["h2d_syncs"], d2h_syncs=totals["d2h_syncs"],
            syncs_per_token=totals["syncs_per_token"],
            preemptions=totals["preemptions"])
    return {
        "schema": "swallow.bench.serve/v1",
        "arch": arch, "batch": max_batch, "page_size": page_size,
        "max_window": max_window, "trace": "decode-burst",
        "quick": quick, "seed": seed,
        "fused": out["fused"], "perstep": out["perstep"],
        "tokens_match": toks["fused"] == toks["perstep"],
        "speedup_decode": out["fused"]["decode_tok_per_s"]
        / max(out["perstep"]["decode_tok_per_s"], 1e-9),
        "sync_reduction": out["perstep"]["syncs_per_token"]
        / max(out["fused"]["syncs_per_token"], 1e-9),
    }


def bench_prefix_comparison(*, quick: bool = True, seed: int = 0,
                            max_batch: int = 4, page_size: int = 8,
                            arch: str = "tiny-100m"):
    """Replay the shared-prefix multi-tenant trace twice — prefix cache
    on vs off — with shared params and warmed-up compiles, asserting
    per-request token identity (sharing is a placement transform, not a
    sampler change).

    Returns the BENCH_prefix.json payload (see scripts/check_bench.py):
    hit rate, prefill tokens saved, TTFT, tokens/s, and the headline
    ``prefill_token_reduction`` (>= 2x on this trace — the §X-B sharing
    overlay as a throughput lever).
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm

    tenants = shared_prefix_tenants(quick)
    max_len = max(t.prompt_len + t.gen for t in tenants)
    # room for every slot's worst case + the donated radix branches
    n_pages = 2 * max_batch * (-(-max_len // page_size)) \
        + len(tenants) * (-(-max_len // page_size)) + 1
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out, toks, ttft = {}, {}, {}
    for mode, cached in (("on", True), ("off", False)):
        eng, rows, totals = replay(tenants, seed=seed, max_batch=max_batch,
                                   page_size=page_size, n_pages=n_pages,
                                   prefix_cache=cached, warmup=True,
                                   params=params, arch=arch)
        toks[mode] = {r.rid: list(r.tokens) for r in eng.sched.finished}
        ttft[mode] = [r.first_token_step - r.arrived_step
                      for r in eng.sched.finished]
        out[mode] = dict(
            tokens=totals["tokens"], steps=totals["steps"],
            prefill_tokens=totals["prefill_tokens"],
            tok_per_s=totals["tok_per_s"],
            ttft_steps_mean=float(np.mean(ttft[mode])),
            preemptions=totals["preemptions"])
        if cached:
            out[mode].update(
                hit_rate=totals["hit_rate"],
                prefill_tokens_cached=totals["prefill_tokens_cached"],
                cow_copies=totals["cow_copies"],
                shared_pages=totals["shared_pages"],
                evictions=totals["prefix_evictions"],
                bytes_deduped=totals["bytes_deduped"])
    payload = {
        "schema": "swallow.bench.prefix/v1",
        "arch": arch, "batch": max_batch, "page_size": page_size,
        "trace": "shared-prefix", "quick": quick, "seed": seed,
        "tenants": len(tenants),
        "requests_per_tenant": tenants[0].n_requests,
        "on": out["on"], "off": out["off"],
        "tokens_match": toks["on"] == toks["off"],
        "prefill_token_reduction": out["off"]["prefill_tokens"]
        / max(out["on"]["prefill_tokens"], 1),
        "ttft_ratio": out["on"]["ttft_steps_mean"]
        / max(out["off"]["ttft_steps_mean"], 1e-9),
    }
    return payload


def bench_spec_comparison(*, quick: bool = True, seed: int = 0,
                          page_size: int = 8, max_window: int = 8,
                          spec_k="auto", arch: str = "tiny-100m"):
    """Replay the repetitive single-stream trace twice — speculative
    decoding on vs off — with shared params and warmed-up compiles,
    asserting per-request token identity (acceptance only ever keeps
    the verifier's own greedy tokens, so speculation is a dispatch
    transform, not a sampler change).

    Runs at max_batch=1: the latency-bound regime where cross-request
    batching cannot amortize model passes, so ``dispatches_per_token``
    isolates what drafting+verification buys (off is ~1.0 pass/token
    even with fused windows — a K-step scan is K sequential passes; a
    K+1-wide verify is ONE).  Speculation runs the device-resident
    fused draft+verify chain with ``spec_k="auto"`` adaptive depth by
    default — the configuration the engine ships.

    Returns the BENCH_spec.json payload (see scripts/check_bench.py):
    the headline ``spec_speedup`` (on/off wall tok_per_s, >= 1.0 is the
    bar — speculation must WIN wall-clock, not just dispatch counts),
    ``on.dispatches_per_token`` (< 0.7 — >= 1.4x fewer model dispatches
    per emitted token), and the honesty split of where each run's wall
    time went: ``scan_s`` (plain fused-scan device time),
    ``draft_verify_s`` (the speculative dispatch chain), ``host_s``
    (everything that is not a device dispatch — scheduling, accounting,
    h2d/d2h marshalling).  PR 5 hid a 5.6x wall-clock REGRESSION behind
    a 5x dispatch-count win precisely because this split was missing.
    """
    import jax
    from repro.configs import get_tiny_config
    from repro.models import lm

    tenants = repetitive_tenants(quick)
    max_len = max(t.prompt_len + t.gen for t in tenants)
    n_pages = (-(-max_len // page_size)) + 1       # exact single-slot pool
    cfg = get_tiny_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out, toks = {}, {}
    for mode, spec in (("on", True), ("off", False)):
        eng, rows, totals = replay(tenants, seed=seed, max_batch=1,
                                   page_size=page_size, n_pages=n_pages,
                                   fused=True, max_window=max_window,
                                   spec_decode=spec, spec_k=spec_k,
                                   warmup=True, params=params, arch=arch)
        toks[mode] = {r.rid: list(r.tokens) for r in eng.sched.finished}
        verify_s = totals.get("spec_verify_s", 0.0)
        out[mode] = dict(
            tokens=totals["tokens"], steps=totals["steps"],
            model_passes=totals["model_passes"],
            dispatches_per_token=totals["dispatches_per_token"],
            tok_per_s=totals["tok_per_s"],
            decode_tok_per_s=totals["decode_tok_per_s"],
            # the wall-clock honesty split: wall = scan + draft/verify
            # + host-side overhead
            wall_s=totals["wall_s"],
            scan_s=totals["decode_s"] - verify_s,
            draft_verify_s=verify_s,
            host_s=totals["wall_s"] - totals["decode_s"],
            h2d_syncs=totals["h2d_syncs"],
            d2h_syncs=totals["d2h_syncs"],
            preemptions=totals["preemptions"])
        if spec:
            out[mode].update(
                accept_rate=totals["accept_rate"],
                spec_drafted=totals["spec_drafted"],
                spec_accepted=totals["spec_accepted"],
                spec_verifies=totals["spec_verifies"],
                spec_rollbacks=totals["spec_rollbacks"],
                spec_k_mean=totals["spec_k_mean"])
    return {
        "schema": "swallow.bench.spec/v2",
        "arch": arch, "batch": 1, "page_size": page_size,
        "max_window": max_window, "spec_k": spec_k,
        "trace": "repetitive", "quick": quick, "seed": seed,
        "on": out["on"], "off": out["off"],
        "tokens_match": toks["on"] == toks["off"],
        "dispatch_reduction": out["off"]["dispatches_per_token"]
        / max(out["on"]["dispatches_per_token"], 1e-9),
        "spec_speedup": out["on"]["tok_per_s"]
        / max(out["off"]["tok_per_s"], 1e-9),
    }


# Pinned tensor-parallel workload: each layout replays EXACTLY this in a
# fresh subprocess (the parent process pinned its device count at jax
# import, so striped meshes need their own interpreter with
# --xla_force_host_platform_device_count set first — the same technique
# as tests/test_multidevice.py).
_TP_CHILD = r'''
import json, os, sys
import numpy as np
import jax
sys.path.insert(0, sys.argv[1])
data, model = int(sys.argv[2]), int(sys.argv[3])
arch, seed = sys.argv[4], int(sys.argv[5])
from repro.configs import get_tiny_config
from repro.models import lm
from repro.serving import PagedEngine
from repro.launch.mesh import make_test_mesh

cfg = get_tiny_config(arch)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
mesh = make_test_mesh(data, model) if data * model > 1 else None
n_nodes = max(model, 1)
eng = PagedEngine(cfg, params, max_batch=3, page_size=4, n_pages=48,
                  max_len=32, n_nodes=n_nodes, mesh=mesh,
                  prefix_cache=True, trace=True)
rng = np.random.default_rng(seed)
shared = rng.integers(2, cfg.vocab_size, 6, dtype=np.int32)
prompts = []
for i in range(6):
    tail = rng.integers(2, cfg.vocab_size, 6, dtype=np.int32)
    head = shared if i >= 4 else rng.integers(2, cfg.vocab_size, 6,
                                              dtype=np.int32)
    prompts.append(np.concatenate([head, tail]))
gens = [6, 9, 4, 7, 8, 5]
owner_steps = np.zeros(n_nodes, np.int64)
for i, (p, g) in enumerate(zip(prompts, gens)):
    eng.submit(p, g, rid=f"r{i}")
while eng.sched.waiting or eng.sched.running or eng.sched.prefilling:
    eng.step()
    for pages in eng.alloc.held.values():      # page-steps per owner node
        for pg in pages:
            owner_steps[pg % n_nodes] += 1
eng.tracer.finalize(eng.sched.step_idx)
report = eng.tracer.model_error_report()
tot = int(owner_steps.sum())
out = {
    "predicted_s": sum(r["predicted_s"] for r in report.values()),
    "measured_s": sum(r["measured_s"] for r in report.values()),
    "predicted_comms_s": sum(r.get("predicted_comms_s", 0.0)
                             for r in report.values()),
    "comms_bytes": sum(r.get("comms_bytes", 0.0)
                       for r in report.values()),
    "measured_remote_frac": (1.0 - owner_steps[0] / tot) if tot else 0.0,
    "steps": eng.steps_run,
    "cow_copies": eng.cache.stats.cow_copies,
    "preemptions": eng.metrics()["preemptions"],
}
tokens = {r.rid: [int(t) for t in r.tokens] for r in eng.sched.finished}
out["tokens"] = tokens
print("JSON:" + json.dumps(out))
'''

TP_LAYOUTS = ((1, 1), (1, 2), (2, 2))


def bench_tp_comparison(*, quick: bool = True, seed: int = 0,
                        arch: str = "tiny-100m"):
    """Replay a pinned prefix-sharing workload through the paged engine
    at every serving layout — 1x1 single device, then 1x2 and 2x2
    striped meshes — each in a fresh subprocess with the host device
    count forced, asserting per-request greedy-token bit-identity
    against the 1x1 baseline (the ISSUE's exactness gate: sharding is a
    placement transform, never a sampler change).

    Per layout the payload records the traced run's predicted vs
    measured seconds, the window-level predicted interconnect cost
    (``predicted_comms_s`` / ``comms_bytes`` — the §V link model priced
    per dispatch span), and ``measured_remote_frac``: the fraction of
    page-steps held on nodes other than node 0, measured from the live
    allocator each engine step.  The §V model predicts (n-1)/n for a
    striped store; ``scripts/check_bench.py::check_tp`` gates the
    measured/predicted ratio at ``PERF_SMOKE_MAX_TP_MODEL_ERROR``.

    Returns the BENCH_tp.json payload.
    """
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    layouts = []
    base_tokens = None
    for data, model in TP_LAYOUTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        proc = subprocess.run(
            [sys.executable, "-c", _TP_CHILD, src, str(data), str(model),
             arch, str(seed)],
            capture_output=True, text=True, env=env, cwd=root, timeout=900)
        assert proc.returncode == 0, \
            f"tp child {data}x{model} failed:\n{proc.stdout}\n{proc.stderr}"
        payload = next(ln for ln in proc.stdout.splitlines()
                       if ln.startswith("JSON:"))
        child = json.loads(payload[len("JSON:"):])
        tokens = child.pop("tokens")
        if base_tokens is None:
            base_tokens = tokens
        n = max(model, 1)
        predicted_remote = (n - 1) / n
        layouts.append(dict(
            layout=f"{data}x{model}", data=data, model=model,
            tokens_match=tokens == base_tokens,
            predicted_remote_frac=predicted_remote,
            remote_frac_ratio=(child["measured_remote_frac"]
                               / predicted_remote if predicted_remote
                               else 1.0),
            **child))
    return {
        "schema": "swallow.bench.tp/v1",
        "arch": arch, "batch": 3, "page_size": 4, "n_pages": 48,
        "trace": "tp-pinned", "quick": quick, "seed": seed,
        "layouts": layouts,
        "tokens_match": all(l["tokens_match"] for l in layouts),
    }


def format_table(rows, totals) -> str:
    out = [f"# paged serve trace — {len(rows)} tenants, "
           f"{totals['n_pages']} pages x {totals['page_size']} tokens",
           f"{'tenant':<12} {'slo':<11} {'reqs':>5} {'tokens':>7} "
           f"{'ttft_mean':>10} {'ttft_p95':>9} {'ttft_p99':>9} "
           f"{'met%':>5} {'preempt':>8}"]
    for r in rows:
        out.append(f"{r['tenant']:<12} {r['slo']:<11} {r['requests']:>5} "
                   f"{r['tokens']:>7} {r['ttft_mean']:>10.1f} "
                   f"{r['ttft_p95']:>9.1f} {r['ttft_p99']:>9.1f} "
                   f"{r['slo_met_frac'] * 100:>4.0f}% "
                   f"{r['preemptions']:>8}")
    t = totals
    out.append(f"{t['steps']} engine steps in {t['windows']} device "
               f"dispatches, {t['tokens']} tokens "
               f"({t['tok_per_s']:.0f} tok/s wall, "
               f"{t['decode_tok_per_s']:.0f} decode tok/s); "
               f"host<->device syncs {t['h2d_syncs']} h2d + "
               f"{t['d2h_syncs']} d2h ({t['syncs_per_token']:.2f}/token); "
               f"page occupancy "
               f"mean {t['occupancy_mean'] * 100:.0f}% / peak "
               f"{t['occupancy_peak'] * 100:.0f}%; "
               f"{t['preemptions']} preemptions")
    if "accept_rate" in t:
        out.append(f"spec decode: {t['model_passes']} model passes "
                   f"({t['dispatches_per_token']:.2f}/token), "
                   f"{t['accept_rate'] * 100:.0f}% accept rate "
                   f"({t['spec_accepted']}/{t['spec_drafted']} drafts, "
                   f"{t['spec_verifies']} verifies, "
                   f"{t['spec_rollbacks']} page rollbacks); "
                   f"mean K {t['spec_k_mean']:.1f}, draft+verify "
                   f"{t['spec_verify_s']:.3f}s of {t['decode_s']:.3f}s "
                   f"decode")
    if "hit_rate" in t:
        out.append(f"prefix cache: {t['hit_rate'] * 100:.0f}% hit rate, "
                   f"{t['prefill_tokens_cached']} prefill tokens served "
                   f"from shared pages ({t['prefill_tokens']} computed), "
                   f"{t['cow_copies']} COW copies, {t['shared_pages']} "
                   f"tree pages, {t['prefix_evictions']} evictions, "
                   f"{t['bytes_deduped'] / 1024:.0f} KiB deduped")
    if "chunk_dispatches" in t:
        out.append(f"chunked prefill: {t['chunk_tasks']} chunks in "
                   f"{t['chunk_rounds']} rounds "
                   f"({t['chunk_dispatches']} dispatches), "
                   f"{t['chunk_preemptions']} mid-prefill preemptions")
    if "node_failures" in t:
        out.append(f"fault plane: {t['node_failures']} node failures / "
                   f"{t['node_joins']} re-joins, "
                   f"{t['pages_quarantined']} pages quarantined, "
                   f"{t['requests_recovered']} requests recovered "
                   f"({t['tokens_recomputed']} tokens recomputed), "
                   f"{t['requests_shed']} shed, "
                   f"{t['transient_rejections']} transient rejections, "
                   f"recovery p50/p99 {t['recovery_steps_p50']:.0f}/"
                   f"{t['recovery_steps_p99']:.0f} steps, "
                   f"{t['quarantined_served']} stale reads")
    return "\n".join(out)


def fleet_view(eng) -> str:
    """Per-tenant gauges through the nOS serving surface.  The
    speculative-decoding gauges are engine-wide (acceptance is not
    tracked per tenant), so every tenant row shows the same pair.  When
    the flight recorder is armed, each tenant's share of the
    predicted-vs-measured attribution rides along (split by token
    share — dispatches are batched across tenants, so per-tenant wall
    is an apportionment, not a measurement) and the nOS attribution
    table is appended."""
    from repro.core import nos as nos_mod
    from repro.serving.slo import get_slo
    pod = nos_mod.NOS(data_rows=4, model_cols=1)
    est = eng.decode_estimate      # engine-priced step time & energy
    j_per_token = est.energy.total_j / max(eng.max_batch, 1)
    m = eng.metrics()
    report = None
    pred_s = meas_s = pred_j = 0.0
    if eng.tracer is not None:
        report = eng.tracer.model_error_report()
        pred_s = sum(r["predicted_s"] for r in report.values())
        meas_s = sum(r["measured_s"] for r in report.values())
        pred_j = sum(r["predicted_j"] for r in report.values())
    all_tokens = sum(len(r.tokens) for r in eng.sched.finished)
    tenants = sorted({r.tenant for r in eng.sched.finished})
    for name in tenants:
        fin = [r for r in eng.sched.finished if r.tenant == name]
        ttft = [r.first_token_step - r.arrived_step for r in fin]
        tokens = sum(len(r.tokens) for r in fin)
        met_tokens = sum(len(r.tokens) for r in fin
                         if r.first_token_step <= r.deadline_step)
        # a trace tenant's requests share one SLO class; price its
        # step-clock deadline to seconds with the engine's own estimate
        slo = get_slo(fin[0].slo) if fin else None
        pod.submit(nos_mod.Job(name, rows_needed=1))
        pod.update_serving(
            name,
            pages_held=max((eng.alloc.pages_for(r.prompt_len + r.gen)
                            for r in fin), default=0),
            tokens_out=tokens,
            queue_latency_s=(float(np.mean(ttft)) if ttft else 0.0)
            * est.step_time_s,
            preemptions=sum(r.preemptions for r in fin),
            energy_j=tokens * j_per_token,
            accept_rate=m.get("accept_rate"),
            dispatches_per_token=m.get("dispatches_per_token"),
            ttft_p99_s=(float(np.percentile(ttft, 99)) if ttft else 0.0)
            * est.step_time_s,
            ttft_target_s=(slo.ttft_steps * est.step_time_s
                           if slo else None),
            goodput_frac=met_tokens / max(tokens, 1),
            # fault gauges are engine-wide, like accept_rate: every
            # tenant row shows the same recovery story
            pages_quarantined=m.get("pages_quarantined"),
            requests_recovered=m.get("requests_recovered"),
            tokens_recomputed=m.get("tokens_recomputed"),
            recovery_steps_p99=m.get("recovery_steps_p99"),
            **({"predicted_s": pred_s * tokens / max(all_tokens, 1),
                "measured_s": meas_s * tokens / max(all_tokens, 1),
                "predicted_j": pred_j * tokens / max(all_tokens, 1)}
               if report else {}))
    table = pod.serving_table()
    if report:
        table += ("\n[nOS] predicted-vs-measured attribution:\n"
                  + pod.attribution_table())
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small trace for CI / docs examples")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=0)
    ap.add_argument("--link-mode", default="circuit",
                    choices=["circuit", "packet"])
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused multi-token decode windows "
                         "(--no-fused = legacy per-step loop)")
    ap.add_argument("--window", type=int, default=8,
                    help="max fused window (tokens per device dispatch)")
    ap.add_argument("--trace", default="mixed",
                    choices=sorted(TRACES),
                    help="mixed: the bursty Poisson tenants; "
                         "shared-prefix: N tenants x M requests sharing "
                         "per-tenant system prompts; repetitive: the "
                         "single-stream motif trace speculation feeds on; "
                         "overload: the heavy-traffic SLO harness "
                         "(diurnal interactive + Pareto batch + surge)")
    ap.add_argument("--prefix-cache", default="off", choices=["on", "off"],
                    help="radix-tree prefix sharing on the page store")
    ap.add_argument("--spec-decode", default="off", choices=["on", "off"],
                    help="n-gram speculative decoding (draft from the "
                         "sequence's own history, verify K+1 positions "
                         "in one dispatch)")
    ap.add_argument("--spec-k", default="auto",
                    help="max draft tokens per verification dispatch, or "
                         "'auto' for per-request adaptive depth from the "
                         "acceptance EWMA (the default)")
    ap.add_argument("--chunk-prefill", default="off", choices=["on", "off"],
                    help="page-aligned chunked prefill with SLO-aware EDF "
                         "admission (off = monolithic priced FIFO)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="tokens per prefill chunk (0 = 2 pages)")
    ap.add_argument("--nodes", type=int, default=1,
                    help="memory nodes striping the page pool (a node "
                         "failure quarantines its stripe)")
    ap.add_argument("--fault-plan", default="off", choices=["off", "chaos"],
                    help="chaos: arm a seeded FaultPlan (node failures + "
                         "transient rejections + a straggler) against "
                         "the run")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the chaos FaultPlan")
    ap.add_argument("--fault-horizon", type=int, default=48,
                    help="steps the chaos schedule spans")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="arm the flight recorder and export the replay "
                         "as Chrome trace-event JSON "
                         "(docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-out", default=None,
                    metavar="METRICS.json",
                    help="dump the unified metrics registry snapshot")
    args = ap.parse_args()
    spec_k = args.spec_k if args.spec_k == "auto" else int(args.spec_k)
    fault_plan = None
    if args.fault_plan == "chaos":
        from repro.serving import FaultPlan
        if args.nodes < 2:
            print("serve_trace: --fault-plan chaos needs --nodes >= 2 "
                  "(node 0 never fails)", file=sys.stderr)
            raise SystemExit(2)
        fault_plan = FaultPlan.seeded(args.fault_seed, n_nodes=args.nodes,
                                      horizon=args.fault_horizon)
    eng, rows, totals = replay(args.trace, quick=args.quick,
                               seed=args.seed, max_batch=args.batch,
                               page_size=args.page_size, n_pages=args.pages,
                               link_mode=args.link_mode, fused=args.fused,
                               max_window=args.window,
                               prefix_cache=args.prefix_cache == "on",
                               spec_decode=args.spec_decode == "on",
                               spec_k=spec_k,
                               chunk_prefill=args.chunk_prefill == "on",
                               chunk_tokens=args.chunk_tokens,
                               n_nodes=args.nodes, fault_plan=fault_plan,
                               trace=bool(args.trace_out))
    print(format_table(rows, totals))
    if eng.tracer is not None:
        from repro.serving.telemetry import format_model_error
        eng.tracer.finalize(eng.sched.step_idx)
        report = eng.tracer.model_error_report()
        if report:
            print("per-phase model error (cost-engine predicted vs "
                  "measured wall):")
            print(format_model_error(report))
        if args.trace_out:
            eng.tracer.write_chrome(args.trace_out)
            print(f"[trace] wrote {args.trace_out} "
                  f"({eng.tracer.recorded} spans recorded, "
                  f"{eng.tracer.dropped} evicted)")
    if args.metrics_out:
        import json
        with open(args.metrics_out, "w") as f:
            json.dump(eng.registry.snapshot(), f, indent=2,
                      sort_keys=True)
        print(f"[metrics] wrote {args.metrics_out}")
    if args.trace == "overload":
        for cls, d in slo_stats(eng).items():
            print(f"slo[{cls}]: p50/p95/p99 ttft "
                  f"{d['ttft_steps_p50']:.0f}/{d['ttft_steps_p95']:.0f}/"
                  f"{d['ttft_steps_p99']:.0f} steps "
                  f"(target {d['ttft_target_steps']}), "
                  f"met {d['slo_met_frac'] * 100:.0f}%, goodput "
                  f"{d['goodput_tokens']}/{d['tokens']} tokens")
    print("[nOS] fleet serving view:")
    print(fleet_view(eng))


if __name__ == "__main__":
    main()
