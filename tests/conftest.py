"""Test fixtures.  NOTE: no XLA_FLAGS here — tests must see ONE device;
multi-device behaviour is tested via subprocesses (test_multidevice.py)."""
import os
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=64, seed=7):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if cfg.embed_inputs:
        tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    else:
        tokens = jax.random.normal(k1, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.mrope_sections is not None:
        import repro.models.lm as lm
        batch["positions"] = lm.default_positions(cfg, B, S)
    return batch
