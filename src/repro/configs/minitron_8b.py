"""Minitron-8B (width-pruned Nemotron-4) [arXiv:2407.14679; hf-verified].

Dense decoder: 32L, d_model=4096, 32 Q heads / 8 KV heads, d_ff=16384,
vocab=256000.  Nemotron family: squared-ReLU MLP (no GLU gate), untied
embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    act="relu2",
    gated_ffn=False,
    rope_theta=10_000.0,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_block_q=16, attn_block_kv=32)
