"""Unified LM: init / forward / loss / prefill / decode over layer segments.

Layers are grouped into homogeneous *segments* (same cycle of layer kinds,
same FFN type) and scanned with ``lax.scan`` — the layer-streaming
structure that keeps the compiled HLO small and gives FSDP its
gather-per-layer (Swallow C3 "overlays") behaviour.  Heterogeneous
patterns (gemma2 local/global, recurrentgemma 2:1) scan whole cycles;
remainder layers form their own segments.
"""
from __future__ import annotations

import functools
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, modules as nn
from repro.parallel.sharding import logical_constraint

LOSS_CHUNK = 512  # sequence chunk for the fused/chunked cross-entropy


class SegmentSpec(NamedTuple):
    kinds: Tuple[str, ...]
    is_moe: bool
    n_cycles: int
    scanned: bool
    start_layer: int


def make_segments(cfg: ModelConfig) -> List[SegmentSpec]:
    kinds = cfg.layer_kinds
    moe_flags = [cfg.moe is not None and i >= cfg.first_k_dense
                 for i in range(cfg.n_layers)]
    p = len(cfg.layer_pattern)
    segs: List[SegmentSpec] = []
    i = 0
    while i < cfg.n_layers:
        if i % p == 0 and i + p <= cfg.n_layers \
                and len(set(moe_flags[i:i + p])) == 1:
            # count consecutive full cycles with the same MoE signature
            n = 0
            j = i
            while j + p <= cfg.n_layers \
                    and kinds[j:j + p] == cfg.layer_pattern \
                    and len(set(moe_flags[j:j + p])) == 1 \
                    and moe_flags[j] == moe_flags[i]:
                n += 1
                j += p
            segs.append(SegmentSpec(cfg.layer_pattern, moe_flags[i], n,
                                    n > 1, i))
            i = j
        else:
            # remainder: group consecutive same-(kind, moe) layers
            k0, m0 = kinds[i], moe_flags[i]
            n = 0
            while i + n < cfg.n_layers and kinds[i + n] == k0 \
                    and moe_flags[i + n] == m0:
                n += 1
            segs.append(SegmentSpec((k0,), m0, n, n > 1, i))
            i += n
    assert sum(s.n_cycles * len(s.kinds) for s in segs) == cfg.n_layers
    return segs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig):
    dtype = nn.dt(cfg.param_dtype)
    segs = make_segments(cfg)
    n_keys = len(segs) + 4
    ks = jax.random.split(key, n_keys)
    params: dict = {}
    if cfg.embed_inputs:
        params["embed"] = {"embed_table": nn.embed_init(
            ks[0], cfg.vocab_size, cfg.d_model, dtype)}

    def cycle_init(k, seg: SegmentSpec):
        kk = jax.random.split(k, len(seg.kinds))
        return [blocks.init(kk[j], cfg, seg.kinds[j], seg.is_moe, dtype)
                for j in range(len(seg.kinds))]

    seg_params = []
    for si, seg in enumerate(segs):
        if seg.scanned:
            seg_keys = jax.random.split(ks[1 + si], seg.n_cycles)
            seg_params.append(jax.vmap(
                functools.partial(cycle_init, seg=seg))(seg_keys))
        else:
            seg_params.append(cycle_init(ks[1 + si], seg))
    params["segments"] = seg_params
    params["final_norm"] = nn.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        params["head"] = {"head_w": nn.dense_init(
            ks[-1], cfg.d_model, cfg.vocab_size, dtype)}
    if cfg.mtp_depth:
        kk = jax.random.split(ks[-2], 2 + cfg.mtp_depth)
        last_seg = segs[-1]
        params["mtp"] = {
            "mtp_proj": nn.dense_init(kk[0], 2 * cfg.d_model, cfg.d_model,
                                      dtype),
            "norm_h": nn.rmsnorm_init(cfg.d_model),
            "norm_e": nn.rmsnorm_init(cfg.d_model),
            "final_norm": nn.rmsnorm_init(cfg.d_model),
            "block": blocks.init(kk[1], cfg, last_seg.kinds[-1],
                                 last_seg.is_moe, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------
def _rope_dim(cfg) -> int:
    return cfg.mla.qk_rope_head_dim if cfg.mla is not None else cfg.head_dim


def _angles(cfg, positions):
    if not cfg.rope:
        return None
    return nn.rope_angles(positions, _rope_dim(cfg), cfg.rope_theta,
                          cfg.mrope_sections)


def default_positions(cfg, batch: int, seq: int, offset: int = 0):
    pos = jnp.arange(offset, offset + seq, dtype=jnp.int32)[None]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def embed_tokens(params, cfg, tokens):
    if cfg.embed_inputs:
        table = params["embed"]["embed_table"]
        x = jnp.take(table, tokens, axis=0).astype(nn.dt(cfg.activation_dtype))
    else:
        x = tokens.astype(nn.dt(cfg.activation_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def head_logits(params, cfg, h):
    """h (..., D) -> fp32 logits (..., V), with final softcap."""
    if cfg.tie_embeddings and cfg.embed_inputs:
        w = params["embed"]["embed_table"]  # (V, D)
        logits = jax.lax.dot_general(
            h, w, (((h.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        logits = jax.lax.dot_general(
            h, params["head"]["head_w"], (((h.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    logits = logical_constraint(logits, "batch", None, "vocab")
    if cfg.logit_softcap is not None:
        logits = nn.softcap(logits, cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, *, mode: str,
            positions=None, impl: Optional[str] = None):
    """tokens: (B,S) int32 ids or (B,S,D) embeddings (stub frontends).

    Returns (h_final (B,S,D) pre-final-norm, caches, aux).
    caches is None in train mode; in prefill mode it is the raw per-segment
    cache pytree (convert with ``caches_from_prefill``).
    """
    assert mode in ("train", "prefill")
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[:2]
    if positions is None:
        positions = default_positions(cfg, B, S)
    angles = _angles(cfg, positions)
    x = logical_constraint(x, "batch", "seq_sp", None)

    segs = make_segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches = []

    for seg, seg_p in zip(segs, params["segments"]):
        def cycle_apply(cyc_p, x):
            cache_list = []
            aux_c = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(seg.kinds):
                x, c, a = blocks.apply(cyc_p[j], cfg, kind, x,
                                       angles=angles, mode=mode, impl=impl)
                if mode == "prefill":
                    cache_list.append(c)
                aux_c = aux_c + a
            x = logical_constraint(x, "batch", "seq_sp", None)
            return x, (tuple(cache_list) if mode == "prefill" else None), aux_c

        if seg.scanned:
            def scan_body(carry, cyc_p):
                x, aux = carry
                x, cache_c, aux_c = cycle_apply(cyc_p, x)
                return (x, aux + aux_c), cache_c

            if cfg.remat and mode == "train":
                scan_body = jax.checkpoint(scan_body)
            (x, aux_total), cache_seg = jax.lax.scan(
                scan_body, (x, aux_total), seg_p)
        else:
            x, cache_seg, aux_c = cycle_apply(seg_p, x)
            aux_total = aux_total + aux_c
        caches.append(cache_seg)

    return x, (caches if mode == "prefill" else None), aux_total


# ---------------------------------------------------------------------------
# loss (chunked fused cross-entropy over the sequence)
# ---------------------------------------------------------------------------
def _head_weight(params, cfg):
    """(D, V) head matrix (transposed embedding when tied)."""
    if cfg.tie_embeddings and cfg.embed_inputs:
        return params["embed"]["embed_table"].T
    return params["head"]["head_w"]


def _local_ce(logits, labels_c, mask_c, v_offset, v_local, softcap,
              axes=()):
    """Vocab-parallel CE on local logits (B,Sc,v_local) fp32.

    With ``axes`` (mesh axis names of the vocab shards) the reductions are
    explicit psums — exact, and only (B,Sc)-sized traffic on the wire.
    """
    if softcap is not None:
        logits = nn.softcap(logits, softcap)
    # stop_gradient on the max: pmax has no VJP, and d(lse)/d(logits) is
    # exactly softmax either way (the max terms cancel analytically)
    m = jax.lax.stop_gradient(logits.max(-1))
    if axes:
        m = jax.lax.stop_gradient(jax.lax.pmax(m, axes))
    s = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
    if axes:
        s = jax.lax.psum(s, axes)
    lse = m + jnp.log(s)
    loc = labels_c - v_offset
    in_range = (loc >= 0) & (loc < v_local)
    loc = jnp.clip(loc, 0, v_local - 1)
    correct = jnp.take_along_axis(logits, loc[..., None], axis=-1)[..., 0]
    correct = jnp.where(in_range, correct, 0.0)
    if axes:
        correct = jax.lax.psum(correct, axes)
    nll = (lse - correct) * mask_c
    return nll.sum(), mask_c.sum()


def _chunk_ce(params, cfg, h_c, labels_c, mask_c):
    """One sequence chunk of CE.  Under a mesh this is a shard_map with
    vocab-parallel logits: each shard computes (B,Sc,V/tp) locally and the
    only collectives are (B,Sc)-sized psums — never logits-sized."""
    from repro.parallel.sharding import current_env
    env = current_env()
    w = _head_weight(params, cfg)
    vocab_axes = env.resolve("vocab") if env is not None else None
    if env is None or vocab_axes is None:
        logits = jax.lax.dot_general(
            h_c, w, (((h_c.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return _local_ce(logits, labels_c, mask_c, 0, cfg.vocab_size,
                         cfg.logit_softcap)

    axes = (vocab_axes,) if isinstance(vocab_axes, str) else tuple(vocab_axes)
    tp = 1
    for a in axes:
        tp *= env.mesh.shape[a]
    if cfg.vocab_size % tp:
        logits = head_logits(params, cfg, h_c)
        return _local_ce(logits, labels_c, mask_c, 0, cfg.vocab_size,
                         None)  # softcap applied in head_logits

    v_local = cfg.vocab_size // tp

    def body(h_l, w_l, lab_l, mask_l):
        idx = jax.lax.axis_index(axes[0]) if len(axes) == 1 else 0
        logits = jax.lax.dot_general(
            h_l, w_l, (((h_l.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        tot, cnt = _local_ce(logits, lab_l, mask_l, idx * v_local, v_local,
                             cfg.logit_softcap, axes)
        batch_axes = [a for a in env.mesh.axis_names if a not in axes]
        if batch_axes:
            tot = jax.lax.psum(tot, tuple(batch_axes))
            cnt = jax.lax.psum(cnt, tuple(batch_axes))
        return tot, cnt

    from repro.models.moe import _shard_map
    tot, cnt = _shard_map(
        body, mesh=env.mesh,
        in_specs=(env.spec("batch", None, None),   # h replicated over model
                  env.spec(None, "vocab"),
                  env.spec("batch", None),
                  env.spec("batch", None)),
        out_specs=(env.spec(), env.spec()),
        check_vma=False)(h_c, w, labels_c, mask_c)
    return tot, cnt


def cross_entropy(params, cfg, h, labels, mask):
    """Chunked CE: never materializes (B,S,V) for the whole sequence."""
    B, S, D = h.shape
    c = min(LOSS_CHUNK, S)
    while S % c:
        c -= 1
    n = S // c
    if n == 1:
        tot, cnt = _chunk_ce(params, cfg, h, labels, mask)
        return tot / jnp.maximum(cnt, 1.0)

    hs = jnp.moveaxis(h.reshape(B, n, c, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, c), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        h_c, l_c, m_c = inp
        t, k = _chunk_ce(params, cfg, h_c, l_c, m_c)
        return (tot + t, cnt + k), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, impl=None):
    """batch: tokens/embeds, labels (B,S), mask (B,S). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    h, _, aux = forward(params, cfg, tokens, mode="train",
                        positions=batch.get("positions"), impl=impl)
    hn = nn.rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    ce = cross_entropy(params, cfg, hn, batch["labels"], batch["mask"])
    loss = ce
    metrics = {"ce": ce, "moe_aux": aux}
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_coef * aux
    if cfg.mtp_depth and "mtp" in params:
        mtp_ce = _mtp_loss(params, cfg, h, tokens, batch["labels"],
                           batch["mask"], impl=impl)
        loss = loss + 0.3 * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params, cfg, h, tokens, labels, mask, *, impl=None):
    """DeepSeek multi-token prediction: one extra block predicts t+2."""
    p = params["mtp"]
    B, S = labels.shape
    # embedding of the next token (teacher-forced)
    e_next = embed_tokens(params, cfg, labels)          # (B,S,D) token t+1
    hn = nn.rmsnorm(h, p["norm_h"]["scale"], cfg.norm_eps)
    en = nn.rmsnorm(e_next, p["norm_e"]["scale"], cfg.norm_eps)
    h_in = nn.matmul(jnp.concatenate([hn, en], -1), p["mtp_proj"])
    positions = default_positions(cfg, B, S)
    angles = _angles(cfg, positions)
    seg = make_segments(cfg)[-1]
    h_mtp, _, _ = blocks.apply(p["block"], cfg, seg.kinds[-1], h_in,
                               angles=angles, mode="train", impl=impl)
    h_mtp = nn.rmsnorm(h_mtp, p["final_norm"]["scale"], cfg.norm_eps)
    # targets: token t+2 = labels shifted left by one
    labels2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], 1)
    mask2 = jnp.concatenate([mask[:, 1:], jnp.zeros_like(mask[:, -1:])], 1)
    return cross_entropy(params, cfg, h_mtp, labels2, mask2)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------
def caches_from_prefill(cfg, raw_caches, max_len: int):
    segs = make_segments(cfg)
    out = []
    for seg, seg_c in zip(segs, raw_caches):
        def conv_cycle(cyc):
            return tuple(blocks.cache_from_prefill(cfg, seg.kinds[j], cyc[j],
                                                   max_len)
                         for j in range(len(seg.kinds)))
        if seg.scanned:
            out.append(jax.vmap(conv_cycle)(seg_c))
        else:
            out.append(conv_cycle(seg_c))
    return out


def prefill(params, cfg, tokens, *, max_len: int, positions=None, impl=None):
    """Returns (next-token logits (B,1,V), decode caches)."""
    h, raw, _ = forward(params, cfg, tokens, mode="prefill",
                        positions=positions, impl=impl)
    caches = caches_from_prefill(cfg, raw, max_len)
    h_last = h[:, -1:]
    h_last = nn.rmsnorm(h_last, params["final_norm"]["scale"], cfg.norm_eps)
    return head_logits(params, cfg, h_last), caches


def init_caches(cfg, batch: int, max_len: int):
    dtype = nn.dt(cfg.activation_dtype)
    segs = make_segments(cfg)
    out = []
    for seg in segs:
        cyc = tuple(blocks.cache_init(cfg, k, batch, max_len, dtype)
                    for k in seg.kinds)
        if seg.scanned:
            cyc = jax.tree.map(
                lambda l: jnp.zeros((seg.n_cycles,) + l.shape, l.dtype), cyc)
        out.append(cyc)
    return out


# ---------------------------------------------------------------------------
# paged decode — pools + block tables instead of per-sequence slabs
# ---------------------------------------------------------------------------
def paged_decodable(cfg) -> bool:
    """Paged serving needs causal, embedded-token, global-attention-only
    configs (windows and recurrent states are constant-size — nothing to
    page) and no M-RoPE (per-sequence positions are scalar per step)."""
    return (cfg.supports_decode and cfg.embed_inputs
            and cfg.mrope_sections is None
            and all(k == "attn" for k in cfg.layer_kinds))


def init_paged_caches(cfg, n_pages: int, page_size: int):
    """Per-layer paged KV pools, mirroring the init_caches pytree: one
    PagedAttnCache per layer, stacked (n_cycles, ...) for scanned
    segments.  All layers share one block table — page ids are logical
    across the whole stack, exactly the vLLM layout."""
    assert paged_decodable(cfg), f"{cfg.name} is not paged-decodable"
    dtype = nn.dt(cfg.activation_dtype)
    segs = make_segments(cfg)
    out = []
    for seg in segs:
        cyc = tuple(blocks.paged_cache_init(cfg, k, n_pages, page_size,
                                            dtype)
                    for k in seg.kinds)
        if seg.scanned:
            cyc = jax.tree.map(
                lambda l: jnp.zeros((seg.n_cycles,) + l.shape, l.dtype), cyc)
        out.append(cyc)
    return out


def paged_from_prefill(cfg, pools, raw_caches, block_row):
    """Scatter ONE sequence's prefill kv (from forward(mode="prefill"),
    batch 1) into the pools at the pages named by ``block_row``."""
    segs = make_segments(cfg)
    out = []
    for seg, seg_pool, seg_raw in zip(segs, pools, raw_caches):
        def conv_cycle(cyc_pool, cyc_raw):
            return tuple(
                blocks.paged_cache_from_prefill(cfg, seg.kinds[j],
                                                cyc_pool[j], cyc_raw[j],
                                                block_row)
                for j in range(len(seg.kinds)))
        if seg.scanned:
            out.append(jax.vmap(conv_cycle)(seg_pool, seg_raw))
        else:
            out.append(conv_cycle(seg_pool, seg_raw))
        # vmap over the scan-stacked layer dim: same block row, each
        # layer's own pool/raw slice
    return out


def decode_step_paged(params, cfg, tokens, pools, block_tables, pos):
    """One paged decode step over a continuous batch.

    tokens (B,1) int32; block_tables (B,nmax) int32 physical page ids;
    pos (B,) int32 per-sequence positions (inactive slots: 0, with a
    null-page block row).  Returns (logits (B,1,V), new pools).
    """
    x = embed_tokens(params, cfg, tokens)
    positions = pos[:, None].astype(jnp.int32)
    angles = _angles(cfg, positions)

    segs = make_segments(cfg)
    new_pools = []
    for seg, seg_p, seg_pool in zip(segs, params["segments"], pools):
        def cycle_decode(cyc_p, cyc_pool, x):
            new_c = []
            for j, kind in enumerate(seg.kinds):
                x, c = blocks.apply_decode_paged(cyc_p[j], cfg, kind, x,
                                                 cyc_pool[j], block_tables,
                                                 pos, angles=angles)
                new_c.append(c)
            return x, tuple(new_c)

        if seg.scanned:
            def scan_body(x, inp):
                cyc_p, cyc_pool = inp
                x, new_c = cycle_decode(cyc_p, cyc_pool, x)
                return x, new_c
            x, new_seg = jax.lax.scan(scan_body, x, (seg_p, seg_pool))
        else:
            x, new_seg = cycle_decode(seg_p, seg_pool, x)
        new_pools.append(new_seg)

    h = nn.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return head_logits(params, cfg, h), new_pools


def decode_window_paged(params, cfg, tokens, pools, block_tables, pos,
                        active, k: int):
    """Fused K-step greedy decode window, entirely on device.

    ``lax.scan`` chains :func:`decode_step_paged` K times: the greedy
    argmax of step j feeds step j+1 without a host round-trip, KV pages
    are appended in place, and per-slot positions advance on device.
    The block tables must be fixed for the whole window — the scheduler
    pre-reserves the window's pages (``safe_horizon``) to guarantee it.

    tokens (B,1) int32 last emitted token per slot; pos (B,) int32 write
    positions; active (B,) int32 1 for occupied slots (inactive slots
    hold token/pos fixed so their null-page writes stay at slot 0).
    Returns (emitted (B,K) int32, last tokens (B,1), pos (B,), pools).
    """
    def body(carry, _):
        tok, p, pl = carry
        logits, pl = decode_step_paged(params, cfg, tok, pl, block_tables, p)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)      # (B,1)
        nxt = jnp.where(active[:, None] > 0, nxt, tok)
        p = p + active
        return (nxt, p, pl), nxt[:, 0]

    (tok, pos, pools), toks = jax.lax.scan(body, (tokens, pos, pools),
                                           None, length=k)
    return jnp.moveaxis(toks, 0, 1), tok, pos, pools


def _suffix_forward_paged(params, cfg, tokens, pools, block_row, start,
                          n_valid):
    """Shared body of :func:`prefill_suffix_paged` and
    :func:`verify_window_paged`: run a W-token continuation (positions
    start..start+W-1) through every layer's ``apply_prefill_paged``,
    scattering its kv into the sequence's pages and attending causally
    over the whole page run.  Returns (hidden states (1,W,D), pools)."""
    x = embed_tokens(params, cfg, tokens)
    B, W = tokens.shape
    positions = (start + jnp.arange(W, dtype=jnp.int32))[None]
    positions = jnp.broadcast_to(positions, (B, W))
    angles = _angles(cfg, positions)

    segs = make_segments(cfg)
    new_pools = []
    for seg, seg_p, seg_pool in zip(segs, params["segments"], pools):
        def cycle_apply(cyc_p, cyc_pool, x):
            new_c = []
            for j, kind in enumerate(seg.kinds):
                x, c = blocks.apply_prefill_paged(
                    cyc_p[j], cfg, kind, x, cyc_pool[j], block_row,
                    start, n_valid, angles=angles)
                new_c.append(c)
            return x, tuple(new_c)

        if seg.scanned:
            def scan_body(x, inp):
                cyc_p, cyc_pool = inp
                x, new_c = cycle_apply(cyc_p, cyc_pool, x)
                return x, new_c
            x, new_seg = jax.lax.scan(scan_body, x, (seg_p, seg_pool))
        else:
            x, new_seg = cycle_apply(seg_p, seg_pool, x)
        new_pools.append(new_seg)
    return x, new_pools


def prefill_suffix_paged(params, cfg, tokens, pools, block_row, start,
                         n_valid):
    """Chunked prefill of a prompt *suffix* against the paged pools — the
    prefix-cache hit path.  The cached prefix (positions 0..start-1)
    already lives in shared pages named by ``block_row``; only the
    uncached suffix runs through the model, in ONE batched dispatch:
    each layer scatters the suffix kv into the request's pages and
    attends causally over the whole page run (cached prefix + suffix),
    same arithmetic as the decode path, no new kernel.

    tokens (1,W) int32 suffix ids, padded to a bucket width W; ``start``
    scalar int32 cached-prefix length; ``n_valid`` scalar int32 true
    suffix length (padded slots scatter to the null page, whose garbage
    is masked by design).  Returns (next-token logits (1,1,V) at the
    last *valid* suffix position — the request's first generated token —
    and the updated pools).
    """
    x, new_pools = _suffix_forward_paged(params, cfg, tokens, pools,
                                         block_row, start, n_valid)
    h_last = jnp.take(x, n_valid - 1, axis=1)[:, None]     # (1,1,D)
    h_last = nn.rmsnorm(h_last, params["final_norm"]["scale"], cfg.norm_eps)
    return head_logits(params, cfg, h_last), new_pools


def chunk_prefill_paged(params, cfg, tokens, pools, block_row, start,
                        n_valid):
    """One slice of a chunked prefill: positions ``start ..
    start+n_valid-1`` of a prompt whose earlier KV (cached prefix or
    previous chunks — the suffix body cannot tell the difference) is
    already in the pages named by ``block_row``.  A chunk at offset
    ``start`` IS a suffix continuation at ``start``, so this shares
    :func:`prefill_suffix_paged`'s body verbatim; composing k chunks
    writes the same KV, in the same order, with the same arithmetic, as
    one monolithic dispatch — the bit-identity the chunked oracle rung
    pins.  Only the final chunk's returned logits are consumed (the
    first generated token); intermediate chunks are dispatched for their
    pool side effect alone.
    """
    return prefill_suffix_paged(params, cfg, tokens, pools, block_row,
                                start, n_valid)


def verify_window_paged(params, cfg, tokens, pools, block_row, start,
                        n_valid):
    """Speculative-decoding verification: score K+1 continuation
    positions of ONE sequence in ONE batched dispatch.

    ``tokens`` (1,W) holds [last emitted token, draft_1..draft_K] padded
    to a pow2 bucket width W; ``start`` is the sequence's KV write
    position (the last emitted token's KV lands there, exactly as a
    decode step would place it) and ``n_valid`` = K+1.  The body is the
    same per-layer ``apply_prefill_paged`` path as the prefix-cache
    suffix prefill — kv for all K+1 inputs is scattered into the
    sequence's pages (padding routed to the null page) and every
    position attends causally over the whole page run — so scoring K+1
    positions costs one model pass instead of K+1 sequential decode
    steps, and the arithmetic matches the decode path token-for-token.

    Returns (logits (1,W,V) at every position — position j's greedy
    argmax is the model's true next token after input j, which the
    engine compares against draft j+1 to accept the longest matching
    prefix — and the updated pools).  Rejected positions' KV stays in
    the pages but is masked by position and overwritten before the
    write position reaches it; whole rejected pages are rolled back via
    ``PageAllocator.truncate_to``.
    """
    x, new_pools = _suffix_forward_paged(params, cfg, tokens, pools,
                                         block_row, start, n_valid)
    h = nn.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return head_logits(params, cfg, h), new_pools


def decode_step(params, cfg, tokens, caches, pos, *, impl=None):
    """One decode step. tokens (B,1) ids or (B,1,D) embeds; pos scalar.

    Returns (logits (B,1,V), new caches).
    """
    x = embed_tokens(params, cfg, tokens)
    B = x.shape[0]
    if cfg.mrope_sections is not None:
        positions = jnp.full((3, B, 1), pos, jnp.int32)
    else:
        positions = jnp.full((B, 1), pos, jnp.int32)
    angles = _angles(cfg, positions)

    segs = make_segments(cfg)
    new_caches = []
    for seg, seg_p, seg_c in zip(segs, params["segments"], caches):
        def cycle_decode(cyc_p, cyc_c, x):
            new_c = []
            for j, kind in enumerate(seg.kinds):
                x, c = blocks.apply_decode(cyc_p[j], cfg, kind, x, cyc_c[j],
                                           pos, angles=angles)
                new_c.append(c)
            return x, tuple(new_c)

        if seg.scanned:
            def scan_body(x, inp):
                cyc_p, cyc_c = inp
                x, new_c = cycle_decode(cyc_p, cyc_c, x)
                return x, new_c
            x, new_seg = jax.lax.scan(scan_body, x, (seg_p, seg_c))
        else:
            x, new_seg = cycle_decode(seg_p, seg_c, x)
        new_caches.append(new_seg)

    h = nn.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return head_logits(params, cfg, h), new_caches
