"""Differential serving fuzz: one small randomized arrival trace
replayed across the full flag cube {prefix-cache on/off} x {fused
on/off} x {spec-decode on/off + adaptive-K on/off} x {chunked-prefill
on/off} — 32 configurations, every one of which must emit greedy tokens
identical to the dense oracle, request for request.

The trace deliberately mixes the features' trigger conditions: shared
prefixes that diverge mid-page (COW), motif-tiled prompts whose greedy
continuations loop (speculation accepts), staggered arrivals (admission
events cap fused windows and speculation horizons), and a pool small
enough for growth pressure.  Adaptive K (``spec_k="auto"``) rides the
same trace with per-request EWMA depth control — device-resident
drafting in both spec modes.  Chunked prefill slices every admission
into page-aligned chunks under SLO-aware EDF — composition with COW
suffixes and speculative restarts is exactly where partial-block-row
bugs would hide.  The oracle and each configuration's output are
memoized per run so the 32-point cube costs one engine replay each, all
sharing one compiled step set (conftest / engine._jitted_steps).

The chaos axis (``test_chaos_cube_survivors_match_dense_oracle``)
replays the same trace under a pinned fault schedule — two node
failures with re-joins, a straggler window, transient admission
rejections — across the 16-point {prefix-cache} x {fused} x {spec} x
{chunked} cube on a 3-node striped pool: every request must still
emit oracle-identical tokens (fault recovery is exact greedy
recompute) with zero quarantined-page reads.
"""
import numpy as np
import pytest

from conftest import dense_oracle, get_tiny_model, make_engine, \
    seeded_prompts

PAGE = 4
MAX_BATCH = 2
N_PAGES = 26
CUBE = [(pc, fz, sp, ak, ck)
        for pc in (False, True) for fz in (False, True)
        for sp in (False, True) for ak in (False, True)
        for ck in (False, True)]

_MEMO = {}


def _trace():
    """(prompts, gens, arrival steps) — deterministic, seeded."""
    cfg, _ = get_tiny_model()
    shared = seeded_prompts(cfg, 2, 12, shared=9, seed=21)   # mid-page COW
    loops = seeded_prompts(cfg, 2, 12, motif=4, seed=33)     # spec fodder
    plain = seeded_prompts(cfg, 2, 12, seed=45)
    prompts = [shared[0], loops[0], plain[0], shared[1], loops[1],
               plain[1]]
    gens = [6, 9, 4, 7, 8, 5]
    arrivals = [0, 0, 1, 3, 5, 9]
    return prompts, gens, arrivals


def _replay(prefix_cache, fused, spec, adaptive=False, chunked=False,
            n_nodes=1, fault_plan=None):
    """Drive the engine like the trace benchmark: submissions land when
    the scheduler clock reaches their arrival step, windows never decode
    past the next arrival.  ``fault_plan`` arms the deterministic fault
    plane over an ``n_nodes``-striped pool (the chaos axis)."""
    cfg, params = get_tiny_model()
    prompts, gens, arrivals = _trace()
    max_len = max(p.shape[0] + g for p, g in zip(prompts, gens))
    eng = make_engine(cfg, params, max_batch=MAX_BATCH, page_size=PAGE,
                      n_pages=N_PAGES, max_len=max_len, fused=fused,
                      prefix_cache=prefix_cache, spec_decode=spec,
                      spec_k="auto" if adaptive else 4, max_window=4,
                      chunked_prefill=chunked, n_nodes=n_nodes)
    if fault_plan is not None:
        eng.install_faults(fault_plan)
    pending = sorted(zip(arrivals, range(len(prompts))))
    while pending or eng.sched.waiting or eng.sched.prefilling \
            or eng.sched.running:
        while pending and pending[0][0] <= eng.sched.step_idx:
            _, i = pending.pop(0)
            eng.submit(np.asarray(prompts[i]), gens[i], rid=f"r{i}",
                       slo="interactive" if i % 2 else "batch")
        if eng.sched.waiting or eng.sched.prefilling or eng.sched.running:
            cap = pending[0][0] - eng.sched.step_idx if pending else None
            eng.step(max_window=cap)
        else:
            eng.sched.step_idx += 1
    assert eng.alloc.check_conservation()
    if eng.cache is None:
        assert eng.alloc.pages_in_use == 0
    return eng, {r.rid: list(r.tokens) for r in eng.sched.finished}


def _oracle():
    if "oracle" not in _MEMO:
        cfg, params = get_tiny_model()
        prompts, gens, _ = _trace()
        max_len = max(p.shape[0] + g for p, g in zip(prompts, gens))
        _MEMO["oracle"] = dense_oracle(cfg, params, prompts, gens, max_len)
    return _MEMO["oracle"]


@pytest.mark.parametrize("prefix_cache,fused,spec,adaptive,chunked", CUBE)
def test_flag_cube_matches_dense_oracle(prefix_cache, fused, spec,
                                        adaptive, chunked):
    eng, toks = _replay(prefix_cache, fused, spec, adaptive, chunked)
    assert len(toks) == len(_oracle())
    assert toks == _oracle(), (prefix_cache, fused, spec, adaptive,
                               chunked)
    m = eng.metrics()
    # the features actually engaged on their trigger configs
    if prefix_cache:
        assert m["prefix_hits"] >= 1
    if spec:
        assert m["spec_verifies"] >= 1 and m["accept_rate"] > 0.0
        if adaptive:
            assert eng.spec.adaptive and m["spec_k_mean"] > 0.0
    else:
        # adaptive-K is a spec-decode mode: without spec it must be
        # inert (no controller, no spec metrics)
        assert eng.spec is None and "accept_rate" not in m
    if chunked:
        assert m["chunk_dispatches"] >= len(toks)
        assert m["chunk_tasks"] >= len(toks)
    else:
        # chunked counters must not exist on the monolithic scheduler
        assert not eng.sched.chunked and "chunk_tasks" not in m


CHAOS_CUBE = [(pc, fz, sp, ck)
              for pc in (False, True) for fz in (False, True)
              for sp in (False, True) for ck in (False, True)]


def _chaos_plan():
    """Pinned fault schedule for the chaos axis: a transient-rejection
    burst, a straggler window, and two node failures with re-joins —
    all on the step clock, so each cube point replays identically."""
    from repro.serving import FaultEvent, FaultPlan
    return FaultPlan([
        FaultEvent(2, "transient", count=2),
        FaultEvent(3, "slow", 2, duration=4, factor=4.0),
        FaultEvent(4, "fail", 1),
        FaultEvent(10, "join", 1),
        FaultEvent(14, "fail", 2),
        FaultEvent(20, "join", 2),
    ])


@pytest.mark.parametrize("prefix_cache,fused,spec,chunked", CHAOS_CUBE)
def test_chaos_cube_survivors_match_dense_oracle(prefix_cache, fused,
                                                 spec, chunked):
    """The fault-injection axis over the feature cube: the same seeded
    chaos schedule (two node failures + a straggler + transient
    admission rejections) against every {prefix-cache} x {fused} x
    {spec} x {chunked} composition, on a 3-node striped pool sized so
    nothing sheds.  Every request must finish with tokens bit-identical
    to the dense oracle — recovery is exact greedy recompute through
    whatever machinery the config composes (COW re-acquire, chunk
    restart, draft rollback) — and no dispatch may ever touch a
    quarantined page."""
    eng, toks = _replay(prefix_cache, fused, spec, False, chunked,
                        n_nodes=3, fault_plan=_chaos_plan())
    oracle = _oracle()
    assert toks.keys() == oracle.keys(), "a request was shed or lost"
    assert toks == oracle, (prefix_cache, fused, spec, chunked)
    m = eng.metrics()
    assert m["node_failures"] >= 2, "the watchdog missed a failure"
    assert m["requests_recovered"] >= 1, "no live request was hit"
    assert m["quarantined_served"] == 0
    assert m["transient_rejections"] >= 1
    assert eng.sched.conserved(eng._n_submitted)


def test_adaptive_spec_preemption_and_rollback_stay_exact():
    """Forced mid-stream preemption + draft rollback under adaptive K:
    a pool too small for the working set (budget 0 admits greedily)
    preempts a speculating request mid-window sequence; its recompute
    re-drafts from a re-pushed device history (the (rid, preemptions)
    key changed) and the adaptive controller keeps its EWMA across the
    preemption.  Tokens must stay dense-exact and every page returns."""
    cfg, params = get_tiny_model()
    prompts, _, _ = _trace()
    gens = [10, 14, 8, 11, 13, 9]     # longer tails than the cube trace:
    max_len = max(p.shape[0] + g       # deep drafts AND pool churn
                  for p, g in zip(prompts, gens))
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng = make_engine(cfg, params, max_batch=MAX_BATCH, page_size=PAGE,
                      n_pages=11, max_len=max_len, prefill_budget=0.0,
                      spec_decode=True, spec_k="auto", max_window=4)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(np.asarray(p), g, rid=f"r{i}")
    fin = eng.run()
    toks = {r.rid: list(r.tokens) for r in fin}
    assert toks == dense
    m = eng.metrics()
    assert m["preemptions"] >= 1, "pool never forced a preemption"
    assert m["spec_rollbacks"] >= 1, "trace never exercised rollback"
    assert m["spec_verifies"] >= 1
    assert eng.alloc.check_conservation() and eng.alloc.pages_in_use == 0


def test_mesh_axis_matches_dense_oracle():
    """The mesh axis of the cube: the pinned trace replayed at 1x1 (no
    mesh), 1x2 and 2x2 — striped KV pools with the shard_map
    owner-partials decode merge — in a forced-4-device subprocess (jax
    pins the device count at first init, so the main pytest process
    cannot host this).  Every layout must emit greedy tokens
    bit-identical to the dense oracle computed in the same subprocess,
    including the prefix-cache COW composition (shared prompts diverging
    mid-page on device-sharded pools) and a forced-preemption pool
    (victim recompute re-pushes translated block rows)."""
    import os
    from test_multidevice import run_py
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    out = run_py(f"""
        import sys
        sys.path.insert(0, {tests_dir!r})
        import numpy as np
        from conftest import (dense_oracle, get_tiny_model, make_engine,
                              seeded_prompts)
        from repro.launch.mesh import make_test_mesh

        cfg, params = get_tiny_model()
        shared = seeded_prompts(cfg, 2, 12, shared=9, seed=21)
        loops = seeded_prompts(cfg, 2, 12, motif=4, seed=33)
        plain = seeded_prompts(cfg, 2, 12, seed=45)
        prompts = [shared[0], loops[0], plain[0], shared[1], loops[1],
                   plain[1]]
        gens = [10, 14, 8, 11, 13, 9]
        max_len = max(p.shape[0] + g for p, g in zip(prompts, gens))
        dense = dense_oracle(cfg, params, prompts, gens, max_len)

        def replay(mesh, n_pages, **kw):
            eng = make_engine(cfg, params, max_batch=2, page_size=4,
                              n_pages=n_pages, max_len=max_len,
                              max_window=4, mesh=mesh, **kw)
            for i, (p, g) in enumerate(zip(prompts, gens)):
                eng.submit(np.asarray(p), g, rid=f"r{{i}}")
            eng.run()
            return eng, {{r.rid: list(r.tokens)
                          for r in eng.sched.finished}}

        for d, m in ((1, 1), (1, 2), (2, 2)):
            mesh = make_test_mesh(d, m) if d * m > 1 else None
            # prefix-cache COW on striped pools (divergence mid-page)
            eng, toks = replay(mesh, 26, prefix_cache=True)
            assert toks == dense, (d, m, "prefix")
            assert eng.cache.stats.cow_copies >= 1, (d, m)
            assert eng.metrics()["prefix_hits"] >= 1, (d, m)
            # forced preemption: pool too small for the working set
            eng, toks = replay(mesh, 12, prefill_budget=0.0)
            assert toks == dense, (d, m, "preempt")
            assert eng.metrics()["preemptions"] >= 1, (d, m)
            assert eng.alloc.check_conservation()
            assert eng.alloc.pages_in_use == 0
            print(f"{{d}}x{{m}} OK")
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_chunked_midprefill_preemption_recomputes_through_cache():
    """The forced composition trace: a half-prefilled CHUNKED request is
    preempted by a decoding tenant's page growth, then recomputes
    through the prefix cache and finishes while adaptive-K speculation
    drives the survivor — tokens stay dense-exact throughout.

    Construction: request C seeds the radix tree with a 17-token prefix
    (its whole prompt is referenced by B later, so the pool CANNOT
    relieve pressure by evicting tree pages).  A — a motif prompt under
    adaptive speculation, the earliest arrival, thus never a victim —
    grows page by page while B's 29-token prompt trickles through
    4-token chunks under the interactive budget.  The pool is sized so
    A's growth runs dry mid-B-prefill: the victim rule (latest arrival
    over running + prefilling) preempts B with ``prefilled <
    prompt_len``, releasing its COW reference; B's recompute re-acquires
    the shared prefix from the tree and completes."""
    cfg, params = get_tiny_model()
    shared = seeded_prompts(cfg, 2, 29, shared=17, seed=77)
    seed_prompt = np.asarray(shared[0][:17])       # C: exactly the prefix
    loop = seeded_prompts(cfg, 1, 8, motif=4, seed=88)[0]
    prompts = [seed_prompt, loop, shared[1]]
    gens = [2, 14, 4]
    max_len = max(p.shape[0] + g for p, g in zip(prompts, gens))
    dense = dense_oracle(cfg, params, prompts, gens, max_len)
    eng = make_engine(cfg, params, max_batch=2, page_size=PAGE,
                      n_pages=13, max_len=max_len, fused=True,
                      max_window=4, chunked_prefill=True, chunk_tokens=4,
                      prefix_cache=True, spec_decode=True, spec_k="auto")
    # phase 1: C completes alone and donates its pages to the tree
    eng.submit(np.asarray(prompts[0]), gens[0], rid="r0", slo="standard")
    eng.run()
    assert eng.cache is not None and eng.alloc.pages_in_use > 0
    # phase 2: A decodes (interactive: tight chunk budget for B), B's
    # long prompt chunks along until A's growth drains the pool
    eng.submit(np.asarray(prompts[1]), gens[1], rid="r1",
               slo="interactive")
    eng.step()
    eng.submit(np.asarray(prompts[2]), gens[2], rid="r2", slo="batch")
    fin = eng.run()
    toks = {r.rid: list(r.tokens) for r in eng.sched.finished}
    assert toks == dense
    m = eng.metrics()
    assert eng.sched.chunk_preemptions >= 1, \
        "B was never preempted mid-prefill"
    assert m["prefix_hits"] >= 2, "B's recompute missed the tree"
    assert m["spec_verifies"] >= 1 and m["accept_rate"] > 0.0
    assert eng.alloc.check_conservation()
    assert len(fin) >= 2 and len(toks) == 3
