"""MoE dispatch invariants — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_tiny_config
from repro.models import moe


def _cfg(E=8, k=2, cf=1.25):
    from repro.configs.base import MoEConfig
    return get_tiny_config("grok-1-314b").replace(
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=32,
                      capacity_factor=cf))


@settings(max_examples=25, deadline=None)
@given(T=st.integers(4, 64), E=st.integers(2, 16), k=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
def test_dispatch_invariants(T, E, k, seed):
    k = min(k, E)
    ids = jax.random.randint(jax.random.PRNGKey(seed), (T, k), 0, E)
    C = moe.capacity(_cfg(E=E, k=k), T)
    slot_tok, slot = moe.dispatch_indices(ids, T, k, E, C)
    slot_tok = np.asarray(slot_tok)
    slot = np.asarray(slot)
    # every non-sentinel slot holds a valid token id
    valid = slot_tok[slot_tok < T]
    assert ((valid >= 0) & (valid < T)).all()
    # no slot is double-assigned: kept assignments map to unique slots
    kept = slot[slot < E * C]
    assert len(np.unique(kept)) == len(kept)
    # each expert receives at most C tokens
    for e in range(E):
        n_e = ((slot >= e * C) & (slot < (e + 1) * C)).sum()
        assert n_e <= C
    # slot round-trips: slot s holds the token that was routed there
    for i, s in enumerate(slot):
        if s < E * C:
            assert slot_tok[s] == i // k


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_route_weights_normalized(seed):
    cfg = _cfg()
    tokens = jax.random.normal(jax.random.PRNGKey(seed), (16, cfg.d_model))
    rw = jax.random.normal(jax.random.PRNGKey(seed + 1),
                           (cfg.d_model, cfg.moe.n_experts))
    w, ids, aux = moe.route(cfg, rw, tokens)
    assert np.allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert float(aux) > 0.0
    assert (np.asarray(ids) < cfg.moe.n_experts).all()


def test_local_moe_matches_dense_when_capacity_huge():
    """With top_k == n_experts and huge capacity, MoE == sum of all expert
    FFNs weighted by (uniform) routing weights."""
    from repro.configs.base import MoEConfig
    cfg = get_tiny_config("grok-1-314b").replace(
        moe=MoEConfig(n_experts=2, top_k=2, d_ff_expert=16,
                      capacity_factor=4.0))
    key = jax.random.PRNGKey(0)
    p = moe.init(key, cfg, jnp.float32)
    T = 8
    tokens = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model))
    out, aux = moe.local_moe(cfg, tokens, p["router_w"], p.get("e_gate"),
                             p["e_up"], p["e_down"])
    # dense reference
    w, ids, _ = moe.route(cfg, p["router_w"], tokens)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    import repro.models.modules as nn
    ref = jnp.zeros_like(out)
    for t in range(T):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe.top_k):
            e = int(ids[t, j])
            h = nn.activation(cfg.act)(tokens[t] @ p["e_gate"][e]) \
                * (tokens[t] @ p["e_up"][e])
            acc += w[t, j] * (h @ p["e_down"][e])
        ref = ref.at[t].set(acc)
    assert jnp.abs(out - ref).max() < 1e-3


def test_moe_grad_flows():
    cfg = _cfg()
    p = moe.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = moe.apply(p, cfg, x)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    leaves = jax.tree.leaves(g)
    assert all(jnp.isfinite(l).all() for l in leaves)
    assert sum(float(jnp.abs(l).sum()) for l in leaves) > 0
