"""Swallow §III + §VIII + §X-B composed: the serving subsystem.

  paged_kv   — §X-B striped store applied to KV pages (host allocator;
               page owner = core/memory_server.striped_owner)
  scheduler  — §III farmer-worker continuous batching with §VIII-style
               priced admission and page-pressure preemption
  engine     — the device-side loop: paged pools, block tables, one
               jitted decode step per batch refill
  prefix_cache — §X-B's shared-memory overlay made load-bearing: a
               radix tree over token IDs whose nodes own ref-counted,
               immutable KV pages (copy-on-write on divergence, LRU
               eviction under pool pressure)
  spec_decode — §V's payload-per-dispatch argument applied to model
               passes: weightless n-gram drafting verified in one
               batched dispatch (accept-prefix + rollback), cutting
               dispatches per emitted token below 1.0; the proposer
               runs on device by default (fused draft+verify chain)
               with per-request adaptive draft depth (AdaptiveK)
  slo        — per-tenant SLO classes (TTFT deadlines on the step
               clock, tolerable-stall fractions) driving the chunked
               scheduler's EDF admission and per-window chunk budget
  telemetry  — §IV's measurement plane: the unified MetricsRegistry
               (counters/gauges/streaming percentile digests behind
               every module above), the StepTracer flight recorder
               (request-lifecycle + dispatch spans, Chrome-trace
               export for Perfetto, post-mortem flight dumps), and
               the predicted-vs-measured model-error rollup
  faults     — §VIII's failure model made deterministic: a seeded
               FaultPlan (node failures, transient dispatch errors,
               straggler slowdowns on the step clock) and the
               FaultPlane watchdog wiring runtime/health detectors
               into PageAllocator.fail_node quarantine + exact-
               recompute recovery through the preemption machinery

Entry points: ``repro.launch.serve --engine paged [--prefix-cache on]
[--spec-decode on] [--chunk-prefill on --slo <class>] [--fault-plan
chaos]`` and ``benchmarks/serve_trace.py``; docs in docs/SERVING.md,
docs/PREFIX_CACHE.md, docs/LOAD_TESTING.md, docs/FAULT_TOLERANCE.md
and docs/TESTING.md.
"""
from repro.serving.engine import PagedEngine
from repro.serving.faults import FaultEvent, FaultPlan, FaultPlane
from repro.serving.paged_kv import NULL_PAGE, PageAllocator
from repro.serving.prefix_cache import (PrefixCache, PrefixMatch,
                                        RadixNode)
from repro.serving.scheduler import (ContinuousBatchScheduler, Request,
                                     StepPlan)
from repro.serving.slo import DEFAULT_SLO, SLO_CLASSES, SLOClass, get_slo
from repro.serving.spec_decode import (AdaptiveK, NGramSpec, SpecStats,
                                       device_propose, propose_ngram)
from repro.serving.telemetry import (HistogramDigest, MetricsRegistry,
                                     Span, StepTracer,
                                     validate_chrome_trace)

__all__ = ["PagedEngine", "PageAllocator", "NULL_PAGE",
           "PrefixCache", "PrefixMatch", "RadixNode",
           "ContinuousBatchScheduler", "Request", "StepPlan",
           "NGramSpec", "SpecStats", "AdaptiveK", "propose_ngram",
           "device_propose",
           "SLOClass", "SLO_CLASSES", "DEFAULT_SLO", "get_slo",
           "FaultEvent", "FaultPlan", "FaultPlane",
           "HistogramDigest", "MetricsRegistry", "Span", "StepTracer",
           "validate_chrome_trace"]
