"""Swallow §III + §X-B composed: the paged-KV continuous-batching engine.

What is reproduced: the farmer-worker loop (§III, C3) running against a
striped memory server (§X-B) — the device-side half of the serving
subsystem.  Per-layer KV pools (``lm.init_paged_caches``) are the
striped store, the block-table matrix is the address map, and one jitted
``make_paged_serve_step`` call decodes every occupied slot of the batch
while :mod:`repro.serving.scheduler` refills freed slots with priced
prefills.

What is extrapolated: the paper's farmer distributes closed-form work
items; here slot state (tokens, positions, block tables) lives in small
host numpy arrays pushed to the device each step, which keeps the jitted
step shape-stable (fixed batch, fixed pool) — the property that lets a
tiny CPU host replay the same schedule a pod would run.

Greedy decoding throughout: paged vs dense token equality is an
acceptance gate (tests/test_serving.py), and it is also what makes
recompute-preemption exact.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.paged_kv import NULL_PAGE, PageAllocator
from repro.serving.scheduler import ContinuousBatchScheduler, Request


class PagedEngine:
    """Paged-KV serving engine over one model + one device mesh.

    ``max_len`` bounds prompt+gen per sequence; the block table has
    ``ceil(max_len / page_size)`` entries per slot.  ``n_pages`` includes
    the reserved null page.
    """

    def __init__(self, cfg, params, *, max_batch: int = 4,
                 page_size: int = 16, n_pages: int = 64,
                 max_len: int = 256, n_nodes: int = 1,
                 link_mode: str = "circuit", prefill_budget: float = 2.0):
        import jax
        import jax.numpy as jnp
        from repro.models import lm
        from repro import steps as steps_mod

        assert lm.paged_decodable(cfg), \
            f"{cfg.name} is not paged-decodable (attention-only, causal)"
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.nmax = -(-max_len // page_size)
        self._jnp = jnp

        self.alloc = PageAllocator(n_pages=n_pages, page_size=page_size,
                                   n_nodes=n_nodes)
        self.link_mode = link_mode
        self.n_nodes = n_nodes
        from repro.configs.base import ShapeConfig
        self.decode_estimate = self._estimate(
            ShapeConfig("serve_decode", max_len, max_batch, "decode"),
            link_mode, n_nodes)
        self.sched = ContinuousBatchScheduler(
            self.alloc, max_batch,
            prefill_cost_s=self._prefill_cost(link_mode, n_nodes),
            decode_cost_s=self.decode_estimate.step_time_s,
            prefill_budget=prefill_budget)

        self.pools = lm.init_paged_caches(cfg, n_pages=n_pages,
                                          page_size=page_size)
        self._prefill = jax.jit(steps_mod.make_paged_prefill_step(cfg),
                                donate_argnums=(2,))
        self._serve = jax.jit(steps_mod.make_paged_serve_step(cfg),
                              donate_argnums=(2,))
        # host-side slot state, pushed to device each step
        self.block_tables = np.full((max_batch, self.nmax), NULL_PAGE,
                                    np.int32)
        self.tokens = np.zeros((max_batch, 1), np.int32)
        self.pos = np.zeros((max_batch,), np.int32)
        self._n_submitted = 0
        self.steps_run = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        self.decode_time_s = 0.0
        self.peak_pages = 0
        self.t0 = time.time()

    def reset_metrics(self):
        """Zero every counter/clock (e.g. after a warmup pass) while
        keeping the compiled steps, pools and allocator state."""
        self.sched.finished.clear()
        self._n_submitted = 0
        self.steps_run = self.decode_steps = self.decode_tokens = 0
        self.decode_time_s = 0.0
        self.peak_pages = 0
        self.t0 = time.time()

    # -- cost-engine pricing (the scheduler's admission inputs) ------------
    def _estimate(self, shape, link_mode, n_nodes):
        from repro.core import costs
        return costs.estimate(self.cfg, costs.Layout(data=1, model=n_nodes),
                              link_mode, shape)

    def _prefill_cost(self, link_mode, n_nodes):
        from repro.configs.base import ShapeConfig

        def cost(prompt_len: int) -> float:
            shape = ShapeConfig("serve_prefill", max(prompt_len, 1), 1,
                                "prefill")
            return self._estimate(shape, link_mode, n_nodes).step_time_s
        return cost

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, gen: int, *, tenant: str = "default",
               rid: Optional[str] = None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        assert prompt.ndim == 1 and prompt.shape[0] + gen <= self.max_len
        rid = rid or f"r{self._n_submitted}"
        self._n_submitted += 1
        req = Request(rid=rid, prompt_len=int(prompt.shape[0]), gen=gen,
                      tenant=tenant, prompt=prompt)
        self.sched.submit(req)
        return req

    # -- one engine step ---------------------------------------------------
    def _block_row(self, rid: str) -> np.ndarray:
        row = np.full((self.nmax,), NULL_PAGE, np.int32)
        pages = self.alloc.held[rid]
        row[:len(pages)] = pages
        return row

    def _clear_slot(self, slot: int):
        self.block_tables[slot] = NULL_PAGE
        self.tokens[slot] = 0
        self.pos[slot] = 0

    def step(self) -> List[Request]:
        """Plan, prefill admissions, decode every occupied slot.  Returns
        requests finished this step."""
        jnp = self._jnp
        plan = self.sched.plan_step()
        finished: List[Request] = []
        for slot in range(self.max_batch):   # preempted/idle slots -> null
            if slot not in self.sched.running:
                self._clear_slot(slot)
        for req in plan.admitted:
            row = self._block_row(req.rid)
            logits, self.pools = self._prefill(
                self.params, jnp.asarray(req.prompt[None]), self.pools,
                jnp.asarray(row))
            tok = int(jnp.argmax(logits, -1)[0, 0])
            self.sched.note_first_token(req, tok)
            if req.state == "running":     # gen > 1: occupy the slot
                self.block_tables[req.slot] = row
                self.tokens[req.slot] = tok
                self.pos[req.slot] = req.pos
            else:                          # gen == 1: finished at prefill
                finished.append(req)
        if self.sched.running:
            # refresh block tables of grown requests
            for slot, req in self.sched.running.items():
                self.block_tables[slot] = self._block_row(req.rid)
                self.pos[slot] = req.pos
                if req.tokens:
                    self.tokens[slot] = req.tokens[-1]
            active = dict(self.sched.running)
            t_dec = time.time()
            tok, _, self.pools = self._serve(
                self.params, jnp.asarray(self.tokens), self.pools,
                jnp.asarray(self.block_tables), jnp.asarray(self.pos))
            tok_np = np.asarray(tok)          # blocks: decode-only timing
            self.decode_time_s += time.time() - t_dec
            self.decode_steps += 1
            emitted: Dict[int, int] = {s: int(tok_np[s, 0]) for s in active}
            self.decode_tokens += len(emitted)
            finished += self.sched.complete_step(emitted)
        else:
            self.sched.step_idx += 1
        for slot in range(self.max_batch):   # finished slots -> null
            if slot not in self.sched.running:
                self._clear_slot(slot)
        self.steps_run += 1
        self.peak_pages = max(self.peak_pages, self.alloc.pages_in_use)
        return finished

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Step until every submitted request finished."""
        while (self.sched.waiting or self.sched.running) \
                and self.steps_run < max_steps:
            self.step()
        if self.sched.waiting or self.sched.running:
            raise RuntimeError(
                f"engine wedged: {len(self.sched.waiting)} waiting / "
                f"{len(self.sched.running)} running after {max_steps} steps")
        assert self.sched.conserved(self._n_submitted)
        return self.sched.finished

    # -- observability -----------------------------------------------------
    def metrics(self) -> dict:
        fin = self.sched.finished
        dt = max(time.time() - self.t0, 1e-9)
        ttft = [r.first_token_step - r.arrived_step for r in fin
                if r.first_token_step is not None]
        return {
            "finished": len(fin),
            "tokens_out": sum(len(r.tokens) for r in fin),
            "steps": self.steps_run,
            "tok_per_s": sum(len(r.tokens) for r in fin) / dt,
            "decode_step_s": self.decode_time_s / max(self.decode_steps, 1),
            "ttft_steps_mean": float(np.mean(ttft)) if ttft else 0.0,
            "ttft_steps_p95": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "pages_in_use": self.alloc.pages_in_use,
            "peak_pages": self.peak_pages,
            "page_occupancy": self.peak_pages / max(self.alloc.n_pages - 1,
                                                    1),
            "preemptions": sum(r.preemptions for r in self.sched.all_requests),
        }
