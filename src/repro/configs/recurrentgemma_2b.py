"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf-verified].

Hybrid: 26L, d_model=2560, 10 Q heads / 1 KV head (MQA), d_ff=7680,
vocab=256000.  Repeating (RG-LRU, RG-LRU, local-attention) pattern — 2:1
recurrent:attention — with a 2048-token local window, GeGLU, sqrt(d) embed
scale.  Sub-quadratic: eligible for the long_500k shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    lru_width=2560,
    conv1d_width=4,
    act="gelu",
    gated_ffn=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=32, lru_width=64,
        attn_block_q=16, attn_block_kv=32)
