"""Multi-device behaviour via subprocesses (the main pytest process must
keep seeing ONE device — jax locks the device count at first init)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + \
        f" --xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_sharded_loss_matches_local():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_tiny_config
        from repro.models import lm
        from repro.parallel.sharding import use_sharding
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 4)
        for arch in ("qwen3-14b", "deepseek-v3-671b", "rwkv6-1.6b",
                     "recurrentgemma-2b"):
            cfg = get_tiny_config(arch)
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            B, S = 4, 64
            k1, k2 = jax.random.split(jax.random.PRNGKey(7))
            tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
            labels = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
            batch = {"tokens": tokens, "labels": labels,
                     "mask": jnp.ones((B, S), jnp.float32)}
            l0, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
            with use_sharding(mesh):
                l1, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(
                    params, batch)
            assert abs(float(l0) - float(l1)) < 2e-2, (arch, l0, l1)
        print("OK")
    """)
    assert "OK" in out


def test_striped_store_write_keeps_placement():
    """``StripedStore.write`` goes through ``.at[].set`` — a scatter whose
    output sharding XLA may resolve to replicated.  The store re-pins the
    stripe after every write; this asserts the slab still carries the
    P("model") placement (and round-trips values) afterwards."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.memory_server import StripedStore, stripe_slab_index
        from repro.parallel.sharding import use_sharding
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(1, 4)
        with use_sharding(mesh):
            st = StripedStore(size=64)
            want = NamedSharding(mesh, P("model"))
            assert st.slab.sharding.is_equivalent_to(want, st.slab.ndim), \\
                st.slab.sharding
            addrs = jnp.array([0, 5, 17, 63])
            st.write(addrs, jnp.array([1., 2., 3., 4.]))
            # the write must not decay the stripe to replicated
            assert st.slab.sharding.is_equivalent_to(want, st.slab.ndim), \\
                st.slab.sharding
            assert jnp.array_equal(st.read(addrs),
                                   jnp.array([1., 2., 3., 4.]))
            # host rule and device placement agree: slab row of address a
            # is the stripe permutation, and row 0 stays row 0
            assert int(stripe_slab_index(0, st.n, st.size)) == 0
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_lattice_allreduce_and_pipeline():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.parallel.sharding import use_sharding
        from repro.parallel import lattice, pipeline
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        with use_sharding(mesh):
            x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
            out = lattice.lattice_all_reduce(x, fast_axes=("data",),
                                             slow_axis="pod")
            assert jnp.allclose(out, x * 8)
        mesh2 = jax.make_mesh((4,), ("stage",))
        W = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
        stage_fn = lambda w, x: jnp.tanh(x @ w)
        seq = x
        for s in range(4):
            seq = stage_fn(W[s], seq)
        with use_sharding(mesh2):
            y = jax.jit(lambda W, x: pipeline.pipeline_apply(
                stage_fn, W, x, n_micro=4, axis="stage"))(W, x)
        assert jnp.abs(y - seq).max() < 1e-5
        print("OK")
    """)
    assert "OK" in out


def test_compressed_allreduce_error_feedback():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.parallel.sharding import use_sharding
        from repro.optim import compress
        mesh = jax.make_mesh((4,), ("data",))
        with use_sharding(mesh):
            g = jax.random.normal(jax.random.PRNGKey(0), (3000,))
            err = jnp.zeros_like(g)
            # accumulated estimate over steps: error feedback keeps the
            # running mean unbiased-ish
            acc = jnp.zeros_like(g)
            for _ in range(8):
                red, err = jax.jit(lambda g, e: compress.compressed_all_reduce(
                    g, e, axis="data"))(g, err)
                acc = acc + red
            rel = float(jnp.abs(acc / 8 - g).max() / jnp.abs(g).max())
            assert rel < 0.02, rel
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_small_mesh_cell():
    """A miniature dry-run: lower + compile a tiny arch on 8 devices,
    memory/cost/collective record extraction end to end."""
    out = run_py("""
        import jax
        from repro.configs import get_tiny_config
        from repro.configs.base import ShapeConfig
        from repro import steps as steps_mod
        from repro.parallel.sharding import use_sharding
        from repro.launch.mesh import make_test_mesh
        from repro.analysis import hlo
        cfg = get_tiny_config("qwen3-14b")
        shape = ShapeConfig("t", 128, 16, "train")
        mesh = make_test_mesh(2, 4)
        with use_sharding(mesh) as env:
            adam_cfg = steps_mod.adam_config_for(cfg)
            params, opt = steps_mod.make_state_structs(cfg, adam_cfg, mesh, env)
            batch = steps_mod.make_batch_struct(cfg, shape, mesh, env)
            step = steps_mod.make_train_step(cfg, adam_cfg)
            compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt, batch).compile()
            mem = compiled.memory_analysis()
            assert mem.temp_size_in_bytes > 0
            summ = hlo.collective_summary(compiled.as_text())
            assert summ["total_wire_bytes_per_device"] > 0
        print("OK")
    """)
    assert "OK" in out


def test_elastic_reshard_across_meshes():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_tiny_config
        from repro.models import lm
        from repro.optim import adam as adam_lib
        from repro.runtime import checkpoint as ckpt, elastic
        from repro.parallel.sharding import use_sharding
        from repro.launch.mesh import make_test_mesh
        import tempfile
        cfg = get_tiny_config("qwen3-14b")
        adam_cfg = adam_lib.AdamConfig()
        d = tempfile.mkdtemp()
        # save sharded on a (4,2) mesh
        mesh_a = make_test_mesh(4, 2)
        with use_sharding(mesh_a) as env:
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            opt = adam_lib.init(params, adam_cfg)
            ps, os_ = elastic.state_shardings(cfg, adam_cfg, env)
            params = jax.device_put(params, ps)
            opt = jax.device_put(opt, os_)
            ckpt.save(d, 5, {"params": params, "opt": opt})
        # restore onto a (2,4) mesh (elastic rescale)
        mesh_b = make_test_mesh(2, 4)
        step, p2, o2 = elastic.restore_elastic(d, cfg, adam_cfg, mesh_b)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            assert jnp.array_equal(jax.device_get(a), jax.device_get(b))
        print("OK")
    """)
    assert "OK" in out
