"""Benchmark driver: one section per paper table/figure + micro timings +
the roofline table.  Prints ``name,us_per_call,derived`` CSV.

``--json`` additionally emits the machine-readable perf trajectory:
``BENCH_micro.json`` (every micro row), ``BENCH_serve.json`` (the
fused-vs-per-step serving comparison with token-identity check),
``BENCH_prefix.json`` (the prefix-cache on-vs-off shared-prefix trace:
hit rate, prefill-token reduction, token identity) and
``BENCH_spec.json`` (speculative decoding on-vs-off on the repetitive
trace: dispatches per token, accept rate, token identity) and
``BENCH_slo.json`` (chunked prefill vs monolithic on the overload
trace: per-SLO-class TTFT percentiles, goodput, token identity) and
``BENCH_chaos.json`` (fault-free vs seeded-chaos on the
fault-injection trace: survivor token identity, goodput retained,
recovery percentiles) and ``BENCH_obs.json`` (flight recorder off vs
on on the overload trace: token identity, tracing overhead ratio, the
predicted-vs-measured model-error rollup, a schema-validated trace
excerpt) and ``BENCH_tp.json`` (the pinned workload replayed at every
serving layout — 1x1 vs striped 1x2/2x2 meshes in forced-device
subprocesses: token bit-identity across layouts, predicted
interconnect cost per window, and the measured remote page fraction
against the (n-1)/n stripe model) into ``--json-dir``.  ``--only PATTERN`` filters sections by substring (an
unknown pattern is an error listing the valid titles) — the CI
perf-smoke job runs ``--only micro --json`` and validates the files
with ``scripts/check_bench.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    sys.path.insert(0, ".")
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="run only sections whose title contains this")
    ap.add_argument("--json", action="store_true",
                    help="emit BENCH_micro.json + BENCH_serve.json")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_*.json files")
    args = ap.parse_args()

    from benchmarks import cost_sweep as cs
    from benchmarks import paper_tables as pt
    from benchmarks import perf_micro as pm
    from benchmarks import roofline_table as rt
    from benchmarks import serve_trace as st

    sections = [
        ("Table II (link energies)", pt.table2_link_energy),
        ("Table III (e/c, E/C)", pt.table3_ec_ratio),
        ("Table IV (power/core)", pt.table4_power),
        ("Fig 3 (memory/task)", pt.fig3_memory_per_task),
        ("Fig 5 (thread throughput)", pt.fig5_thread_throughput),
        ("Fig 9/10 (DVFS)", pt.fig9_fig10_dvfs),
        ("Fig 11 (neuron scaling)", pt.fig11_neuron_scaling),
        ("Fig 8/9 (nOS cost sweep)", cs.sweep_rows),
        ("micro: train grad", pm.micro_train_steps),
        ("micro: kernels", pm.micro_kernels),
        ("micro: serve", pm.micro_serve),
        ("micro: data", pm.micro_data_pipeline),
        ("micro: checkpoint", pm.micro_checkpoint),
        ("roofline table", rt.roofline_rows),
    ]
    if args.only:
        all_titles = [t for t, _ in sections]
        sections = [(t, f) for t, f in sections if args.only in t]
        if not sections:
            print(f"--only {args.only!r} matches no section; valid "
                  f"titles (substring match):", file=sys.stderr)
            for t in all_titles:
                print(f"  {t}", file=sys.stderr)
            raise SystemExit(2)
    print("name,us_per_call,derived")
    failures = 0
    micro_rows = []
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
                if name.startswith("micro/"):
                    micro_rows.append(
                        {"name": name, "us_per_call": float(us),
                         "derived": str(derived)})
        except Exception:
            traceback.print_exc()
            failures += 1
    if args.json:
        os.makedirs(args.json_dir, exist_ok=True)
        micro = {
            "schema": "swallow.bench.micro/v1",
            "host": platform.machine(),
            "python": platform.python_version(),
            "rows": micro_rows,
        }
        micro_path = os.path.join(args.json_dir, "BENCH_micro.json")
        with open(micro_path, "w") as f:
            json.dump(micro, f, indent=1)
        print(f"# wrote {micro_path} ({len(micro_rows)} rows)")
        comparisons = [
            ("BENCH_serve.json", st.bench_fused_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"speedup_decode={d['speedup_decode']:.2f}x"),
            ("BENCH_prefix.json", st.bench_prefix_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"hit_rate={d['on']['hit_rate']:.2f}, "
                       f"prefill_token_reduction="
                       f"{d['prefill_token_reduction']:.2f}x"),
            ("BENCH_spec.json", st.bench_spec_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"dispatches_per_token="
                       f"{d['on']['dispatches_per_token']:.3f} vs "
                       f"{d['off']['dispatches_per_token']:.3f}, "
                       f"accept_rate={d['on']['accept_rate']:.2f}, "
                       f"spec_speedup={d['spec_speedup']:.2f}x"),
            ("BENCH_slo.json", st.bench_slo_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"p99_ttft_ratio={d['p99_ttft_ratio']:.2f}, "
                       f"goodput_ratio={d['goodput_ratio']:.2f}"),
            ("BENCH_chaos.json", st.bench_chaos_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"node_failures={d['chaos']['node_failures']}, "
                       f"requests_recovered="
                       f"{d['chaos']['requests_recovered']}, "
                       f"goodput_retained={d['goodput_retained']:.2f}"),
            ("BENCH_obs.json", st.bench_obs_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"overhead_ratio={d['overhead_ratio']:.3f}, "
                       f"spans={d['on']['spans_recorded']}"),
            ("BENCH_tp.json", st.bench_tp_comparison,
             lambda d: f"tokens_match={d['tokens_match']}, "
                       f"layouts={[l['layout'] for l in d['layouts']]}, "
                       f"remote_frac_ratio="
                       f"{d['layouts'][-1]['remote_frac_ratio']:.3f}"),
        ]
        for fname, bench_fn, summarize in comparisons:
            try:
                doc = bench_fn(quick=True)
                path = os.path.join(args.json_dir, fname)
                with open(path, "w") as f:
                    json.dump(doc, f, indent=1)
                print(f"# wrote {path} ({summarize(doc)})")
            except Exception:
                traceback.print_exc()
                failures += 1
    if not args.only:
        print("# --- full roofline table ---")
        try:
            rt.print_full_table()
        except Exception:
            traceback.print_exc()
            failures += 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
