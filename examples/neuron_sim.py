"""Case study I (Swallow §X-A): Izhikevich spiking-network simulation.

Event-driven spiking neurons with 10% random connectivity; spikes are
"messages" (here: a masked matmul against the connectivity table — on a
mesh, neurons shard over devices and the spike vector is the all-gathered
message multicast the paper describes).

Also reproduces the Fig. 11 scaling analysis: per-neuron state is ~18 B
but the 10% connectivity table costs N bits *per neuron*, so neurons per
64 kB core shrink as N grows and the processors needed grow ~N^2 — the
paper's conclusion (run many modest sims, not one huge one) falls out of
``scaling_curve``.

Run:  PYTHONPATH=src python examples/neuron_sim.py [--neurons 512]
"""
import argparse
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

CORE_BYTES = 64 * 1024
STATE_BYTES = 18          # 8 B state + 10 B event buffer (paper)
CODE_STACK = 1100 + 336   # shared code + stack


def max_neurons_per_core(total_neurons: int,
                         connectivity: float = 0.10) -> int:
    """Paper's memory model: state + N-bit connectivity row per neuron."""
    table_bytes = total_neurons / 8.0
    per_neuron = STATE_BYTES + table_bytes
    avail = CORE_BYTES - CODE_STACK
    return max(int(avail // per_neuron), 0)


def scaling_curve(max_procs: int = 100_000):
    """(neurons_per_core, total_neurons) pairs — Fig. 11's red line."""
    out = []
    for n_per_core in (1, 2, 4, 8, 16, 32, 64, 128, 191):
        # solve total = procs * n_per_core with the table constraint
        # table for total neurons must fit: n_per_core*(18 + total/8) < 63k
        total = (CORE_BYTES - CODE_STACK) / n_per_core - STATE_BYTES
        total *= 8.0                       # bits -> neurons
        procs = total / n_per_core
        if procs > max_procs:
            total = max_procs * n_per_core
        out.append((n_per_core, total))
    return out


def simulate(n_neurons: int = 512, steps: int = 200, seed: int = 0,
             connectivity: float = 0.10, dt: float = 1.0):
    """Izhikevich regular-spiking network with random 10% connectivity."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    # heterogeneous parameters (Izhikevich 2003)
    r = jax.random.uniform(k1, (n_neurons,))
    exc = jax.random.uniform(k2, (n_neurons,)) < 0.8
    a = jnp.where(exc, 0.02, 0.02 + 0.08 * r)
    b = jnp.where(exc, 0.2, 0.25 - 0.05 * r)
    c = jnp.where(exc, -65.0 + 15 * r ** 2, -65.0)
    d = jnp.where(exc, 8.0 - 6 * r ** 2, 2.0)
    W = (jax.random.uniform(k3, (n_neurons, n_neurons)) < connectivity)
    Wv = jnp.where(W, jnp.where(exc[None, :], 0.5, -1.0), 0.0)

    def step(state, key):
        v, u = state
        I = 5.0 * jax.random.normal(key, (n_neurons,))
        fired = v >= 30.0
        I = I + Wv @ fired.astype(jnp.float32)   # spike multicast
        v = jnp.where(fired, c, v)
        u = jnp.where(fired, u + d, u)
        v = v + dt * (0.04 * v * v + 5.0 * v + 140.0 - u + I)
        v = jnp.minimum(v, 30.0)
        u = u + dt * a * (b * v - u)
        return (v, u), fired.sum()

    keys = jax.random.split(key, steps)
    v0 = jnp.full((n_neurons,), -65.0)
    u0 = b * v0
    (_, _), spikes = jax.lax.scan(step, (v0, u0), keys)
    total = int(spikes.sum())
    return {"total_spikes": total,
            "rate_hz": total / n_neurons / (steps * dt / 1000.0)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--neurons", type=int, default=512)
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    res = simulate(args.neurons, args.steps)
    print(f"simulated {args.neurons} neurons x {args.steps} ms: "
          f"{res['total_spikes']} spikes ({res['rate_hz']:.1f} Hz/neuron)")

    print("\nFig. 11 scaling (64 kB cores, 10% connectivity):")
    print(f"{'neurons/core':>14} {'total neurons':>14} {'procs needed':>14}")
    for npc, total in scaling_curve():
        print(f"{npc:>14} {total:>14.0f} {total / npc:>14.0f}")
    print(f"\nmax neurons/core at N=100k: {max_neurons_per_core(100_000)}"
          f" (the paper's hard-limit regime: P ~ N^2)")


if __name__ == "__main__":
    main()
