"""Pipeline parallelism over a mesh axis (Swallow Fig. 2b at pod scale).

GPipe-style schedule via shard_map + ppermute: stage s holds its layer
group's params (stacked dim sharded over the "stage" axis); microbatches
enter at stage 0, flow through the ring, and leave at stage n-1.  The
fill/drain bubble is the textbook (n_stages - 1) / (n_micro + n_stages - 1)
overhead, reported by ``bubble_fraction``.  Differentiating through the
shard_map transposes every ppermute, so the backward pass is the reverse
pipeline automatically.

The unit here is an arbitrary ``stage_fn(stage_params, x) -> x``; the
benchmarks drive it with transformer-block stacks.  The collective-permute
traffic this emits is the Swallow "streaming" pattern: activations only,
no weights, nearest-neighbor.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import current_env

from repro.parallel.sharding import compat_shard_map as _shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable, stage_params, x,
                   *, n_micro: int, axis: str = "stage"):
    """Run ``x`` through ``n_stages`` = mesh.shape[axis] stages.

    stage_params: pytree with leading dim n_stages (sharded over ``axis``).
    x: (batch, ...) — batch must divide n_micro.
    Returns y with the same shape, replicated over the stage axis.
    """
    env = current_env()
    if env is None or axis not in env.mesh.axis_names \
            or env.mesh.shape[axis] == 1:
        # degenerate: run all stages sequentially
        n_stages = jax.tree.leaves(stage_params)[0].shape[0]
        for s in range(n_stages):
            p_s = jax.tree.map(lambda l: l[s], stage_params)
            x = stage_fn(p_s, x)
        return x

    n_stages = env.mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    xs = x.reshape(n_micro, mb, *x.shape[1:])
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(p_local, xs_l):
        p_s = jax.tree.map(lambda l: l[0], p_local)
        idx = jax.lax.axis_index(axis)
        is_first = idx == 0
        is_last = idx == n_stages - 1
        carry = jnp.zeros_like(xs_l[0])
        outs = jnp.zeros_like(xs_l)
        total = n_micro + n_stages - 1
        for t in range(total):
            inject = xs_l[min(t, n_micro - 1)]
            x_in = jnp.where(is_first, inject, carry)
            y = stage_fn(p_s, x_in)
            o_idx = t - (n_stages - 1)
            if o_idx >= 0:
                outs = jnp.where(is_last,
                                 outs.at[o_idx].set(y), outs)
            carry = jax.lax.ppermute(y, axis, fwd_perm)
        # deliver: only the last stage holds real outputs -> psum-mask
        outs = jnp.where(is_last, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    ys = _shard_map(body, mesh=env.mesh, in_specs=in_specs, out_specs=P(),
                    check_vma=False)(stage_params, xs)
    return ys.reshape(B, *x.shape[1:])
