"""Pallas TPU RG-LRU linear-recurrence scan.

h_t = a_t * h_{t-1} + b_t over the sequence, vectorized across a width
tile.  Grid (B, nW, nS): the width dim is "parallel" (independent lanes),
the sequence dim "arbitrary" (sequential) with the running state h in
VMEM scratch.  Inside a (block_s, block_w) tile the recurrence steps row
by row on the VPU — the width tile (128-lane aligned) keeps the vector
units full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _rglru_kernel(a_ref, b_ref, o_ref, hT_ref, h_ref, *, block_s, ns):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)   # (bs, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h

    @pl.when(si == ns - 1)
    def _fin():
        hT_ref[0, ...] = h_ref[...]


def rglru_scan(a, b, h0, *, block_s=256, block_w=256, interpret=True):
    """a, b (B,S,W) fp32; h0 (B,W). Returns (h (B,S,W), hT (B,W)).

    h0 is folded into b[0] (b'_0 = a_0*h0 + b_0) so the kernel always
    starts from zero state.
    """
    B, S, W = a.shape
    b = b.at[:, 0].add(a[:, 0] * h0)
    bs = min(block_s, S)
    while S % bs:
        bs -= 1
    bw = min(block_w, W)
    while W % bw:
        bw -= 1
    ns, nw = S // bs, W // bw

    kernel = functools.partial(_rglru_kernel, block_s=bs, ns=ns)
    hs, hT = pl.pallas_call(
        kernel,
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
            pl.BlockSpec((1, bw), lambda bb, w, s: (bb, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return hs, hT
