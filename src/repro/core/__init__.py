"""Swallow's contributions (C1-C10, see DESIGN.md §1) as composable modules.

  principles     — §II-A scale-free property checks
  ratio          — §II-B/V-D e/c & E/C methodology (Tab. III)
  topology       — §V-A 2.5-D lattice + dimension-ordered routing
  network        — §V-B/C packet vs circuit link model
  energy         — §VI-VII energy transparency & proportionality
  memory_server  — §III-A/X-B nodes-as-storage, address%n striping
  overlays       — §III-B overlays -> remat/weight-streaming planner
  paradigms      — §III farmer-worker / streaming pipelines
  nos            — §VIII nOS: cost-aware multi-tenant mesh-slice scheduler
  costs          — §II-B+§V+§VI composed: the unified cost engine
                   (estimate(config, layout, mode)) behind --layout auto,
                   nOS admission and benchmarks/cost_sweep.py

The serving-side composition of these pieces (paged KV over the striped
store, priced continuous batching) lives in ``repro.serving``; see
docs/SERVING.md and docs/ARCHITECTURE.md for the layer map.
"""
